"""Invariant lint driver: ``python -m repro.analysis.lint src/``.

Runs the repo-specific AST rules (:mod:`repro.analysis.rules`) over the
given files/directories and exits nonzero on any finding — the CI
``analysis`` job gates every PR on a clean tree (DESIGN.md §11).

Suppression: a deliberate exception carries ``# lint: ok[rule-name]``
on the flagged line (or the line directly above); a bare
``# lint: ok`` suppresses every rule on that line. Use sparingly — the
pragma is greppable on purpose.

Programmatic surface (what the fixture tests drive)::

    from repro.analysis.lint import lint_source, lint_paths
    findings = lint_source(code, "snippet.py", rules={"scatter-drop"})
"""

from __future__ import annotations

import argparse
import ast
import os
import re
import sys
from typing import Iterable, List, Optional, Sequence, Set

from repro.analysis.rules import ALL_RULES, RULES_BY_NAME, Finding

_PRAGMA = re.compile(r"#\s*lint:\s*ok(?:\[([a-z0-9, -]+)\])?")


def _select(rules: Optional[Iterable[str]]):
    if rules is None:
        return ALL_RULES
    names = set(rules)
    unknown = names - set(RULES_BY_NAME)
    if unknown:
        raise ValueError(
            f"unknown rule(s) {sorted(unknown)}; "
            f"known: {sorted(RULES_BY_NAME)}")
    return tuple(r for r in ALL_RULES if r.name in names)


def _suppressed(finding: Finding, lines: Sequence[str]) -> bool:
    for lineno in (finding.line, finding.line - 1):
        if 1 <= lineno <= len(lines):
            m = _PRAGMA.search(lines[lineno - 1])
            if m:
                if m.group(1) is None:
                    return True
                allowed = {s.strip() for s in m.group(1).split(",")}
                if finding.rule in allowed:
                    return True
    return False


def lint_source(source: str, filename: str = "<string>",
                rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint one source string; returns the (pragma-filtered) findings."""
    try:
        tree = ast.parse(source, filename=filename)
    except SyntaxError as e:
        return [Finding(filename, e.lineno or 0, e.offset or 0,
                        "syntax", f"syntax error: {e.msg}")]
    lines = source.splitlines()
    findings: List[Finding] = []
    for rule in _select(rules):
        findings.extend(f for f in rule.check(tree, filename)
                        if not _suppressed(f, lines))
    return sorted(findings, key=lambda f: (f.file, f.line, f.col, f.rule))


def _py_files(paths: Iterable[str]) -> List[str]:
    out: List[str] = []
    for p in paths:
        if os.path.isdir(p):
            for root, dirs, files in os.walk(p):
                dirs[:] = sorted(d for d in dirs
                                 if not d.startswith((".", "__pycache__")))
                out.extend(os.path.join(root, f) for f in sorted(files)
                           if f.endswith(".py"))
        elif p.endswith(".py"):
            out.append(p)
    return out


def lint_paths(paths: Iterable[str],
               rules: Optional[Iterable[str]] = None) -> List[Finding]:
    """Lint every .py file under ``paths`` (files or directories)."""
    findings: List[Finding] = []
    for path in _py_files(paths):
        with open(path, "r", encoding="utf-8") as f:
            findings.extend(lint_source(f.read(), path, rules=rules))
    return findings


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific invariant lint (DESIGN.md §11)")
    ap.add_argument("paths", nargs="*", default=["src"],
                    help="files or directories to lint (default: src)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to run")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalogue and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in ALL_RULES:
            print(f"{r.name:14s} {r.summary}")
        return 0

    rules: Optional[Set[str]] = None
    if args.rules:
        rules = {s.strip() for s in args.rules.split(",") if s.strip()}
    findings = lint_paths(args.paths or ["src"], rules=rules)
    for f in findings:
        print(f)
    n_files = len(_py_files(args.paths or ["src"]))
    if findings:
        print(f"\n{len(findings)} finding(s) in {n_files} file(s)",
              file=sys.stderr)
        return 1
    print(f"clean: {n_files} file(s), "
          f"{len(rules) if rules else len(ALL_RULES)} rule(s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
