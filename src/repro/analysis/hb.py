"""Vector clocks for the runtime threadcomm sanitizer (DESIGN.md §11).

The sanitizer models every execution context that can issue communication
— each ``CommStream`` plus one implicit "host" context per root threadcomm
— as a vector-clock process. Issues tick the issuing context's clock;
``wait()`` merges the request's issue-time snapshot into the waiter's
context; entering a stream merges the parent context (program order flows
into the stream). Two operations are *concurrent* — the paper's §2
accidental-serialization precondition — exactly when neither snapshot
happens-before the other.
"""

from __future__ import annotations

from typing import Dict, Hashable


class VectorClock:
    """A sparse vector clock over hashable context keys."""

    __slots__ = ("_c",)

    def __init__(self, init: Dict[Hashable, int] = None):
        self._c: Dict[Hashable, int] = dict(init) if init else {}

    def tick(self, ctx: Hashable) -> int:
        """Advance this clock's component for ``ctx``; returns the new
        component value."""
        v = self._c.get(ctx, 0) + 1
        self._c[ctx] = v
        return v

    def merge(self, other: "VectorClock") -> None:
        """Pointwise max — the happens-before join (message receive)."""
        for k, v in other._c.items():
            if v > self._c.get(k, 0):
                self._c[k] = v

    def copy(self) -> "VectorClock":
        return VectorClock(self._c)

    def leq(self, other: "VectorClock") -> bool:
        """True iff self happens-before-or-equals other (pointwise <=)."""
        return all(v <= other._c.get(k, 0) for k, v in self._c.items())

    def concurrent_with(self, other: "VectorClock") -> bool:
        """Neither snapshot ordered before the other: a real race window."""
        return not self.leq(other) and not other.leq(self)

    def __repr__(self) -> str:  # debugging aid only
        return f"VectorClock({self._c!r})"
