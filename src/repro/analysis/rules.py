"""Repo-specific AST lint rules (DESIGN.md §11).

Six rules, each enforcing an invariant the generic linters cannot see
because it lives in this repo's conventions (drop-mode scatters over
parked slots, carried-state threading, jit donation, Request
lifecycles, MPIX-stream regions, host/device sync discipline):

* ``scatter-drop``   — slot/block-table-indexed ``.at[...]`` writes must
  carry explicit ``mode="drop"``.
* ``state-thread``   — ``.at[...]`` writes into carried-state leaves
  (``conv``/``ssm``/``cross_k``/``cross_v`` — DESIGN.md §13) must carry
  explicit ``mode="drop"``, whatever the index is named.
* ``donated-use``    — a buffer passed through a ``donate_argnums`` jit
  must not be read again before it is rebound.
* ``request-leak``   — every issued ``Request`` must reach
  ``wait``/``test``/``waitall`` on every path (including the exception
  path of a try/finally).
* ``stream-order``   — no blocking collective inside a
  ``with comm.stream(...)`` region; no comm op on a comm after
  ``finish()``/``free()`` without a revalidating ``start()``.
* ``host-sync``      — no host-synchronizing call (``.item()``,
  ``np.asarray`` of a traced value, ``float()`` of a parameter, ...)
  inside a jit'd micro-step body.

The rules are deliberately heuristic (name patterns, function-local
dataflow): they are tuned to produce zero false positives on this tree
while catching the real bug classes PR 4/PR 5 had to find by hand.
Suppress a deliberate exception with ``# lint: ok[rule-name]`` on the
flagged line.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple


@dataclass(frozen=True)
class Finding:
    file: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.file}:{self.line}:{self.col}: [{self.rule}] {self.message}"


def _chain(node: ast.AST) -> Optional[str]:
    """Dotted name for a Name/Attribute chain ('self.kv.buffers'), else
    None for anything with a non-trivial base."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _walk_skipping_defs(node: ast.AST):
    """Yield descendant nodes without descending into nested function or
    class definitions (their bodies run in another scope/time)."""
    stack = list(ast.iter_child_nodes(node))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                              ast.Lambda, ast.ClassDef)):
            stack.extend(ast.iter_child_nodes(n))


def _functions(tree: ast.Module):
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node


class Rule:
    name = ""
    summary = ""

    def check(self, tree: ast.Module, filename: str) -> List[Finding]:
        raise NotImplementedError


# ---------------------------------------------------------------------------
# scatter-drop
# ---------------------------------------------------------------------------

class ScatterDropRule(Rule):
    name = "scatter-drop"
    summary = ('slot/row/block-table-indexed .at[...] writes must pass '
               'mode="drop"')

    #: index identifiers that mark a scatter as slot-pool / block-table /
    #: parked-position addressing — the indices that are out of range BY
    #: DESIGN (padding rows aim at num_slots, parked positions at
    #: PARK_POS) and rely on drop semantics to write nothing
    _PAT = re.compile(r"slot|row|table|block|park|trow|wslot|wblk|woff",
                      re.IGNORECASE)
    _WRITE_METHODS = frozenset({"set", "add", "multiply", "mul", "divide",
                                "max", "min", "apply"})

    def check(self, tree, filename):
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self._WRITE_METHODS):
                continue
            sub = node.func.value
            if not (isinstance(sub, ast.Subscript)
                    and isinstance(sub.value, ast.Attribute)
                    and sub.value.attr == "at"):
                continue
            names: Set[str] = set()
            for n in ast.walk(sub.slice):
                if isinstance(n, ast.Name):
                    names.add(n.id)
                elif isinstance(n, ast.Attribute):
                    names.add(n.attr)
            hits = sorted(n for n in names if self._PAT.search(n))
            if not hits:
                continue
            mode = next((kw.value for kw in node.keywords
                         if kw.arg == "mode"), None)
            if isinstance(mode, ast.Constant) and mode.value == "drop":
                continue
            out.append(Finding(
                filename, node.lineno, node.col_offset, self.name,
                f".at[...].{node.func.attr} indexed by "
                f"{', '.join(hits)} must pass mode=\"drop\": slot/"
                "block-table indices carry out-of-range sentinels by "
                "design (padding rows, PARK_POS) and XLA's default "
                "out-of-bounds clamp would silently corrupt a real row"))
        return out


# ---------------------------------------------------------------------------
# state-thread
# ---------------------------------------------------------------------------

class StateThreadRule(Rule):
    name = "state-thread"
    summary = ('carried-state leaf .at[...] writes (conv/ssm/cross_k/'
               'cross_v) must pass mode="drop"')

    #: names that mark the write TARGET as a carried-state leaf
    #: (DESIGN.md §13): SSM/hybrid recurrent state and enc-dec cross
    #: K/V. Complements scatter-drop, which keys on the *index* name —
    #: a state scatter through an innocuously named index (``idx``)
    #: still addresses per-request rows whose padding sentinel is out
    #: of range by design, so the target name is the invariant here.
    _STATE = re.compile(r"\bconv\b|\bssm\b|cross_k|cross_v", re.IGNORECASE)
    _WRITE_METHODS = ScatterDropRule._WRITE_METHODS

    @staticmethod
    def _target_names(expr) -> Set[str]:
        """Identifiers mentioned in the expression being indexed (the X
        of ``X.at[...]``): variable names, attribute names, and string
        keys of dict-style cache access (``cache["conv"]``)."""
        names: Set[str] = set()
        for n in ast.walk(expr):
            if isinstance(n, ast.Name):
                names.add(n.id)
            elif isinstance(n, ast.Attribute):
                names.add(n.attr)
            elif isinstance(n, ast.Constant) and isinstance(n.value, str):
                names.add(n.value)
        return names

    def check(self, tree, filename):
        out: List[Finding] = []
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self._WRITE_METHODS):
                continue
            sub = node.func.value
            if not (isinstance(sub, ast.Subscript)
                    and isinstance(sub.value, ast.Attribute)
                    and sub.value.attr == "at"):
                continue
            # the expression being scattered into, minus the ".at"
            hits = sorted(n for n in self._target_names(sub.value.value)
                          if self._STATE.search(n))
            if not hits:
                continue
            # a fully-constant index is a compile-time-checked address,
            # not a per-request scatter — out of scope
            if all(isinstance(n, ast.Constant)
                   for n in ast.walk(sub.slice)
                   if isinstance(n, (ast.Name, ast.Constant))):
                continue
            mode = next((kw.value for kw in node.keywords
                         if kw.arg == "mode"), None)
            if isinstance(mode, ast.Constant) and mode.value == "drop":
                continue
            out.append(Finding(
                filename, node.lineno, node.col_offset, self.name,
                f".at[...].{node.func.attr} into carried-state leaf "
                f"({', '.join(hits)}) must pass mode=\"drop\": state "
                "rows are per-request and their padding/parked indices "
                "are out of range by design — the default out-of-bounds "
                "clamp would overwrite a live request's scan state"))
        return out


# ---------------------------------------------------------------------------
# donated-use
# ---------------------------------------------------------------------------

class DonatedUseRule(Rule):
    name = "donated-use"
    summary = ("a buffer passed through a donate_argnums jit must not be "
               "read again before rebinding")

    @staticmethod
    def _donated_positions(call: ast.Call) -> Optional[Tuple[int, ...]]:
        """The donate_argnums of a ``jax.jit(...)`` call, or None when
        the call is not a donating jit."""
        fc = _chain(call.func)
        if fc not in ("jax.jit", "jit"):
            return None
        for kw in call.keywords:
            if kw.arg != "donate_argnums":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, int):
                return (v.value,)
            if isinstance(v, (ast.Tuple, ast.List)):
                elts = [e.value for e in v.elts
                        if isinstance(e, ast.Constant)
                        and isinstance(e.value, int)]
                return tuple(elts)
        return None

    def _collect_donating(self, scope: ast.AST) -> Dict[str, Tuple[int, ...]]:
        """Map of callable chain -> donated positions for assignments
        like ``self._decode = jax.jit(fn, donate_argnums=(1, 2))``
        anywhere under ``scope`` (a module or a class body)."""
        out: Dict[str, Tuple[int, ...]] = {}
        for node in ast.walk(scope):
            if not (isinstance(node, ast.Assign)
                    and isinstance(node.value, ast.Call)):
                continue
            pos = self._donated_positions(node.value)
            if pos is None:
                continue
            for tgt in node.targets:
                c = _chain(tgt)
                if c is not None:
                    out[c] = pos
        return out

    def check(self, tree, filename):
        out: List[Finding] = []
        module_map = self._collect_donating(tree)
        class_maps: Dict[int, Dict[str, Tuple[int, ...]]] = {}
        owner_class: Dict[int, Optional[ast.ClassDef]] = {}
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                class_maps[id(node)] = self._collect_donating(node)
                for fn in node.body:
                    if isinstance(fn, (ast.FunctionDef,
                                       ast.AsyncFunctionDef)):
                        owner_class[id(fn)] = node
        for fn in _functions(tree):
            cls = owner_class.get(id(fn))
            donating = dict(module_map)
            if cls is not None:
                donating.update(class_maps[id(cls)])
            out.extend(self._check_function(fn, donating, filename))
        return out

    def _check_function(self, fn, donating, filename) -> List[Finding]:
        # events ordered by (line, phase): loads first (call args are
        # loads on the kill line and must not flag), then kills, then
        # stores/revives (the canonical `buf = self._step(buf)` rebinds
        # on the same statement)
        LOAD, KILL, STORE = 0, 1, 2
        events: List[Tuple[int, int, str, ast.AST]] = []

        for node in _walk_skipping_defs(fn):
            if isinstance(node, ast.Call):
                pos = None
                fc = _chain(node.func)
                if fc is not None and fc in donating:
                    pos = donating[fc]
                elif isinstance(node.func, ast.Call):
                    pos = DonatedUseRule._donated_positions(node.func)
                if pos:
                    end = node.end_lineno or node.lineno
                    for p in pos:
                        if p < len(node.args):
                            c = _chain(node.args[p])
                            if c is not None:
                                events.append((end, KILL, c, node))
                # a mutating method call on a prefix of a donated chain
                # (self.kv.swap_buffers(...) after donating
                # self.kv.buffers) reinstalls the buffer: revive
                if isinstance(node.func, ast.Attribute):
                    base = _chain(node.func.value)
                    if base is not None:
                        events.append((node.end_lineno or node.lineno,
                                       STORE, base + ".*", node))
            elif isinstance(node, (ast.Name, ast.Attribute)) \
                    and isinstance(getattr(node, "ctx", None), ast.Load):
                c = _chain(node)
                if c is not None:
                    events.append((node.lineno, LOAD, c, node))
            elif isinstance(node, (ast.Assign, ast.AugAssign, ast.For,
                                   ast.AnnAssign, ast.withitem)):
                targets = []
                if isinstance(node, ast.Assign):
                    targets = node.targets
                elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
                    targets = [node.target]
                elif isinstance(node, ast.For):
                    targets = [node.target]
                elif isinstance(node, ast.withitem):
                    targets = [node.optional_vars] if node.optional_vars \
                        else []
                for t in targets:
                    line = getattr(node, "end_lineno", None) \
                        or getattr(t, "end_lineno", None) or t.lineno
                    for leaf in ast.walk(t):
                        c = _chain(leaf)
                        if c is not None:
                            events.append((line, STORE, c, node))

        events.sort(key=lambda e: (e[0], e[1]))
        dead: Dict[str, Tuple[int, ast.AST]] = {}
        out: List[Finding] = []
        for line, phase, chain, node in events:
            if phase == LOAD:
                hit = dead.get(chain)
                if hit is not None and line > hit[0]:
                    out.append(Finding(
                        filename, line, node.col_offset, self.name,
                        f"`{chain}` was donated to a jit at line "
                        f"{hit[0]} and read again before rebinding: "
                        "donated buffers are deleted by XLA aliasing — "
                        "use the jit's returned value (or rebind first)"))
                    del dead[chain]
            elif phase == KILL:
                dead[chain] = (line, node)
            else:  # STORE / revive
                if chain.endswith(".*"):
                    prefix = chain[:-2] + "."
                    for k in [k for k in dead if k.startswith(prefix)]:
                        del dead[k]
                else:
                    dead.pop(chain, None)
        return out


# ---------------------------------------------------------------------------
# request-leak
# ---------------------------------------------------------------------------

_ISSUE_OPS = frozenset({
    "isend", "irecv", "icollective", "iallreduce", "ireduce", "ibcast",
    "ibarrier", "iallgather", "ireduce_scatter",
})
_COMPLETE_OPS = frozenset({"wait", "test", "synchronize"})
_COMPLETE_FNS = frozenset({"waitall", "testall"})


class RequestLeakRule(Rule):
    name = "request-leak"
    summary = ("a Request from i*-ops must reach wait/test/waitall on "
               "every path")

    # hooks the span-leak rule overrides — the AST walk is identical,
    # only the issue/completion vocabulary and the wording differ
    _issue_attrs = _ISSUE_OPS
    _ctor: Optional[str] = "Request"
    _complete_attrs = _COMPLETE_OPS
    _complete_fns = _COMPLETE_FNS
    _noun = "Request"

    def _is_issue(self, call: ast.Call) -> bool:
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in self._issue_attrs:
            return True
        if self._ctor is None:
            return False
        c = _chain(call.func)
        return c is not None and c.split(".")[-1] == self._ctor

    def _msg_discard(self) -> str:
        return ("Request discarded at the call site: the operation "
                "is never completed — bind it and wait()/waitall() "
                "(or testall in a progress loop)")

    def _msg_leak(self, name: str) -> str:
        return (f"Request bound to `{name}` is never completed: no "
                "wait()/test()/waitall() reaches it in this "
                "function and it does not escape")

    def _msg_exception(self, name: str) -> str:
        return (f"Requests bound to `{name}` are issued inside a try "
                "body and only completed there: an exception mid-issue "
                "abandons every request already in flight — move the "
                "waitall/wait into the finally block")

    def check(self, tree, filename):
        out: List[Finding] = []
        for fn in _functions(tree):
            out.extend(self._check_function(fn, filename))
        return out

    def _check_function(self, fn, filename) -> List[Finding]:
        out: List[Finding] = []
        issues: Dict[str, List[ast.Call]] = {}   # binding -> issue calls
        escaped: Set[str] = set()
        completed: Dict[str, List[ast.AST]] = {}  # binding -> completions
        aliases: Dict[str, str] = {}              # loop var -> iterated list
        synchronized = False

        def bind_of(call: ast.Call, parents: Dict[int, ast.AST]
                    ) -> Optional[str]:
            """The name an issue call's result lands in; records escapes
            and discards along the way (None = handled elsewhere)."""
            p = parents.get(id(call))
            if isinstance(p, ast.Expr):
                out.append(Finding(
                    filename, call.lineno, call.col_offset, self.name,
                    self._msg_discard()))
                return None
            if isinstance(p, ast.Assign) and len(p.targets) == 1 \
                    and isinstance(p.targets[0], ast.Name):
                return p.targets[0].id
            if isinstance(p, ast.Call) and isinstance(p.func, ast.Attribute) \
                    and p.func.attr in ("append", "add", "insert") \
                    and isinstance(p.func.value, ast.Name):
                return p.func.value.id     # reqs.append(comm.isend(...))
            # returned / stored on self / passed to a helper: assume the
            # receiver owns completion
            return "<escaped>"

        parents: Dict[int, ast.AST] = {}
        for node in _walk_skipping_defs(fn):
            for child in ast.iter_child_nodes(node):
                parents.setdefault(id(child), node)
        for child in ast.iter_child_nodes(fn):
            parents.setdefault(id(child), fn)

        for node in _walk_skipping_defs(fn):
            if isinstance(node, ast.Call) and self._is_issue(node):
                b = bind_of(node, parents)
                if b and b != "<escaped>":
                    issues.setdefault(b, []).append(node)
            elif isinstance(node, ast.For) \
                    and isinstance(node.target, ast.Name) \
                    and isinstance(node.iter, ast.Name):
                aliases[node.target.id] = node.iter.id
            elif isinstance(node, ast.Call):
                if isinstance(node.func, ast.Attribute) \
                        and node.func.attr in self._complete_attrs:
                    if node.func.attr == "synchronize":
                        synchronized = True
                    base = node.func.value
                    if isinstance(base, ast.Name):
                        name = aliases.get(base.id, base.id)
                        completed.setdefault(name, []).append(node)
                elif isinstance(node.func, ast.Name) \
                        and node.func.id in self._complete_fns:
                    for arg in node.args:
                        for n in ast.walk(arg):
                            if isinstance(n, ast.Name):
                                completed.setdefault(
                                    aliases.get(n.id, n.id), []
                                ).append(node)
            elif isinstance(node, (ast.Return, ast.Yield)) \
                    and node.value is not None:
                for n in ast.walk(node.value):
                    if isinstance(n, ast.Name):
                        escaped.add(n.id)
            elif isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if isinstance(tgt, (ast.Attribute, ast.Subscript)):
                        for n in ast.walk(node.value):
                            if isinstance(n, ast.Name):
                                escaped.add(n.id)

        # a binding passed as an argument to any other call escapes
        for node in _walk_skipping_defs(fn):
            if isinstance(node, ast.Call):
                if self._is_issue(node):
                    continue
                fc = _chain(node.func)
                is_completion = (
                    (isinstance(node.func, ast.Attribute)
                     and node.func.attr in self._complete_attrs)
                    or (fc is not None
                        and fc.split(".")[-1] in self._complete_fns))
                if is_completion:
                    continue
                for arg in node.args:
                    if isinstance(arg, ast.Name) and arg.id in issues:
                        escaped.add(arg.id)

        for name, calls in issues.items():
            if synchronized or name in escaped or name in completed:
                self._check_exception_path(
                    fn, name, calls, completed.get(name, []), filename, out)
                continue
            for call in calls:
                out.append(Finding(
                    filename, call.lineno, call.col_offset, self.name,
                    self._msg_leak(name)))
        return out

    @staticmethod
    def _span(stmts: Sequence[ast.AST]) -> Tuple[int, int]:
        return (stmts[0].lineno,
                stmts[-1].end_lineno or stmts[-1].lineno)

    def _check_exception_path(self, fn, name, calls, completions,
                              filename, out) -> None:
        """Issues inside a try body whose only completions are also in
        the try body, with a finally that never completes them, leak on
        the exception path — the transport bug class."""
        if not completions:
            return
        for node in _walk_skipping_defs(fn):
            if not (isinstance(node, ast.Try) and node.finalbody):
                continue
            lo, hi = self._span(node.body)
            flo, fhi = self._span(node.finalbody)
            inside = [c for c in calls if lo <= c.lineno <= hi]
            if not inside:
                continue
            safe = [c for c in completions
                    if not (lo <= c.lineno <= hi)]
            if safe:
                continue
            out.append(Finding(
                filename, inside[0].lineno, inside[0].col_offset,
                self.name, self._msg_exception(name)))


# ---------------------------------------------------------------------------
# span-leak
# ---------------------------------------------------------------------------

_SPAN_ISSUE_OPS = frozenset({"span", "begin_span"})
_SPAN_COMPLETE_OPS = frozenset({"end", "end_span"})


class SpanLeakRule(RequestLeakRule):
    """Same AST shape as request-leak, retargeted at the tracer's
    manual span API (DESIGN.md §15): a handle from ``tr.span(...)`` /
    ``tr.begin_span(...)`` bound to a local name must reach ``end()``
    on every path. Context-manager use (``with tr.span(...):``) and
    handles that escape (returned, stored on ``self``, passed on) are
    exception-safe or owned elsewhere and never flagged — exactly the
    request-leak escape semantics. A leaked span corrupts the tracer's
    thread-local nesting stack, mis-parenting every later span on that
    thread."""

    name = "span-leak"
    summary = ("a manually-bound tracer span must reach end() on every "
               "path (or be opened as a context manager)")

    _issue_attrs = _SPAN_ISSUE_OPS
    _ctor = None
    _complete_attrs = _SPAN_COMPLETE_OPS
    _complete_fns = frozenset()
    _noun = "Span"

    def _msg_discard(self) -> str:
        return ("Span discarded at the call site: it opens on the "
                "tracer's stack and is never ended — use "
                "`with tr.span(...):` or bind the handle and end() it")

    def _msg_leak(self, name: str) -> str:
        return (f"Span bound to `{name}` is never ended: no end() "
                "reaches it in this function and it does not escape — "
                "the tracer's nesting stack leaks")

    def _msg_exception(self, name: str) -> str:
        return (f"Spans bound to `{name}` are opened inside a try body "
                "and only ended there: an exception leaves them on the "
                "tracer's stack — move the end() into the finally "
                "block (or use `with tr.span(...):`)")


# ---------------------------------------------------------------------------
# stream-order
# ---------------------------------------------------------------------------

_BLOCKING_OPS = frozenset({
    "allreduce", "reduce", "bcast", "barrier", "allgather",
    "reduce_scatter", "alltoall", "send_recv",
})
_COMM_OPS = _BLOCKING_OPS | _ISSUE_OPS | frozenset({
    "split", "dup", "stream", "group", "run", "thread_comm",
    "process_comm", "set_attr", "get_attr",
})


class StreamOrderRule(Rule):
    name = "stream-order"
    summary = ("no blocking collective inside a stream region; no comm op "
               "after finish()/free() without start()")

    def check(self, tree, filename):
        out: List[Finding] = []
        for node in ast.walk(tree):
            if isinstance(node, ast.With):
                self._check_stream_region(node, filename, out)
        for fn in _functions(tree):
            self._check_use_after_finish(fn, filename, out)
        return out

    @staticmethod
    def _is_stream_with(node: ast.With) -> bool:
        for item in node.items:
            e = item.context_expr
            if isinstance(e, ast.Call) and isinstance(e.func, ast.Attribute) \
                    and e.func.attr == "stream":
                return True
        return False

    def _check_stream_region(self, node: ast.With, filename, out) -> None:
        if not self._is_stream_with(node):
            return
        for stmt in node.body:
            for n in _walk_skipping_defs(stmt):
                if isinstance(n, ast.Call) \
                        and isinstance(n.func, ast.Attribute) \
                        and n.func.attr in _BLOCKING_OPS:
                    out.append(Finding(
                        filename, n.lineno, n.col_offset, self.name,
                        f"blocking `{n.func.attr}` inside a CommStream "
                        "region: the stream exists to overlap — use the "
                        f"nonblocking `i{n.func.attr}` and wait() after "
                        "the region (a blocking call here also bypasses "
                        "the stream's ordering token)"))
            if isinstance(stmt, ast.Call) \
                    and isinstance(stmt.func, ast.Attribute) \
                    and stmt.func.attr in _BLOCKING_OPS:
                out.append(Finding(
                    filename, stmt.lineno, stmt.col_offset, self.name,
                    f"blocking `{stmt.func.attr}` inside a CommStream "
                    "region"))

    def _check_use_after_finish(self, fn, filename, out) -> None:
        closed: Dict[str, int] = {}    # comm chain -> line of finish/free
        sites: List[Tuple[int, str, str, ast.Call]] = []
        for node in _walk_skipping_defs(fn):
            if isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Attribute):
                base = _chain(node.func.value)
                if base is None:
                    continue
                sites.append((node.lineno, base, node.func.attr, node))
        sites.sort(key=lambda s: s[0])
        for line, base, op, node in sites:
            if op in ("finish", "free"):
                closed.setdefault(base, line)
            elif op == "start":
                closed.pop(base, None)
            elif op in _COMM_OPS and base in closed:
                out.append(Finding(
                    filename, line, node.col_offset, self.name,
                    f"`{base}.{op}` after `{base}.finish()`/`free()` at "
                    f"line {closed[base]}: the activation window is "
                    "closed and every derived object is dead — call "
                    "start() to open a new window first"))


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

class HostSyncRule(Rule):
    name = "host-sync"
    summary = ("no host-synchronizing call inside a jit'd micro-step "
               "body")

    _SYNC_ATTRS = frozenset({"item", "tolist"})
    _SYNC_CHAINS = frozenset({
        "jax.device_get", "jax.block_until_ready", "np.asarray",
        "np.array", "numpy.asarray", "numpy.array",
    })

    @staticmethod
    def _jit_region_names(tree) -> Set[str]:
        """Names of function defs passed (by name or self-attribute) as
        the first argument of a jax.jit call anywhere in the module."""
        names: Set[str] = set()
        for node in ast.walk(tree):
            if not (isinstance(node, ast.Call)
                    and _chain(node.func) in ("jax.jit", "jit")
                    and node.args):
                continue
            a0 = node.args[0]
            if isinstance(a0, ast.Name):
                names.add(a0.id)
            elif isinstance(a0, ast.Attribute):
                names.add(a0.attr)
        return names

    @classmethod
    def _is_jit_region(cls, fn, jit_names: Set[str],
                       parent_fn: Optional[ast.AST]) -> bool:
        if fn.name in jit_names:
            return True
        for dec in fn.decorator_list:
            d = dec.func if isinstance(dec, ast.Call) else dec
            if _chain(d) in ("jax.jit", "jit"):
                return True
        # the engine's factory convention: `def _x_impl*(...)` returning
        # an inner `fn` that the caller jits
        if fn.name == "fn" and parent_fn is not None \
                and parent_fn.name.startswith("_") \
                and "impl" in parent_fn.name:
            return True
        return False

    def check(self, tree, filename):
        out: List[Finding] = []
        jit_names = self._jit_region_names(tree)
        parent_fn: Dict[int, ast.AST] = {}
        for fn in _functions(tree):
            for node in ast.walk(fn):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node is not fn:
                    parent_fn.setdefault(id(node), fn)
        for fn in _functions(tree):
            if not self._is_jit_region(fn, jit_names,
                                       parent_fn.get(id(fn))):
                continue
            params = {a.arg for a in fn.args.args
                      + fn.args.posonlyargs + fn.args.kwonlyargs}
            for node in _walk_skipping_defs(fn):
                if not isinstance(node, ast.Call):
                    continue
                msg = self._sync_call(node, params)
                if msg:
                    out.append(Finding(
                        filename, node.lineno, node.col_offset,
                        self.name,
                        f"{msg} inside jit region `{fn.name}`: forces a "
                        "device->host sync in the hot loop (and fails "
                        "under trace) — keep the value on device, sync "
                        "once per micro-step outside the jit"))
        return out

    def _sync_call(self, node: ast.Call, params: Set[str]) -> Optional[str]:
        if isinstance(node.func, ast.Attribute) \
                and node.func.attr in self._SYNC_ATTRS:
            return f"`.{node.func.attr}()`"
        fc = _chain(node.func)
        if fc in self._SYNC_CHAINS:
            if fc.endswith(("asarray", "array")):
                # np shape math on static host values is legitimate at
                # trace time; only flag converting a traced parameter
                if not (node.args and isinstance(node.args[0], ast.Name)
                        and node.args[0].id in params):
                    return None
            return f"`{fc}`"
        if isinstance(node.func, ast.Name) \
                and node.func.id in ("float", "int") and node.args \
                and isinstance(node.args[0], ast.Name) \
                and node.args[0].id in params:
            return f"`{node.func.id}()` of a traced argument"
        return None


ALL_RULES: Tuple[Rule, ...] = (
    ScatterDropRule(),
    StateThreadRule(),
    DonatedUseRule(),
    RequestLeakRule(),
    SpanLeakRule(),
    StreamOrderRule(),
    HostSyncRule(),
)

RULES_BY_NAME: Dict[str, Rule] = {r.name: r for r in ALL_RULES}
