"""Runtime threadcomm sanitizer (DESIGN.md §11): happens-before tracking
over ``core/comm.py`` operations plus a lease ledger over the serving
pools. Enable with ``REPRO_SANITIZE=1`` (add ``REPRO_SANITIZE_STRICT=1``
to raise at the first finding instead of accumulating).

What it checks, mapped to the paper's pathologies:

* **Unmatched requests** — a :class:`~repro.core.comm.Request` issued
  but never completed by ``wait``/``test``/``waitall`` when its root
  threadcomm ``finish()``es (the window that invalidates it). The MPI
  analogue is an ``MPI_Isend`` whose request leaks: the transfer may
  never complete and the buffer lifetime is undefined.
* **Accidental-serialization hazards** (paper §2) — the *same* comm
  object issued the *same* kind of operation from two execution
  contexts with no happens-before edge between the issues. Collectives
  on one communicator match by issue order, so concurrent unordered
  issues either serialize behind a runtime lock or mismatch; the fix is
  a ``dup()``'d comm per context (what the serving fabric does) or an
  explicit ordering edge (``wait()`` the first before issuing the
  second).
* **Lease safety** — double free / refcount underflow on the KV block
  pool reported with allocation provenance ("allocated at X, first
  freed at Y"), and leases still live when a pool resets.
* **Migration completeness** — a ``KVBlockTransport.migrate`` that
  began but never reached its ``waitall`` completion point.

Execution contexts are ``CommStream`` objects plus one implicit "host"
context per root threadcomm; every hook is O(1) and the hooks compile
to a single ``None`` check when the sanitizer is off, so instrumented
code pays nothing in production.
"""

from __future__ import annotations

import os
import traceback
from dataclasses import dataclass
from typing import Dict, Hashable, List, Optional, Tuple

from repro.analysis.hb import VectorClock
from repro.analysis.ledger import LeaseLedger


class SanitizerError(RuntimeError):
    """Raised at the first finding when the sanitizer runs strict."""


@dataclass
class SanitizerFinding:
    kind: str          # "unmatched-request" | "serialization-hazard" |
                       # "double-free" | "lease-leak" | "migration-incomplete"
    message: str
    site: str = ""

    def __str__(self) -> str:
        loc = f" ({self.site})" if self.site else ""
        return f"[{self.kind}] {self.message}{loc}"


# frames never reported as a user-facing site: the sanitizer itself and
# the instrumented runtime modules (the interesting frame is their caller)
_INTERNAL_BASENAMES = frozenset({
    "sanitizer.py", "ledger.py", "hb.py", "comm.py", "block_pool.py",
    "transport.py",
})


def _call_site(extra_skip: Tuple[str, ...] = ()) -> str:
    skip = _INTERNAL_BASENAMES.union(extra_skip)
    for fr in reversed(traceback.extract_stack()):
        if os.path.basename(fr.filename) not in skip:
            return f"{fr.filename}:{fr.lineno}"
    return "<unknown>"


@dataclass
class _RequestRecord:
    op: str
    comm_id: int
    root_id: int
    ctx: Hashable
    ctx_name: str
    clock: VectorClock
    site: str


@dataclass
class _MigrationRecord:
    n_blocks: int
    root_id: int
    site: str


class ThreadSanitizer:
    """The collector: one instance per process, installed by
    :func:`install` (tests) or the ``REPRO_SANITIZE`` env (CI)."""

    def __init__(self, strict: bool = False):
        self.strict = bool(strict)
        self.findings: List[SanitizerFinding] = []
        self.ledger = LeaseLedger()
        self._clocks: Dict[Hashable, VectorClock] = {}
        self._pending: Dict[int, _RequestRecord] = {}     # id(req) -> record
        self._last_issue: Dict[Tuple[int, str], _RequestRecord] = {}
        self._migrations: Dict[int, _MigrationRecord] = {}

    # ------------------------------------------------------------------
    # reporting
    # ------------------------------------------------------------------
    def _emit(self, kind: str, message: str, site: str = "") -> None:
        f = SanitizerFinding(kind, message, site)
        self.findings.append(f)
        if self.strict:
            raise SanitizerError(str(f))

    def findings_of(self, kind: str) -> List[SanitizerFinding]:
        return [f for f in self.findings if f.kind == kind]

    def assert_clean(self) -> None:
        """Raise if any finding (including still-pending requests or
        migrations) is outstanding — the test-suite epilogue check."""
        leaks = list(self.findings)
        leaks += [SanitizerFinding(
            "unmatched-request",
            f"request({r.op}) never completed", r.site)
            for r in self._pending.values()]
        leaks += [SanitizerFinding(
            "migration-incomplete",
            f"migration of {m.n_blocks} blocks never completed", m.site)
            for m in self._migrations.values()]
        if leaks:
            raise SanitizerError(
                "sanitizer found:\n  " + "\n  ".join(map(str, leaks)))

    # ------------------------------------------------------------------
    # happens-before plumbing
    # ------------------------------------------------------------------
    def _clock(self, ctx: Hashable) -> VectorClock:
        c = self._clocks.get(ctx)
        if c is None:
            c = self._clocks[ctx] = VectorClock()
        return c

    @staticmethod
    def _active_ctx(root) -> Tuple[Hashable, str]:
        """The context currently executing for ``root``: the innermost
        entered stream, else the host context."""
        stack = getattr(root, "_stream_stack", None)
        if stack:
            s = stack[-1]
            return ("stream", id(s)), f"stream {s.name!r}"
        return ("host", id(root)), "host context"

    @staticmethod
    def _issue_ctx(req) -> Tuple[Hashable, str]:
        """The context a request was issued on: its bound stream when it
        has one (covers direct ``Request`` construction, e.g. the KV
        transport), else the host context of its root."""
        if req.stream is not None:
            return ("stream", id(req.stream)), f"stream {req.stream.name!r}"
        return ("host", id(req.comm._root)), "host context"

    # ------------------------------------------------------------------
    # comm hooks (called from repro.core.comm)
    # ------------------------------------------------------------------
    def on_request(self, req) -> None:
        """A nonblocking operation was issued (Request constructed)."""
        ctx, ctx_name = self._issue_ctx(req)
        clock = self._clock(ctx)
        clock.tick(ctx)
        rec = _RequestRecord(
            op=req.op, comm_id=id(req.comm), root_id=id(req.comm._root),
            ctx=ctx, ctx_name=ctx_name, clock=clock.copy(),
            site=_call_site())
        self._pending[id(req)] = rec
        key = (rec.comm_id, rec.op)
        last = self._last_issue.get(key)
        if (last is not None and last.ctx != rec.ctx
                and last.clock.concurrent_with(rec.clock)):
            self._emit(
                "serialization-hazard",
                f"{rec.op} issued on the same comm from {last.ctx_name} "
                f"(at {last.site}) and {rec.ctx_name} with no "
                "happens-before edge: operations on one communicator "
                "match by issue order, so concurrent contexts "
                "accidentally serialize (paper §2) — issue on dup()'d "
                "comms or order the contexts (wait() the first request "
                "before the second issue)",
                rec.site)
        self._last_issue[key] = rec

    def on_request_complete(self, req) -> None:
        """``wait()``/successful ``test()``: the completion merges the
        issue-time snapshot into the waiter's context (a happens-before
        edge from everything ordered before the issue)."""
        rec = self._pending.pop(id(req), None)
        if rec is None:
            return
        ctx, _ = self._active_ctx(req.comm._root)
        waiter = self._clock(ctx)
        waiter.merge(rec.clock)
        waiter.tick(ctx)

    def on_stream_enter(self, stream) -> None:
        """Entering a stream region: program order flows from the
        enclosing context into the stream (what makes issue -> wait ->
        enter-new-stream properly ordered instead of a false hazard)."""
        parent, _ = self._active_ctx(stream.comm._root)
        self._clock(("stream", id(stream))).merge(self._clock(parent))

    def on_finish(self, root) -> None:
        """``ThreadComm.finish()``: every pending request issued under
        this root is now permanently unmatched — report and drop them.
        Incomplete migrations riding this root surface here too."""
        rid = id(root)
        for key in [k for k, r in self._pending.items()
                    if r.root_id == rid]:
            rec = self._pending.pop(key)
            self._emit(
                "unmatched-request",
                f"request({rec.op}) issued on {rec.ctx_name} never "
                "reached wait()/test()/waitall() before finish() closed "
                "its activation window",
                rec.site)
        for key in [k for k, m in self._migrations.items()
                    if m.root_id == rid]:
            mig = self._migrations.pop(key)
            self._emit(
                "migration-incomplete",
                f"KV migration of {mig.n_blocks} blocks never reached "
                "its waitall completion point",
                mig.site)

    # ------------------------------------------------------------------
    # lease hooks (called from repro.serve.block_pool)
    # ------------------------------------------------------------------
    def on_lease_alloc(self, pool, resources, owner) -> None:
        site = _call_site()
        for r in resources:
            self.ledger.on_alloc(id(pool), int(r), owner, site)

    def on_lease_ref(self, pool, resource, owner=None) -> None:
        """A shared reference (prefix lease / CoW source) was added —
        the ledger keeps who and where, so a later double free on the
        shared block reports the whole chain."""
        self.ledger.on_ref(id(pool), int(resource), owner=owner,
                           site=_call_site())

    def on_lease_release(self, pool, resource) -> None:
        self.ledger.on_release(id(pool), int(resource), _call_site())

    def on_double_free(self, pool, resource, last_owner) -> str:
        """Refcount underflow / double free: emit a finding carrying the
        full provenance and return the provenance string so the pool's
        permanent ``SlotError`` can include it."""
        prov = self.ledger.provenance(id(pool), int(resource))
        self._emit(
            "double-free",
            f"double free of block {resource} (last owner "
            f"{last_owner!r}): {prov}",
            _call_site())
        return prov

    def on_pool_reset(self, pool) -> None:
        """Pool reset: leases still live are leaks — report each with
        its allocation site, then forget the pool's history."""
        for res, rec in self.ledger.live_for(id(pool)):
            self._emit(
                "lease-leak",
                f"block {res} (owner {rec.owner!r}) still leased at "
                f"reset(); allocated at {rec.alloc_site}"
                + self.ledger._shared_history(rec),
                _call_site())
        self.ledger.forget_pool(id(pool))

    # ------------------------------------------------------------------
    # migration hooks (called from repro.serve.fabric.transport)
    # ------------------------------------------------------------------
    def on_migrate_begin(self, transport, n_blocks: int) -> None:
        self._migrations[id(transport)] = _MigrationRecord(
            n_blocks=int(n_blocks),
            root_id=id(transport.comm._root),
            site=_call_site())

    def on_migrate_end(self, transport) -> None:
        self._migrations.pop(id(transport), None)


# ---------------------------------------------------------------------------
# process-wide installation
# ---------------------------------------------------------------------------

_SAN: Optional[ThreadSanitizer] = None


def active() -> Optional[ThreadSanitizer]:
    """The installed sanitizer, or None. Instrumented code guards every
    hook with this — one global read and a None check when disabled."""
    return _SAN


def install(strict: bool = False) -> ThreadSanitizer:
    """Install a fresh sanitizer (tests; idempotent over re-install)."""
    global _SAN
    _SAN = ThreadSanitizer(strict=strict)
    return _SAN


def uninstall() -> None:
    global _SAN
    _SAN = None


def _truthy(v: str) -> bool:
    return v.strip().lower() in ("1", "true", "yes", "on")


if _truthy(os.environ.get("REPRO_SANITIZE", "")):
    install(strict=_truthy(os.environ.get("REPRO_SANITIZE_STRICT", "")))
