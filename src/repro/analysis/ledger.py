"""Lease ledger for the runtime sanitizer (DESIGN.md §11): allocation
provenance for pool resources (KV blocks, request rows).

The pools themselves (``BlockPool``/``PagedKVCache``/``SlotKVCache``)
enforce correctness permanently — double free and refcount underflow
raise, ``reset()`` warns on leaked leases. The ledger adds what the
permanent checks cannot afford to keep: the *site* (file:line) where
every live lease was allocated and where a freed lease was released, so
a double free reports "allocated at X, first freed at Y" instead of just
the owner, and a leak at reset names where the leak was created.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Hashable, List, Optional, Tuple


@dataclass
class LeaseRecord:
    """One resource lease: who allocated it, where, and (after release)
    where it was last freed. Shared leases (prefix caching) also carry
    every ``ref()`` site and every shared (non-final) ``free()`` site,
    so an N-way-shared block's history reads end to end."""
    owner: object
    alloc_site: str
    free_site: Optional[str] = None
    refs: int = 1
    ref_sites: List[str] = field(default_factory=list)
    shared_free_sites: List[str] = field(default_factory=list)


@dataclass
class LeaseLedger:
    """Provenance tracking for a family of resource pools.

    Keys are ``(pool_key, resource_id)`` — the sanitizer uses
    ``id(pool)`` as the pool key, so two pools never alias. Freed
    records are retained (with their free site) until the pool resets,
    which is what makes double-free provenance possible.
    """

    _live: Dict[Tuple[Hashable, int], LeaseRecord] = field(
        default_factory=dict)
    _freed: Dict[Tuple[Hashable, int], LeaseRecord] = field(
        default_factory=dict)

    def on_alloc(self, pool: Hashable, resource: int, owner: object,
                 site: str) -> None:
        key = (pool, resource)
        self._freed.pop(key, None)
        self._live[key] = LeaseRecord(owner=owner, alloc_site=site)

    def on_ref(self, pool: Hashable, resource: int,
               owner: object = None, site: Optional[str] = None) -> None:
        """A shared reference was added (prefix lease / CoW source);
        records who took it and where."""
        rec = self._live.get((pool, resource))
        if rec is not None:
            rec.refs += 1
            if site is not None:
                rec.ref_sites.append(
                    site if owner is None else f"{site} by {owner!r}")

    def on_release(self, pool: Hashable, resource: int, site: str) -> None:
        """One reference dropped; the resource fully freed when refs hit
        zero (mirrors ``BlockPool.free`` semantics). Non-final drops of
        a shared lease keep their site for provenance."""
        key = (pool, resource)
        rec = self._live.get(key)
        if rec is None:
            return
        rec.refs -= 1
        if rec.refs <= 0:
            rec.free_site = site
            self._freed[key] = rec
            del self._live[key]
        elif rec.ref_sites:
            rec.shared_free_sites.append(site)

    @staticmethod
    def _shared_history(rec: LeaseRecord) -> str:
        if not rec.ref_sites:
            return ""
        msg = (f", shared {len(rec.ref_sites) + 1}-way "
               f"(ref'd at {', '.join(rec.ref_sites)})")
        if rec.shared_free_sites:
            msg += (", shared refs freed at "
                    + ", ".join(rec.shared_free_sites))
        return msg

    def provenance(self, pool: Hashable, resource: int) -> str:
        """Human-readable history of a resource — the double-free
        diagnostic ("allocated at X, first freed at Y"), including the
        full ref/free chain of a shared (prefix-cached / CoW) lease."""
        rec = self._freed.get((pool, resource))
        if rec is not None:
            return (f"allocated at {rec.alloc_site} by {rec.owner!r}"
                    + self._shared_history(rec)
                    + f", first freed at {rec.free_site}")
        rec = self._live.get((pool, resource))
        if rec is not None:
            return (f"still live; allocated at {rec.alloc_site} by "
                    f"{rec.owner!r}" + self._shared_history(rec))
        return "no recorded lease"

    def live_for(self, pool: Hashable) -> List[Tuple[int, LeaseRecord]]:
        """Leases still outstanding against ``pool`` — the leak set a
        ``reset()`` should find empty."""
        return sorted((res, rec) for (p, res), rec in self._live.items()
                      if p == pool)

    def forget_pool(self, pool: Hashable) -> None:
        """Drop every record for ``pool`` (called at pool reset, after
        the leak check — a fresh pool starts with a clean history)."""
        for d in (self._live, self._freed):
            for key in [k for k in d if k[0] == pool]:
                del d[key]
