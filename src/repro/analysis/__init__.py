"""Static and runtime invariant analysis (DESIGN.md §11).

Two halves:

* :mod:`repro.analysis.lint` / :mod:`repro.analysis.rules` — AST lint
  for the repo-specific invariants (drop-mode scatters, jit donation,
  Request lifecycles, stream ordering, host-sync discipline). Run
  ``python -m repro.analysis.lint src/``; CI gates on a clean tree.
* :mod:`repro.analysis.sanitizer` — the runtime threadcomm sanitizer
  (``REPRO_SANITIZE=1``): happens-before tracking over comm ops
  (:mod:`repro.analysis.hb`), lease provenance over the serving pools
  (:mod:`repro.analysis.ledger`), unmatched requests at ``finish()``,
  accidental-serialization hazards, migration completeness.

This package must stay import-light: ``core/comm.py`` and the serving
pools import :mod:`repro.analysis.sanitizer` at module load to reach
their hooks, so nothing here may import back into ``repro.core`` or
``repro.serve``.
"""

from repro.analysis.sanitizer import (SanitizerError, SanitizerFinding,
                                      ThreadSanitizer, active, install,
                                      uninstall)

__all__ = [
    "SanitizerError",
    "SanitizerFinding",
    "ThreadSanitizer",
    "active",
    "install",
    "uninstall",
]
