"""AdamW, pure JAX, mixed-precision aware.

State keeps float32 first/second moments plus a float32 master copy of the
parameters when the model runs in a lower precision (bf16) — the standard
large-model recipe. All state leaves mirror the parameter tree, so the
parameter PartitionSpecs apply verbatim (ZeRO-style sharded optimizer state
falls out of FSDP-sharded params for free).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any          # float32 master params (None leaves if fp32 model)


def adamw_init(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    needs_master = any(p.dtype != jnp.float32
                       for p in jax.tree_util.tree_leaves(params))
    master = (jax.tree_util.tree_map(lambda p: p.astype(jnp.float32), params)
              if needs_master else None)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.copy, zeros),
                      master=master)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(g.astype(jnp.float32)))
              for g in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state: AdamWState, params, *, lr, beta1=0.9,
                 beta2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.where(grad_clip > 0,
                      jnp.minimum(1.0, grad_clip / (gnorm + 1e-9)), 1.0)
    step = state.step + 1
    t = step.astype(jnp.float32)
    bc1 = 1.0 - beta1 ** t
    bc2 = 1.0 - beta2 ** t
    lr_t = jnp.asarray(lr, jnp.float32)

    def upd(g, m, v, p, master):
        g = g.astype(jnp.float32) * scale
        m = beta1 * m + (1 - beta1) * g
        v = beta2 * v + (1 - beta2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        base = master if master is not None else p.astype(jnp.float32)
        new = base - lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                             + weight_decay * base)
        return new.astype(p.dtype), m, v, new

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    flat_master = (treedef.flatten_up_to(state.master)
                   if state.master is not None else [None] * len(flat_p))
    out = [upd(g, m, v, p, ms) for g, m, v, p, ms
           in zip(flat_g, flat_m, flat_v, flat_p, flat_master)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_master = (treedef.unflatten([o[3] for o in out])
                  if state.master is not None else None)
    new_state = AdamWState(step=step, m=new_m, v=new_v, master=new_master)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}
