"""Decoder-only transformer LM: dense / MoE / SSM / hybrid blocks.

One stacked-parameter block structure per model so layers run under
``lax.scan`` (small HLO, fast compile at 80 layers). Heterogeneity across
layers (hymba global-vs-sliding-window attention) is carried as a stacked
per-layer flag consumed inside the scanned body.

Three entry points per model:
  * ``train_loss(params, batch)``    — full causal forward + chunked CE
  * ``prefill(params, batch)``       — forward + build KV/SSM cache
  * ``decode_step(params, cache, token, pos)`` — one-token serve step
"""

from __future__ import annotations

import math
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import (BLOCK_DENSE, BLOCK_HYBRID, BLOCK_MOE, BLOCK_SSM,
                          ModelConfig)
from repro.models import layers as L
from repro.models import mamba, moe


# ---------------------------------------------------------------------------
# KV-cache head layout
# ---------------------------------------------------------------------------

def kv_store_heads(cfg: ModelConfig, tp: int) -> int:
    """Number of kv heads to *store* in the cache: the smallest replication
    of the true kv heads that the model mesh axis divides (Megatron-style
    kv-head replication for TP > kv_heads). Falls back to no replication
    (cache replicated across TP) when head counts are coprime to tp."""
    if cfg.num_kv_heads == 0:
        return 0
    reps = cfg.num_heads // cfg.num_kv_heads
    for r in range(1, reps + 1):
        if reps % r == 0 and (cfg.num_kv_heads * r) % tp == 0 \
                and cfg.num_heads % (cfg.num_kv_heads * r) == 0:
            return cfg.num_kv_heads * r
    return cfg.num_kv_heads


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_block(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 8)
    p: Dict[str, Any] = {"ln1": L.init_norm(cfg, dtype)}
    if cfg.uses_attention:
        p["attn"] = L.init_attention(cfg, ks[0], dtype)
    if cfg.block == BLOCK_HYBRID:
        p["ssm"] = mamba.init_ssm(cfg, ks[1], dtype)
        # per-branch output norms before averaging (hymba)
        p["attn_out_norm"] = jnp.ones((cfg.d_model,), dtype)
        p["ssm_out_norm"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.block == BLOCK_SSM:
        p["ssm"] = mamba.init_ssm(cfg, ks[1], dtype)
    if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
        p["ln2"] = L.init_norm(cfg, dtype)
        p["mlp"] = L.init_mlp(cfg, ks[2], dtype)
    if cfg.block == BLOCK_MOE:
        p["ln2"] = L.init_norm(cfg, dtype)
        p["moe"] = moe.init_moe(cfg, ks[2], dtype)
    return p


def init_lm_params(cfg: ModelConfig, key, dtype):
    ks = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": L.embed_init(ks[0], (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }
    layer_keys = jax.random.split(ks[1], cfg.num_layers)
    params["blocks"] = jax.vmap(
        lambda k: init_block(cfg, k, dtype))(layer_keys)
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            ks[2], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
    return params


def layer_flags(cfg: ModelConfig):
    """Stacked per-layer metadata: is_global (full attention) flag."""
    flags = jnp.zeros((cfg.num_layers,), bool)
    for i in cfg.global_layers:
        flags = flags.at[i].set(True)
    return flags


# ---------------------------------------------------------------------------
# Block forward (full sequence: train / prefill)
# ---------------------------------------------------------------------------

def _attn_branch(cfg, p, xn, positions, is_global, knobs,
                 collect_cache: bool, cache_heads: int):
    """Self-attention on normed input. Returns (out, cache_or_None)."""
    p = p["attn"]
    S = xn.shape[1]
    q, k, v = L.project_qkv(p, xn, cfg, positions)
    # dynamic per-layer window: 0 disables the window clause in the mask
    if cfg.swa_window > 0:
        window = jnp.where(is_global, 0, cfg.swa_window)
    else:
        window = None
    kf = L.repeat_kv(k, cfg.num_heads)
    vf = L.repeat_kv(v, cfg.num_heads)
    # pin head-sharded attention when heads divide the model axis —
    # otherwise XLA may pick context-parallel attention whose bwd carries
    # save with UNSHARDED heads (2.15GB/layer on internvl; §Perf)
    attn_sh = knobs.get("attn_sharding")
    if attn_sh is not None:
        q = L.constrain(q, attn_sh)
        kf = L.constrain(kf, attn_sh)
        vf = L.constrain(vf, attn_sh)
    if S > knobs["attn_chunk_threshold"]:
        ctx = L.chunked_attention(
            q, kf, vf, q_pos=positions, k_pos=positions, causal=True,
            window=window, softcap=cfg.logit_softcap,
            chunk_q=knobs["attn_chunk"],
            chunk_k=knobs.get("attn_chunk_kv") or knobs["attn_chunk"])
    else:
        ctx = L.full_attention(q, kf, vf, q_pos=positions, k_pos=positions,
                               causal=True, window=window,
                               softcap=cfg.logit_softcap)
    out = L.attn_output(p, ctx, xn.dtype)
    cache = None
    if collect_cache:
        kc = L.repeat_kv(k, cache_heads)
        vc = L.repeat_kv(v, cache_heads)
        cache = {"k": kc, "v": vc}
    return out, cache


def block_forward(cfg, p, x, positions, is_global, knobs, *,
                  collect_cache=False, cache_heads=0, collect_state=False,
                  dropless_moe=False):
    """One block, full-sequence. Returns (x, aux, cache).

    ``dropless_moe`` selects the serve-time per-token routing
    (:func:`moe.moe_apply_dropless`) — parity-safe under any chunking —
    over training's capacity-bounded grouped routing."""
    aux: Dict[str, Any] = {}
    cache: Dict[str, Any] = {}
    xn = L.apply_norm(x, p["ln1"], cfg)

    if cfg.block == BLOCK_SSM:
        if collect_state:
            out, st = mamba.ssm_apply(p["ssm"], xn, cfg, return_state=True)
            cache.update(st)
        else:
            out = mamba.ssm_apply(p["ssm"], xn, cfg)
        x = x + out
    elif cfg.block == BLOCK_HYBRID:
        a_out, a_cache = _attn_branch(cfg, p, xn, positions, is_global, knobs,
                                      collect_cache, cache_heads)
        if collect_state:
            s_out, st = mamba.ssm_apply(p["ssm"], xn, cfg, return_state=True)
            cache.update(st)
        else:
            s_out = mamba.ssm_apply(p["ssm"], xn, cfg)
        a_out = L.rmsnorm(a_out, p["attn_out_norm"], eps=cfg.norm_eps)
        s_out = L.rmsnorm(s_out, p["ssm_out_norm"], eps=cfg.norm_eps)
        x = x + 0.5 * (a_out + s_out)
        if a_cache:
            cache.update(a_cache)
    else:  # dense / moe attention sublayer
        a_out, a_cache = _attn_branch(cfg, p, xn, positions, is_global, knobs,
                                      collect_cache, cache_heads)
        x = x + a_out
        if a_cache:
            cache.update(a_cache)

    if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
        x = x + L.mlp_apply(p["mlp"], L.apply_norm(x, p["ln2"], cfg), cfg)
    elif cfg.block == BLOCK_MOE:
        moe_fn = moe.moe_apply_dropless if dropless_moe else moe.moe_apply
        m_out, m_aux = moe_fn(p["moe"], L.apply_norm(x, p["ln2"], cfg), cfg)
        x = x + m_out
        aux.update(m_aux)
    return x, aux, cache


# ---------------------------------------------------------------------------
# Backbone
# ---------------------------------------------------------------------------

def embed_tokens(cfg, params, tokens, compute_dtype):
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def backbone(cfg, params, x, positions, knobs, *, collect_cache=False,
             cache_heads=0, collect_state=False, remat=True,
             dropless_moe=False):
    """Scan blocks over stacked params. x (B,S,d) -> (hidden, aux, caches)."""
    flags = layer_flags(cfg)

    def body(h, xs):
        p_l, flag = xs
        h = L.constrain(h, knobs.get("act_sharding"))
        h, aux, cache = block_forward(
            cfg, p_l, h, positions, flag, knobs,
            collect_cache=collect_cache, cache_heads=cache_heads,
            collect_state=collect_state, dropless_moe=dropless_moe)
        h = L.constrain(h, knobs.get("act_sharding"))
        return h, (aux, cache)

    if remat:
        body = jax.checkpoint(body)
    x, (auxs, caches) = lax.scan(body, x, (params["blocks"], flags))
    aux = {k: jnp.mean(v) for k, v in auxs.items()}
    x = L.apply_norm(x, params["final_norm"], cfg)
    return x, aux, caches


def lm_head_weight(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _layer_slice(tree, idx):
    """One layer's slice of a stacked (L, ...) cache pytree — shared by
    every cache-carrying scan (decode, chunked prefill, paged paths)."""
    return jax.tree_util.tree_map(
        lambda c: lax.dynamic_index_in_dim(c, idx, 0, keepdims=False), tree)


def _layer_put(tree, new, idx):
    """Write one layer's updated entries back into the stacked cache
    (in-place under XLA's while-loop aliasing; see make_decode_step)."""
    return jax.tree_util.tree_map(
        lambda c, n: lax.dynamic_update_index_in_dim(
            c, n.astype(c.dtype), idx, 0), tree, new)


# ---------------------------------------------------------------------------
# Public entry points (decoder-only; enc-dec lives in encdec.py)
# ---------------------------------------------------------------------------

def make_train_loss(cfg: ModelConfig, knobs):
    compute_dtype = L.dtype_of(knobs["compute_dtype"])

    def train_loss(params, batch):
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens, compute_dtype)
        positions = jnp.arange(x.shape[1])
        if cfg.frontend == "patch_stub":
            # prepend precomputed patch embeddings (frontend stub)
            pe = batch["patch_embeds"].astype(compute_dtype)
            x = jnp.concatenate([pe, x], axis=1)
            positions = jnp.arange(x.shape[1])
        hidden, aux, _ = backbone(cfg, params, x, positions, knobs,
                                  remat=knobs["remat"])
        labels = batch["labels"]
        if cfg.frontend == "patch_stub":
            # keep the full (nicely sharded) sequence; mask the patch
            # positions in the loss instead of slicing hidden — slicing
            # makes the text length ragged vs the SP shards / CE chunks and
            # XLA replicates the whole stream (+12GB on internvl, §Perf)
            F = batch["patch_embeds"].shape[1]
            pad = jnp.full((labels.shape[0], F), -1, labels.dtype)
            labels = jnp.concatenate([pad, labels], axis=1)
        valid = labels >= 0
        loss_sum, n_valid = L.chunked_cross_entropy(
            hidden, lm_head_weight(cfg, params).astype(compute_dtype),
            jnp.maximum(labels, 0), valid=valid, vocab_size=cfg.vocab_size,
            chunk=knobs["loss_chunk"])
        loss = loss_sum / jnp.maximum(n_valid, 1.0)
        if "moe_lb_loss" in aux:
            loss = loss + 0.01 * aux["moe_lb_loss"] + 1e-3 * aux["moe_z_loss"]
        metrics = {"loss": loss, **aux}
        return loss, metrics

    return train_loss


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, tp: int,
               compute_dtype):
    """Stacked (L, ...) cache pytree. ``cache_len`` already reflects
    ring-buffer windowing when enabled."""
    Lc = cfg.num_layers
    c: Dict[str, Any] = {}
    if cfg.uses_attention:
        gs = kv_store_heads(cfg, tp)
        c["k"] = jnp.zeros((Lc, batch, cache_len, gs, cfg.head_dim),
                           compute_dtype)
        c["v"] = jnp.zeros((Lc, batch, cache_len, gs, cfg.head_dim),
                           compute_dtype)
        c["pos"] = jnp.full((Lc, cache_len), -1, jnp.int32)
    if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
        di, n = cfg.ssm_d_inner, cfg.ssm_state
        c["conv"] = jnp.zeros((Lc, batch, cfg.ssm_conv - 1, di + 2 * n),
                              compute_dtype)
        c["ssm"] = jnp.zeros((Lc, batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                             jnp.float32)
    return c


def make_prefill(cfg: ModelConfig, knobs, tp: int):
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    cache_heads = kv_store_heads(cfg, tp)

    def prefill(params, batch, cache_len: int):
        """Run the prompt, return (last-position logits, cache)."""
        tokens = batch["tokens"]
        x = embed_tokens(cfg, params, tokens, compute_dtype)
        positions = jnp.arange(x.shape[1])
        if cfg.frontend == "patch_stub":
            pe = batch["patch_embeds"].astype(compute_dtype)
            x = jnp.concatenate([pe, x], axis=1)
            positions = jnp.arange(x.shape[1])
        S = x.shape[1]
        hidden, _, caches = backbone(
            cfg, params, x, positions, knobs, collect_cache=True,
            cache_heads=cache_heads, collect_state=True,
            remat=knobs["remat"], dropless_moe=True)
        # place collected kv into fixed-capacity cache buffers
        B = x.shape[0]
        cache = init_cache(cfg, B, cache_len, tp, compute_dtype)
        if cfg.uses_attention:
            W = cache_len
            if S <= W:
                cache["k"] = lax.dynamic_update_slice_in_dim(
                    cache["k"], caches["k"].astype(compute_dtype), 0, axis=2)
                cache["v"] = lax.dynamic_update_slice_in_dim(
                    cache["v"], caches["v"].astype(compute_dtype), 0, axis=2)
                pos_row = jnp.where(jnp.arange(W) < S, jnp.arange(W), -1)
            else:  # ring buffer: keep last W entries at rotated slots
                keep_k = caches["k"][:, :, S - W:]
                keep_v = caches["v"][:, :, S - W:]
                abs_pos = jnp.arange(S - W, S)
                slots = abs_pos % W
                order = jnp.argsort(slots)
                cache["k"] = keep_k[:, :, order].astype(compute_dtype)
                cache["v"] = keep_v[:, :, order].astype(compute_dtype)
                pos_row = abs_pos[order]
            cache["pos"] = jnp.broadcast_to(pos_row,
                                            (cfg.num_layers, cache_len))
        if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
            cache["conv"] = caches["conv"].astype(compute_dtype)
            cache["ssm"] = caches["ssm"]
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = (hidden[:, -1, :] @ w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), cache

    return prefill


def _masked_group_attention(cfg, p, q, keys, values, okay, out_dtype):
    """Shared grouped-attention core of the slot (ring) and paged
    (block-table) cached-attention paths: grouped scores, softcap,
    additive NEG_INF mask, softmax, context, output projection. The two
    paths differ only in how keys/values/mask are produced — the math
    here MUST stay one copy or a softcap/masking fix could silently
    diverge them and break the token-parity guarantee CI asserts.

    q (B,C,H,hd); keys/values (B,T,Gs,hd); okay broadcastable to
    (B,C,T).
    """
    B, C = q.shape[0], q.shape[1]
    gs = keys.shape[2]
    R = cfg.num_heads // gs
    qg = q.reshape(B, C, gs, R, cfg.head_dim)
    s = jnp.einsum("bqgrk,btgk->bgrqt", qg, keys).astype(jnp.float32)
    s = s / math.sqrt(cfg.head_dim)
    if cfg.logit_softcap > 0:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
    s = s + jnp.where(okay, 0.0, L.NEG_INF)[:, None, None, :, :]
    prob = jax.nn.softmax(s, axis=-1).astype(out_dtype)
    ctx = jnp.einsum("bgrqt,btgk->bqgrk", prob, values)
    ctx = ctx.reshape(B, C, cfg.num_heads, cfg.head_dim)
    return L.attn_output(p, ctx, out_dtype)


def _cached_attn(cfg, p, xn, layer_cache, qpos, wslot, is_global):
    """Attention for query tokens against (and into) the cache — the
    shared core of single-token decode and chunked prefill.

    xn (B,C,d); layer_cache k/v (B,W,Gs,hd), pos (W,); ``qpos`` (C,) the
    queries' absolute positions, ``wslot`` (C,) the cache slot each query
    writes its k/v/pos to. The writes are drop-mode scatters: aiming a
    query at the out-of-range slot ``W`` (parked decode rows, chunk
    padding) writes *nothing*, which is what lets a continuous-batching
    engine run the decode vmap over its whole slot pool while some slots
    are free or still mid-chunked-prefill. Queries then attend over the
    whole updated cache, causally masked on the stored absolute
    positions — earlier chunks of the same prompt are just cache entries.
    """
    gs = layer_cache["k"].shape[2]
    q, k, v = L.project_qkv(p, xn, cfg, qpos)
    kc = L.repeat_kv(k, gs)
    vc = L.repeat_kv(v, gs)
    new_k = layer_cache["k"].at[:, wslot].set(kc, mode="drop")
    new_v = layer_cache["v"].at[:, wslot].set(vc, mode="drop")
    new_pos = layer_cache["pos"].at[wslot].set(
        qpos.astype(jnp.int32), mode="drop")

    kpos = new_pos  # (W,)
    okay = (kpos[None, :] >= 0) & (kpos[None, :] <= qpos[:, None])  # (C, W)
    if cfg.swa_window > 0:
        win_ok = kpos[None, :] > qpos[:, None] - cfg.swa_window
        okay = okay & jnp.where(is_global, True, win_ok)
    out = _masked_group_attention(cfg, p, q, new_k, new_v, okay[None],
                                  xn.dtype)
    return out, {"k": new_k, "v": new_v, "pos": new_pos}


def _decode_attn(cfg, p, xn, layer_cache, pos, is_global, tp):
    """One-token attention against the cache: the C=1 case of
    :func:`_cached_attn`. A negative (parked) ``pos`` writes nothing."""
    qpos = jnp.full((1,), pos)
    wslot = jnp.where(qpos >= 0, qpos % layer_cache["k"].shape[1],
                      layer_cache["k"].shape[1])
    return _cached_attn(cfg, p, xn, layer_cache, qpos, wslot, is_global)


def make_decode_step(cfg: ModelConfig, knobs, tp: int):
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    flags = layer_flags(cfg)

    def decode_step(params, cache, token, pos):
        """token (B,1) int32, pos scalar int32 -> (logits (B,Vp), cache).

        The cache rides in the scan CARRY and is updated in place per layer
        (dynamic_update_index on the stacked buffers): XLA's while-loop
        in-place analysis then aliases it end-to-end with the donated input
        — passing it as scan xs/ys instead costs 2 extra full-cache copies
        (observed +52GB on qwen3 decode_32k; EXPERIMENTS.md §Perf).
        """
        x = embed_tokens(cfg, params, token, compute_dtype)

        def body(carry, xs):
            h, cch = carry
            p_l, flag, idx = xs
            cache_l = _layer_slice(cch, idx)
            new_cache: Dict[str, Any] = {}
            xn = L.apply_norm(h, p_l["ln1"], cfg)

            def ssm_guarded(p_l, cache_l):
                # parked slots (pos < 0) must keep their carried state: a
                # slot mid-chunked-prefill is parked between chunk
                # deposits while live slots decode, and an unguarded
                # update would overwrite the partially-deposited scan
                # state with a garbage-token step (attention is naturally
                # guarded — its parked write slot drops out of range)
                state = {"conv": cache_l["conv"], "ssm": cache_l["ssm"]}
                out, st = mamba.ssm_decode_step(p_l["ssm"], xn, state, cfg)
                st = {k: jnp.where(pos >= 0, v.astype(state[k].dtype),
                                   state[k])
                      for k, v in st.items()}
                return out, st

            if cfg.block == BLOCK_SSM:
                out, st = ssm_guarded(p_l, cache_l)
                h = h + out
                new_cache.update(st)
            elif cfg.block == BLOCK_HYBRID:
                a_out, a_cache = _decode_attn(cfg, p_l["attn"], xn, cache_l,
                                              pos, flag, tp)
                s_out, st = ssm_guarded(p_l, cache_l)
                a_out = L.rmsnorm(a_out, p_l["attn_out_norm"], eps=cfg.norm_eps)
                s_out = L.rmsnorm(s_out, p_l["ssm_out_norm"], eps=cfg.norm_eps)
                h = h + 0.5 * (a_out + s_out)
                new_cache.update(a_cache)
                new_cache.update(st)
            else:
                a_out, a_cache = _decode_attn(cfg, p_l["attn"], xn, cache_l,
                                              pos, flag, tp)
                h = h + a_out
                new_cache.update(a_cache)
            if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
                h = h + L.mlp_apply(p_l["mlp"],
                                    L.apply_norm(h, p_l["ln2"], cfg), cfg)
            elif cfg.block == BLOCK_MOE:
                m_out, _ = moe.moe_apply_dropless(
                    p_l["moe"], L.apply_norm(h, p_l["ln2"], cfg), cfg)
                h = h + m_out
            return (h, _layer_put(cch, new_cache, idx)), None

        (x, new_cache), _ = lax.scan(
            body, (x, cache),
            (params["blocks"], flags, jnp.arange(cfg.num_layers)))
        x = L.apply_norm(x, params["final_norm"], cfg)
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = (x[:, 0, :] @ w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return decode_step


# ---------------------------------------------------------------------------
# Chunked prefill (fixed-shape prompt deposit for continuous serving)
# ---------------------------------------------------------------------------

# ---------------------------------------------------------------------------
# Paged KV: block-table cache (DESIGN.md §9)
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     tp: int, compute_dtype, num_rows: int = 0):
    """Global KV block pool + per-row carried state.

    k/v are (L, P, bs, Gs, hd): one block table entry maps a request's
    token range [i*bs, (i+1)*bs) onto a pool block shared across all
    layers, so positions are structural — no per-token position array is
    stored (the slot cache needs one for its ring addressing; the paged
    cache does not). Recurrent carried state (SSM conv/ssm leaves) is NOT
    block-addressable — it is one fixed-size pytree per *request row* —
    so those leaves are (L, num_rows, ...), row-aligned with the engine's
    request rows and threaded through the chunk/decode steps explicitly
    (DESIGN.md §13)."""
    if cfg.frontend == "patch_stub":
        raise ValueError("paged KV does not support the patch_stub "
                         "modality frontend (prepended frontend tokens "
                         "have no block-table deposit path)")
    c: Dict[str, Any] = {}
    if cfg.uses_attention:
        gs = kv_store_heads(cfg, tp)
        shape = (cfg.num_layers, num_blocks, block_size, gs, cfg.head_dim)
        c["k"] = jnp.zeros(shape, compute_dtype)
        c["v"] = jnp.zeros(shape, compute_dtype)
    if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
        Lc, di, n = cfg.num_layers, cfg.ssm_d_inner, cfg.ssm_state
        c["conv"] = jnp.zeros((Lc, num_rows, cfg.ssm_conv - 1, di + 2 * n),
                              compute_dtype)
        c["ssm"] = jnp.zeros((Lc, num_rows, cfg.ssm_heads, cfg.ssm_head_dim,
                              n), jnp.float32)
    return c


def _paged_attn(cfg, p, xn, layer_cache, tables, qpos, wvalid, is_global):
    """Attention for query tokens against (and into) the paged pool — the
    block-table analogue of :func:`_cached_attn`, batched across requests.

    xn (B,C,d); layer_cache k/v (P,bs,Gs,hd) — ONE pool shared by every
    request; tables (B,NB) int32 block tables (-1 = absent entry); qpos
    (B,C) absolute query positions (per row — requests decode at
    different depths); wvalid (B,C) marks queries allowed to write their
    k/v (chunk padding and parked rows are not).

    Writes scatter each query's k/v into block ``tables[b, qpos//bs]`` at
    offset ``qpos % bs`` — parked/padded queries aim at the out-of-range
    block index ``P`` and the explicit ``mode="drop"`` discards them
    (default scatter semantics would wraparound-corrupt a live block).
    Queries then attend over their own gathered pages, causally masked on
    the *structural* positions (table entry i holds tokens [i*bs,
    (i+1)*bs)) — stale pages of a block's previous owner are never at a
    position <= qpos of the new owner, so block recycling needs no
    blanking dispatch.
    """
    B = xn.shape[0]
    P, bs, gs, hd = layer_cache["k"].shape
    NB = tables.shape[1]
    q, k, v = L.project_qkv(p, xn, cfg, qpos)        # per-row rope positions
    kc = L.repeat_kv(k, gs)
    vc = L.repeat_kv(v, gs)
    blk = jnp.take_along_axis(tables, jnp.clip(qpos // bs, 0, NB - 1), axis=1)
    wblk = jnp.where(wvalid & (blk >= 0), blk, P)    # P = drop block
    woff = jnp.where(wvalid, qpos % bs, 0)
    new_k = layer_cache["k"].at[wblk, woff].set(kc, mode="drop")
    new_v = layer_cache["v"].at[wblk, woff].set(vc, mode="drop")

    # gather this batch's pages: (B, NB*bs, Gs, hd), token t at index t
    flat = jnp.maximum(tables, 0).reshape(-1)
    kg = jnp.take(new_k, flat, axis=0).reshape(B, NB * bs, gs, hd)
    vg = jnp.take(new_v, flat, axis=0).reshape(B, NB * bs, gs, hd)

    kpos = jnp.arange(NB * bs)                        # structural positions
    okay = (kpos[None, None, :] <= qpos[:, :, None]) \
        & jnp.repeat(tables >= 0, bs, axis=1)[:, None, :]
    if cfg.swa_window > 0:
        win_ok = kpos[None, None, :] > qpos[:, :, None] - cfg.swa_window
        okay = okay & jnp.where(is_global, True, win_ok)
    out = _masked_group_attention(cfg, p, q, kg, vg, okay, xn.dtype)
    return out, {"k": new_k, "v": new_v}


def _paged_backbone(cfg, params, x, tables, qpos, wvalid, cache, flags, *,
                    mode="decode", rows=None, pos0=None, n_valid=None):
    """Scan the blocks over the paged pool (cache rides the scan carry
    exactly like :func:`make_decode_step` — XLA aliases the donated pool
    end-to-end). KV goes through block tables; carried state (conv/ssm)
    is row-aligned: ``mode="decode"`` updates it full-width in place
    (parked rows keep their state via a ``where`` select), ``mode="chunk"``
    gathers the prefilling subset at ``rows`` and scatters the advanced
    state back with a drop-mode write (padding rows aim at the
    out-of-range row)."""
    B = x.shape[0]

    def ssm_step(p_l, cache_l, xn):
        state = {"conv": cache_l["conv"], "ssm": cache_l["ssm"]}
        if mode == "decode":
            out, st = mamba.ssm_decode_step(p_l["ssm"], xn, state, cfg)
            live = qpos[:, 0] >= 0
            st = {k: jnp.where(live.reshape((B,) + (1,) * (v.ndim - 1)),
                               v, state[k].astype(v.dtype)).astype(
                                   state[k].dtype)
                  for k, v in st.items()}
            return out, st
        # chunk: gather the carried state of the prefilling rows (clip:
        # padding rows read row 0 and their writes drop), zero it at the
        # first chunk of a prompt (a ``where`` select, not a multiply, so
        # a stale row's garbage can never leak into a fresh prompt)
        gathered = {k: jnp.take(v, rows, axis=0, mode="clip")
                    for k, v in state.items()}
        fresh = pos0 == 0
        gathered = {k: jnp.where(
            fresh.reshape((rows.shape[0],) + (1,) * (v.ndim - 1)),
            jnp.zeros_like(v), v) for k, v in gathered.items()}
        out, st = mamba.ssm_apply_chunk(p_l["ssm"], xn, cfg, gathered,
                                        n_valid)
        st = {k: state[k].at[rows].set(v.astype(state[k].dtype),
                                       mode="drop")
              for k, v in st.items()}
        return out, st

    def body(carry, xs):
        h, cch = carry
        p_l, flag, idx = xs
        cache_l = _layer_slice(cch, idx)
        new_cache: Dict[str, Any] = {}
        xn = L.apply_norm(h, p_l["ln1"], cfg)
        if cfg.block == BLOCK_SSM:
            out, st = ssm_step(p_l, cache_l, xn)
            h = h + out
            new_cache.update(st)
        elif cfg.block == BLOCK_HYBRID:
            a_out, a_cache = _paged_attn(cfg, p_l["attn"], xn, cache_l,
                                         tables, qpos, wvalid, flag)
            s_out, st = ssm_step(p_l, cache_l, xn)
            a_out = L.rmsnorm(a_out, p_l["attn_out_norm"], eps=cfg.norm_eps)
            s_out = L.rmsnorm(s_out, p_l["ssm_out_norm"], eps=cfg.norm_eps)
            h = h + 0.5 * (a_out + s_out)
            new_cache.update(a_cache)
            new_cache.update(st)
        else:
            a_out, a_cache = _paged_attn(cfg, p_l["attn"], xn, cache_l,
                                         tables, qpos, wvalid, flag)
            h = h + a_out
            new_cache.update(a_cache)
        if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
            h = h + L.mlp_apply(p_l["mlp"],
                                L.apply_norm(h, p_l["ln2"], cfg), cfg)
        elif cfg.block == BLOCK_MOE:
            m_out, _ = moe.moe_apply_dropless(
                p_l["moe"], L.apply_norm(h, p_l["ln2"], cfg), cfg)
            h = h + m_out
        return (h, _layer_put(cch, new_cache, idx)), None

    (x, new_cache), _ = lax.scan(
        body, (x, cache),
        (params["blocks"], flags, jnp.arange(cfg.num_layers)))
    return L.apply_norm(x, params["final_norm"], cfg), new_cache


def make_decode_step_paged(cfg: ModelConfig, knobs, tp: int):
    """Batched one-token decode through per-request block tables: the
    whole request-row batch advances in one call (no outer vmap — the
    pool is one shared buffer, so rows are batched natively with per-row
    positions). A negative (parked) position writes nothing and yields a
    garbage row the engine discards."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    flags = layer_flags(cfg)

    def decode_step(params, cache, tokens, positions, block_tables):
        """tokens (B,1) int32, positions (B,) int32, block_tables (B,NB)
        int32 -> (logits (B,Vp), cache)."""
        x = embed_tokens(cfg, params, tokens, compute_dtype)
        qpos = positions[:, None]                     # (B, 1)
        wvalid = (positions >= 0)[:, None]
        x, new_cache = _paged_backbone(cfg, params, x, block_tables, qpos,
                                       wvalid, cache, flags)
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = (x[:, 0, :] @ w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return decode_step


def make_verify_step_paged(cfg: ModelConfig, knobs, tp: int):
    """K-token teacher-forced decode through block tables (speculative
    verify, DESIGN.md §14): feed the q-block [current token, draft_1 ..
    draft_{K-1}] in ONE dispatch, write the K KV rows with the same
    drop-mode scatters as chunked prefill, and return full-width logits —
    ``logits[:, j]`` is the target's next-token distribution after
    consuming tokens ``.. j``, which is exactly what the acceptance rule
    compares the drafts against. Rollback after a rejection is purely
    structural: the engine advances the row's length by the accepted
    count only, and the stale draft rows beyond it are out-causal-range
    (``kpos <= qpos``) until the next dispatch overwrites them — no
    blanking dispatch exists.

    Dense/MoE families only: recurrent carried state (SSM/hybrid conv +
    scan state) advances through *rejected* tokens and cannot be rolled
    back by a length decrement, so the registry gates this path off for
    carried-state families (``Capabilities.speculative``)."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    flags = layer_flags(cfg)

    def verify_step(params, cache, tokens, positions, block_tables,
                    n_valid):
        """tokens (B,K) int32 — token j of row b at absolute position
        ``positions[b] + j``; positions (B,) int32 (negative = parked
        row, writes nothing); block_tables (B,NB); n_valid (B,) live
        queries per row (<= K; trailing queries are padding) ->
        (logits (B,K,Vp), cache)."""
        B, K = tokens.shape
        x = embed_tokens(cfg, params, tokens, compute_dtype)
        qpos = positions[:, None] + jnp.arange(K)[None, :]
        wvalid = ((jnp.arange(K)[None, :] < n_valid[:, None])
                  & (positions >= 0)[:, None])
        x, new_cache = _paged_backbone(cfg, params, x, block_tables, qpos,
                                       wvalid, cache, flags)
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = jnp.einsum("bkd,dv->bkv", x, w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok[None, None], logits, L.NEG_INF), new_cache

    return verify_step


def make_prefill_chunk_paged(cfg: ModelConfig, knobs, tp: int):
    """Fixed-shape chunked prompt deposit through block tables: up to B
    chunk-rows from different requests write straight into the shared
    pool (no gather/scatter of slot rows — the block table IS the
    indirection). Padding rows carry an all ``-1`` table and
    ``n_valid == 0``: every write drops, and their logits are garbage the
    engine aims at its drop row. ``rows`` carries each chunk-row's engine
    request-row index so recurrent carried state (SSM/hybrid) resumes
    from — and advances — the right (L, num_rows, ...) state row; padding
    rows aim at the out-of-range row index and their state writes drop
    (DESIGN.md §13)."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    flags = layer_flags(cfg)

    def prefill_chunk(params, cache, tokens, block_tables, rows, pos0,
                      n_valid):
        """tokens (B,C) int32; block_tables (B,NB); rows, pos0, n_valid
        (B,) -> (last-valid-position logits (B,Vp), cache)."""
        B, C = tokens.shape
        x = embed_tokens(cfg, params, tokens, compute_dtype)
        qpos = pos0[:, None] + jnp.arange(C)[None, :]
        wvalid = jnp.arange(C)[None, :] < n_valid[:, None]
        x, new_cache = _paged_backbone(cfg, params, x, block_tables, qpos,
                                       wvalid, cache, flags, mode="chunk",
                                       rows=rows, pos0=pos0, n_valid=n_valid)
        last = jnp.clip(n_valid - 1, 0, C - 1)
        hidden = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = (hidden @ w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return prefill_chunk


def make_clone_block(cfg: ModelConfig, knobs, tp: int):
    """Device-side copy-on-write clone of one pool block (prefix caching,
    DESIGN.md §12): duplicate block ``src``'s pages — every layer, k and
    v — into block ``dst``, leaving the rest of the pool untouched.

    The prefix cache leases a *partially* matching cached block to the
    admitting request; the request's chunked prefill then resumes at a
    nonzero offset inside the cloned block and overwrites only the
    divergent tail positions (``prefill_chunk_paged`` already takes
    per-row ``pos0``, so resuming mid-block needs no model change — the
    clone is the one new device op the CoW path requires). The shared
    source block is never written.
    """
    del cfg, knobs, tp      # the pool layout is shape-polymorphic here

    def clone_block(cache, src, dst):
        """cache k/v (L, P, bs, Gs, hd); src/dst scalar int32 -> cache."""
        # block-indexed scatter: like every pool write, an out-of-range
        # destination drops instead of clamping onto a live block
        return {"k": cache["k"].at[:, dst].set(cache["k"][:, src],
                                               mode="drop"),
                "v": cache["v"].at[:, dst].set(cache["v"][:, src],
                                               mode="drop")}

    return clone_block


def _chunk_attn(cfg, p, xn, layer_cache, qpos, valid, is_global):
    """Attention for a prompt chunk against (and into) the cache:
    :func:`_cached_attn` with invalid (padding) positions aimed at the
    drop slot ``W`` — they write no cache pages and, never becoming valid
    cache entries, draw no attention weight from valid queries."""
    W = layer_cache["k"].shape[1]
    wslot = jnp.where(valid, qpos % W, W)
    return _cached_attn(cfg, p, xn, layer_cache, qpos, wslot, is_global)


def make_prefill_chunk(cfg: ModelConfig, knobs, tp: int):
    """Fixed-shape incremental prefill: deposit ``C`` prompt tokens into a
    per-request cache starting at position ``pos0``.

    Unlike :func:`make_prefill` (whose jit shape — and therefore XLA
    compile — depends on the prompt length), this step is always traced at
    the chunk shape, so serving compiles O(1) programs however many
    distinct prompt lengths the traffic carries. The last (partial) chunk
    is padded to ``C`` and masked via ``n_valid``: padding positions never
    write cache entries and never receive attention weight from valid
    queries. Returns the logits at the last *valid* position (only
    meaningful on the final chunk of a prompt) plus the updated cache.

    Supported for every decoder-only block family without a modality
    frontend. Dense attention deposits KV; SSM/hybrid thread their
    recurrent carried state (conv window + SSM state, living in the same
    per-request cache pytree) through :func:`mamba.ssm_apply_chunk`, so a
    prompt split at any ``cfg.ssm_chunk`` multiple resumes the scan
    bit-exactly; MoE routes per-token (:func:`moe.moe_apply_dropless`) so
    chunk boundaries cannot change routing (DESIGN.md §13). Only the
    patch_stub modality frontend stays monolithic — its prepended
    frontend tokens have no chunk deposit path (the registry exposes
    ``prefill_chunk=None`` and the capability flags name the reason).
    """
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    flags = layer_flags(cfg)

    def prefill_chunk(params, cache, tokens, pos0, n_valid):
        """tokens (C,) int32, pos0/n_valid scalar int32, cache a
        per-request (batch=1) pytree -> (logits (Vp,), cache)."""
        C = tokens.shape[0]
        x = embed_tokens(cfg, params, tokens[None], compute_dtype)  # (1,C,d)
        qpos = pos0 + jnp.arange(C)
        valid = jnp.arange(C) < n_valid

        def ssm_chunk(p_l, cache_l, xn):
            # carried state rides the per-request cache; a first chunk
            # (pos0 == 0) starts from zeros via a select, so a recycled
            # slot's stale state can never leak into a fresh prompt
            state = {"conv": cache_l["conv"], "ssm": cache_l["ssm"]}
            state = {k: jnp.where(pos0 == 0, jnp.zeros_like(v), v)
                     for k, v in state.items()}
            out, st = mamba.ssm_apply_chunk(
                p_l["ssm"], xn, cfg, state, jnp.asarray(n_valid).reshape(1))
            return out, st

        def body(carry, xs):
            h, cch = carry
            p_l, flag, idx = xs
            cache_l = _layer_slice(cch, idx)
            new_cache: Dict[str, Any] = {}
            xn = L.apply_norm(h, p_l["ln1"], cfg)
            if cfg.block == BLOCK_SSM:
                out, st = ssm_chunk(p_l, cache_l, xn)
                h = h + out
                new_cache.update(st)
            elif cfg.block == BLOCK_HYBRID:
                a_out, a_cache = _chunk_attn(cfg, p_l["attn"], xn, cache_l,
                                             qpos, valid, flag)
                s_out, st = ssm_chunk(p_l, cache_l, xn)
                a_out = L.rmsnorm(a_out, p_l["attn_out_norm"],
                                  eps=cfg.norm_eps)
                s_out = L.rmsnorm(s_out, p_l["ssm_out_norm"],
                                  eps=cfg.norm_eps)
                h = h + 0.5 * (a_out + s_out)
                new_cache.update(a_cache)
                new_cache.update(st)
            else:
                a_out, a_cache = _chunk_attn(cfg, p_l["attn"], xn, cache_l,
                                             qpos, valid, flag)
                h = h + a_out
                new_cache.update(a_cache)
            if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
                h = h + L.mlp_apply(p_l["mlp"],
                                    L.apply_norm(h, p_l["ln2"], cfg), cfg)
            elif cfg.block == BLOCK_MOE:
                m_out, _ = moe.moe_apply_dropless(
                    p_l["moe"], L.apply_norm(h, p_l["ln2"], cfg), cfg)
                h = h + m_out
            return (h, _layer_put(cch, new_cache, idx)), None

        (x, new_cache), _ = lax.scan(
            body, (x, cache),
            (params["blocks"], flags, jnp.arange(cfg.num_layers)))
        x = L.apply_norm(x, params["final_norm"], cfg)
        last = jnp.clip(n_valid - 1, 0, C - 1)
        hidden = jnp.take(x[0], last, axis=0)                   # (d,)
        w_out = lm_head_weight(cfg, params).astype(compute_dtype)
        logits = (hidden @ w_out).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return prefill_chunk
