"""Model registry: build a uniform ``Model`` bundle from a ModelConfig.

The bundle carries jit-able pure functions closed over the config plus the
execution knobs (dtypes, chunk sizes, remat). ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins for every model input of a workload cell —
the dry-run lowers against these without allocating anything.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import (BLOCK_DENSE, ModelConfig, ShapeConfig,
                          TrainConfig, ServeConfig)
from repro.models import encdec, transformer
from repro.models.layers import dtype_of


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[[Any, Dict[str, jax.Array]], Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    knobs: Dict[str, Any]
    tp: int
    # fixed-shape incremental prefill (chunked prompt deposit) — None for
    # families that must prefill monolithically (SSM/hybrid state threading,
    # modality frontends, encoder-decoder)
    prefill_chunk: Any = None
    # paged KV (block-table) serving paths — None for families without a
    # parity-safe chunked deposit (the paged engine always streams prompts
    # chunk-by-chunk) or with non-attention decode state to page
    init_paged_cache: Any = None
    decode_step_paged: Any = None
    prefill_chunk_paged: Any = None
    # copy-on-write block clone for the radix prefix cache (paged only)
    clone_paged_block: Any = None


def _knobs(train: TrainConfig, serve: ServeConfig,
           act_sharding=None, attn_sharding=None) -> Dict[str, Any]:
    return {
        "compute_dtype": train.compute_dtype,
        "param_dtype": train.param_dtype,
        "loss_chunk": train.loss_chunk,
        "attn_chunk_threshold": train.attn_chunk_threshold,
        "attn_chunk": train.attn_chunk,
        "attn_chunk_kv": getattr(train, "attn_chunk_kv", 0),
        "remat": train.remat,
        "ring_buffer": serve.ring_buffer,
        "act_sharding": act_sharding,
        "attn_sharding": attn_sharding,
    }


def build_model(cfg: ModelConfig, train: TrainConfig = None,
                serve: ServeConfig = None, tp: int = 1,
                act_sharding=None, attn_sharding=None) -> Model:
    train = train or TrainConfig()
    serve = serve or ServeConfig()
    knobs = _knobs(train, serve, act_sharding, attn_sharding)
    pdt = dtype_of(train.param_dtype)

    if cfg.is_encoder_decoder:
        init = lambda key: encdec.init_encdec_params(cfg, key, pdt)
        return Model(
            cfg=cfg,
            init=init,
            train_loss=encdec.make_train_loss(cfg, knobs),
            prefill=encdec.make_prefill(cfg, knobs, tp),
            decode_step=encdec.make_decode_step(cfg, knobs, tp),
            init_cache=lambda batch, cache_len, dtype=None: (
                encdec.init_encdec_cache(cfg, batch, cache_len, tp,
                                         dtype or dtype_of(knobs["compute_dtype"]))),
            knobs=knobs, tp=tp)

    init = lambda key: transformer.init_lm_params(cfg, key, pdt)
    # dense attention only: MoE's capacity-limited routing is grouped over
    # the routed sequence, so per-chunk routing (and padded rows competing
    # for expert capacity) would not be token-identical to monolithic
    # prefill; SSM/hybrid need state threading; frontends prepend tokens
    chunkable = cfg.block == BLOCK_DENSE and cfg.frontend == "none"
    return Model(
        cfg=cfg,
        init=init,
        train_loss=transformer.make_train_loss(cfg, knobs),
        prefill=transformer.make_prefill(cfg, knobs, tp),
        decode_step=transformer.make_decode_step(cfg, knobs, tp),
        init_cache=lambda batch, cache_len, dtype=None: (
            transformer.init_cache(cfg, batch, cache_len, tp,
                                   dtype or dtype_of(knobs["compute_dtype"]))),
        knobs=knobs, tp=tp,
        prefill_chunk=(transformer.make_prefill_chunk(cfg, knobs, tp)
                       if chunkable else None),
        init_paged_cache=(
            (lambda num_blocks, block_size, dtype=None:
             transformer.init_paged_cache(
                 cfg, num_blocks, block_size, tp,
                 dtype or dtype_of(knobs["compute_dtype"])))
            if chunkable else None),
        decode_step_paged=(transformer.make_decode_step_paged(cfg, knobs, tp)
                           if chunkable else None),
        prefill_chunk_paged=(
            transformer.make_prefill_chunk_paged(cfg, knobs, tp)
            if chunkable else None),
        clone_paged_block=(transformer.make_clone_block(cfg, knobs, tp)
                           if chunkable else None))


# ---------------------------------------------------------------------------
# Workload inputs
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, shape: ShapeConfig, serve: ServeConfig):
    """KV-cache capacity for a decode cell. Ring-buffer mode bounds it at the
    sliding window (sub-quadratic serving for hymba long_500k)."""
    if serve.ring_buffer and cfg.swa_window > 0:
        return min(shape.seq_len, cfg.swa_window)
    return shape.seq_len


def batch_spec(cfg: ModelConfig, shape: ShapeConfig,
               compute_dtype: str = "bfloat16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the *data* inputs of a train/prefill
    step (decode caches are built separately via init_cache + eval_shape)."""
    B, S = shape.global_batch, shape.seq_len
    cdt = dtype_of(compute_dtype)
    i32 = jnp.int32
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                           cdt),
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if cfg.frontend == "patch_stub":
        F = cfg.num_frontend_tokens
        s_text = S - F
        return {
            "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
            "labels": jax.ShapeDtypeStruct((B, s_text), i32),
            "patch_embeds": jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }


def make_synthetic_batch(cfg: ModelConfig, shape_or_batch, seq_len=None,
                         seed: int = 0, compute_dtype: str = "bfloat16"):
    """Concrete random batch matching batch_spec (for smoke tests/examples)."""
    if isinstance(shape_or_batch, ShapeConfig):
        B, S = shape_or_batch.global_batch, shape_or_batch.seq_len
    else:
        B, S = shape_or_batch, seq_len
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    cdt = dtype_of(compute_dtype)
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(k1, (B, cfg.encoder_seq, cfg.d_model),
                                        cdt),
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab_size),
        }
    if cfg.frontend == "patch_stub":
        F = cfg.num_frontend_tokens
        return {
            "tokens": jax.random.randint(k2, (B, S - F), 0, cfg.vocab_size),
            "labels": jax.random.randint(k3, (B, S - F), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(k1, (B, F, cfg.d_model), cdt),
        }
    return {
        "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab_size),
    }
