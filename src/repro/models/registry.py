"""Model registry: build a uniform ``Model`` bundle from a ModelConfig.

The bundle carries jit-able pure functions closed over the config plus the
execution knobs (dtypes, chunk sizes, remat). ``input_specs`` produces
``jax.ShapeDtypeStruct`` stand-ins for every model input of a workload cell —
the dry-run lowers against these without allocating anything.
"""

from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.config import (BLOCK_HYBRID, BLOCK_SSM, ModelConfig,
                          ShapeConfig, TrainConfig, ServeConfig)
from repro.models import encdec, transformer
from repro.models.layers import dtype_of


class Capabilities(NamedTuple):
    """Structural serving capabilities of a model family (DESIGN.md §13).

    Derived from the config's block/frontend structure — never from model
    names or comments — and consumed by the continuous engine, scheduler
    pricing, and fabric placement. ``reason`` documents, for anything
    False, *why* the structure forbids it; engines raise it verbatim so
    an operator sees the capability gap, not a silent degradation."""
    chunked_prefill: bool = True    # fixed-shape chunk-streamed prompts
    paged_decode: bool = True       # block-table KV pool serving
    slot_chunk: bool = True         # per-request slot-cache chunk path
    carried_state: bool = False     # non-KV per-request state pytree
    state_leaves: tuple = ()        # cache leaf names of that state
    prefix_cache: bool = True       # radix-tree KV block reuse
    kv_migration: bool = True       # p2p block migration (disagg fabric)
    encoder_prechunk: bool = False  # enc-dec: encoder pass at admission
    chunk_multiple: int = 1         # prefill chunk must divide by this
    speculative: bool = True        # k-token draft-verify decode (§14)
    reason: str = ""


def derive_capabilities(cfg: ModelConfig) -> Capabilities:
    """Map config structure to serving capabilities."""
    if cfg.frontend == "patch_stub":
        return Capabilities(
            chunked_prefill=False, paged_decode=False, slot_chunk=False,
            prefix_cache=False, kv_migration=False, speculative=False,
            reason="patch_stub modality frontend prepends frontend tokens "
                   "that have no chunked/paged deposit path")
    if cfg.is_encoder_decoder:
        return Capabilities(
            slot_chunk=False, carried_state=True,
            state_leaves=("cross_k", "cross_v"),
            prefix_cache=False, kv_migration=False, encoder_prechunk=True,
            speculative=False,
            reason="carried cross-attention state is per-request, not in "
                   "KV blocks: prefix caching and KV-block migration "
                   "would silently drop it, and speculative rollback "
                   "cannot rewind it by a length decrement")
    if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
        return Capabilities(
            carried_state=True, state_leaves=("conv", "ssm"),
            prefix_cache=False, kv_migration=False,
            chunk_multiple=cfg.ssm_chunk, speculative=False,
            reason="recurrent carried state is per-request, not in KV "
                   "blocks: prefix caching and KV-block migration would "
                   "silently drop it; chunk boundaries must fall on "
                   "ssm_chunk multiples for bit-exact scan resume; "
                   "speculative rollback cannot rewind carried state "
                   "advanced through rejected draft tokens")
    return Capabilities()


class Model(NamedTuple):
    cfg: ModelConfig
    init: Callable[[jax.Array], Any]
    train_loss: Callable[[Any, Dict[str, jax.Array]], Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]
    init_cache: Callable[..., Any]
    knobs: Dict[str, Any]
    tp: int
    # fixed-shape incremental prefill (chunked prompt deposit) over the
    # per-request slot cache — None only when capabilities.slot_chunk is
    # False (enc-dec chunks on the paged path only; patch_stub cannot)
    prefill_chunk: Any = None
    # paged KV (block-table) serving paths — None only when
    # capabilities.paged_decode is False
    init_paged_cache: Any = None
    decode_step_paged: Any = None
    prefill_chunk_paged: Any = None
    # copy-on-write block clone for the radix prefix cache (paged only)
    clone_paged_block: Any = None
    # k-token teacher-forced verify dispatch (speculative decoding) —
    # None when capabilities.speculative is False (carried-state rollback)
    verify_step_paged: Any = None
    # enc-dec only: encoder pass as a fixed pre-chunk at admission
    encode_prechunk: Any = None
    # structural serving capabilities (always set; see derive_capabilities)
    capabilities: Capabilities = Capabilities()


def _knobs(train: TrainConfig, serve: ServeConfig,
           act_sharding=None, attn_sharding=None) -> Dict[str, Any]:
    return {
        "compute_dtype": train.compute_dtype,
        "param_dtype": train.param_dtype,
        "loss_chunk": train.loss_chunk,
        "attn_chunk_threshold": train.attn_chunk_threshold,
        "attn_chunk": train.attn_chunk,
        "attn_chunk_kv": getattr(train, "attn_chunk_kv", 0),
        "remat": train.remat,
        "ring_buffer": serve.ring_buffer,
        "act_sharding": act_sharding,
        "attn_sharding": attn_sharding,
    }


def build_model(cfg: ModelConfig, train: TrainConfig = None,
                serve: ServeConfig = None, tp: int = 1,
                act_sharding=None, attn_sharding=None) -> Model:
    train = train or TrainConfig()
    serve = serve or ServeConfig()
    knobs = _knobs(train, serve, act_sharding, attn_sharding)
    pdt = dtype_of(train.param_dtype)

    caps = derive_capabilities(cfg)

    if cfg.is_encoder_decoder:
        init = lambda key: encdec.init_encdec_params(cfg, key, pdt)
        return Model(
            cfg=cfg,
            init=init,
            train_loss=encdec.make_train_loss(cfg, knobs),
            prefill=encdec.make_prefill(cfg, knobs, tp),
            decode_step=encdec.make_decode_step(cfg, knobs, tp),
            init_cache=lambda batch, cache_len, dtype=None: (
                encdec.init_encdec_cache(cfg, batch, cache_len, tp,
                                         dtype or dtype_of(knobs["compute_dtype"]))),
            knobs=knobs, tp=tp,
            init_paged_cache=(
                lambda num_blocks, block_size, dtype=None, num_rows=0:
                encdec.init_paged_cache(
                    cfg, num_blocks, block_size, tp,
                    dtype or dtype_of(knobs["compute_dtype"]),
                    num_rows=num_rows)),
            decode_step_paged=encdec.make_decode_step_paged(cfg, knobs, tp),
            prefill_chunk_paged=encdec.make_prefill_chunk_paged(
                cfg, knobs, tp),
            encode_prechunk=encdec.make_encode_prechunk(cfg, knobs, tp),
            capabilities=caps)

    init = lambda key: transformer.init_lm_params(cfg, key, pdt)
    paged = caps.paged_decode
    return Model(
        cfg=cfg,
        init=init,
        train_loss=transformer.make_train_loss(cfg, knobs),
        prefill=transformer.make_prefill(cfg, knobs, tp),
        decode_step=transformer.make_decode_step(cfg, knobs, tp),
        init_cache=lambda batch, cache_len, dtype=None: (
            transformer.init_cache(cfg, batch, cache_len, tp,
                                   dtype or dtype_of(knobs["compute_dtype"]))),
        knobs=knobs, tp=tp,
        prefill_chunk=(transformer.make_prefill_chunk(cfg, knobs, tp)
                       if caps.slot_chunk else None),
        init_paged_cache=(
            (lambda num_blocks, block_size, dtype=None, num_rows=0:
             transformer.init_paged_cache(
                 cfg, num_blocks, block_size, tp,
                 dtype or dtype_of(knobs["compute_dtype"]),
                 num_rows=num_rows))
            if paged else None),
        decode_step_paged=(transformer.make_decode_step_paged(cfg, knobs, tp)
                           if paged else None),
        prefill_chunk_paged=(
            transformer.make_prefill_chunk_paged(cfg, knobs, tp)
            if paged else None),
        clone_paged_block=(transformer.make_clone_block(cfg, knobs, tp)
                           if paged and caps.prefix_cache else None),
        verify_step_paged=(transformer.make_verify_step_paged(cfg, knobs, tp)
                           if paged and caps.speculative else None),
        capabilities=caps)


# ---------------------------------------------------------------------------
# Workload inputs
# ---------------------------------------------------------------------------

def cache_len_for(cfg: ModelConfig, shape: ShapeConfig, serve: ServeConfig):
    """KV-cache capacity for a decode cell. Ring-buffer mode bounds it at the
    sliding window (sub-quadratic serving for hymba long_500k)."""
    if serve.ring_buffer and cfg.swa_window > 0:
        return min(shape.seq_len, cfg.swa_window)
    return shape.seq_len


def batch_spec(cfg: ModelConfig, shape: ShapeConfig,
               compute_dtype: str = "bfloat16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the *data* inputs of a train/prefill
    step (decode caches are built separately via init_cache + eval_shape)."""
    B, S = shape.global_batch, shape.seq_len
    cdt = dtype_of(compute_dtype)
    i32 = jnp.int32
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model),
                                           cdt),
            "tokens": jax.ShapeDtypeStruct((B, S), i32),
            "labels": jax.ShapeDtypeStruct((B, S), i32),
        }
    if cfg.frontend == "patch_stub":
        F = cfg.num_frontend_tokens
        s_text = S - F
        return {
            "tokens": jax.ShapeDtypeStruct((B, s_text), i32),
            "labels": jax.ShapeDtypeStruct((B, s_text), i32),
            "patch_embeds": jax.ShapeDtypeStruct((B, F, cfg.d_model), cdt),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), i32),
        "labels": jax.ShapeDtypeStruct((B, S), i32),
    }


def make_synthetic_batch(cfg: ModelConfig, shape_or_batch, seq_len=None,
                         seed: int = 0, compute_dtype: str = "bfloat16"):
    """Concrete random batch matching batch_spec (for smoke tests/examples)."""
    if isinstance(shape_or_batch, ShapeConfig):
        B, S = shape_or_batch.global_batch, shape_or_batch.seq_len
    else:
        B, S = shape_or_batch, seq_len
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    cdt = dtype_of(compute_dtype)
    if cfg.is_encoder_decoder:
        return {
            "frames": jax.random.normal(k1, (B, cfg.encoder_seq, cfg.d_model),
                                        cdt),
            "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
            "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab_size),
        }
    if cfg.frontend == "patch_stub":
        F = cfg.num_frontend_tokens
        return {
            "tokens": jax.random.randint(k2, (B, S - F), 0, cfg.vocab_size),
            "labels": jax.random.randint(k3, (B, S - F), 0, cfg.vocab_size),
            "patch_embeds": jax.random.normal(k1, (B, F, cfg.d_model), cdt),
        }
    return {
        "tokens": jax.random.randint(k2, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(k3, (B, S), 0, cfg.vocab_size),
    }
