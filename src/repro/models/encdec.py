"""Whisper-style encoder-decoder transformer.

The audio (conv/mel) frontend is a STUB per the assignment: ``input_specs``
provides precomputed frame embeddings (B, enc_seq, d_model). Encoder:
bidirectional self-attention + sinusoidal positions. Decoder: causal
self-attention (KV-cached) + cross-attention to the encoder output (cross
K/V computed once at prefill) + learned positional embeddings.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models.transformer import (_layer_put, _layer_slice, _paged_attn,
                                      kv_store_heads)

MAX_DECODE_POS = 32_768  # decoder learned-position capacity (covers decode_32k)


def _init_attn_block(cfg, key, dtype):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.init_norm(cfg, dtype),
        "attn": L.init_attention(cfg, ks[0], dtype),
        "ln2": L.init_norm(cfg, dtype),
        "mlp": L.init_mlp(cfg, ks[1], dtype),
    }


def _init_dec_block(cfg, key, dtype):
    ks = jax.random.split(key, 3)
    p = _init_attn_block(cfg, ks[0], dtype)
    p["ln_x"] = L.init_norm(cfg, dtype)
    p["xattn"] = L.init_attention(cfg, ks[1], dtype)
    return p


def init_encdec_params(cfg: ModelConfig, key, dtype, max_pos: int = None):
    max_pos = max_pos or MAX_DECODE_POS
    ks = jax.random.split(key, 5)
    enc_keys = jax.random.split(ks[0], cfg.num_encoder_layers)
    dec_keys = jax.random.split(ks[1], cfg.num_layers)
    params = {
        "embed": L.embed_init(ks[2], (cfg.padded_vocab, cfg.d_model), dtype),
        "dec_pos": L.embed_init(ks[3], (max_pos, cfg.d_model), dtype),
        "enc_blocks": jax.vmap(lambda k: _init_attn_block(cfg, k, dtype))(
            enc_keys),
        "dec_blocks": jax.vmap(lambda k: _init_dec_block(cfg, k, dtype))(
            dec_keys),
        "enc_norm": L.init_norm(cfg, dtype),
        "final_norm": L.init_norm(cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            ks[4], (cfg.d_model, cfg.padded_vocab), cfg.d_model, dtype)
    return params


def _self_attn(cfg, p, xn, positions, *, causal, knobs):
    p = p["attn"]
    q, k, v = L.project_qkv(p, xn, cfg, positions, use_rope=False)
    kf, vf = L.repeat_kv(k, cfg.num_heads), L.repeat_kv(v, cfg.num_heads)
    S = xn.shape[1]
    if S > knobs["attn_chunk_threshold"]:
        ctx = L.chunked_attention(q, kf, vf, q_pos=positions, k_pos=positions,
                                  causal=causal, window=None,
                                  chunk_q=knobs["attn_chunk"],
                                  chunk_k=knobs["attn_chunk"])
    else:
        ctx = L.full_attention(q, kf, vf, q_pos=positions, k_pos=positions,
                               causal=causal, window=None)
    return L.attn_output(p, ctx, xn.dtype)


def encode(cfg, params, frames, knobs):
    """frames (B, T_enc, d) (stub embeddings) -> encoder hidden."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    x = frames.astype(compute_dtype)
    x = x + L.sinusoidal_pos(x.shape[1], cfg.d_model).astype(compute_dtype)
    positions = jnp.arange(x.shape[1])

    def body(h, p_l):
        h = L.constrain(h, knobs.get("act_sharding"))
        hn = L.apply_norm(h, p_l["ln1"], cfg)
        h = h + _self_attn(cfg, p_l, hn, positions, causal=False, knobs=knobs)
        h = h + L.mlp_apply(p_l["mlp"], L.apply_norm(h, p_l["ln2"], cfg), cfg)
        return L.constrain(h, knobs.get("act_sharding")), None

    if knobs["remat"]:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["enc_blocks"])
    return L.apply_norm(x, params["enc_norm"], cfg)


def _cross_kv(cfg, p_x, enc_out):
    """Encoder-side K/V for cross-attention (no rope, no cache growth)."""
    k = jnp.einsum("btd,dhk->bthk", enc_out, p_x["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p_x["wv"].astype(enc_out.dtype))
    if cfg.qkv_bias:
        k = k + p_x["bk"].astype(enc_out.dtype)
        v = v + p_x["bv"].astype(enc_out.dtype)
    return k, v


def _cross_attn(cfg, p_x, xn, ck, cv):
    q = jnp.einsum("bsd,dhk->bshk", xn, p_x["wq"].astype(xn.dtype))
    if cfg.qkv_bias:
        q = q + p_x["bq"].astype(xn.dtype)
    kf, vf = L.repeat_kv(ck, cfg.num_heads), L.repeat_kv(cv, cfg.num_heads)
    Sq, Tk = xn.shape[1], ck.shape[1]
    ctx = L.full_attention(q, kf, vf, q_pos=jnp.arange(Sq),
                           k_pos=jnp.arange(Tk), causal=False, window=None)
    return L.attn_output(p_x, ctx, xn.dtype)


def decode_full(cfg, params, tokens, enc_out, knobs, pos_offset: int = 0):
    """Teacher-forced decoder pass. Returns final hidden (B,S,d)."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    S = tokens.shape[1]
    positions = jnp.arange(pos_offset, pos_offset + S)
    x = x + lax.dynamic_slice_in_dim(params["dec_pos"], pos_offset, S, axis=0
                                     ).astype(compute_dtype)

    def body(h, p_l):
        h = L.constrain(h, knobs.get("act_sharding"))
        hn = L.apply_norm(h, p_l["ln1"], cfg)
        h = h + _self_attn(cfg, p_l, hn, positions, causal=True, knobs=knobs)
        ck, cv = _cross_kv(cfg, p_l["xattn"], enc_out)
        h = h + _cross_attn(cfg, p_l["xattn"],
                            L.apply_norm(h, p_l["ln_x"], cfg), ck, cv)
        h = h + L.mlp_apply(p_l["mlp"], L.apply_norm(h, p_l["ln2"], cfg), cfg)
        return L.constrain(h, knobs.get("act_sharding")), None

    if knobs["remat"]:
        body = jax.checkpoint(body)
    x, _ = lax.scan(body, x, params["dec_blocks"])
    return L.apply_norm(x, params["final_norm"], cfg)


def make_train_loss(cfg: ModelConfig, knobs):
    def train_loss(params, batch):
        enc_out = encode(cfg, params, batch["frames"], knobs)
        hidden = decode_full(cfg, params, batch["tokens"], enc_out, knobs)
        labels = batch["labels"]
        valid = labels >= 0
        w_out = (params["embed"].T if cfg.tie_embeddings
                 else params["lm_head"])
        loss_sum, n_valid = L.chunked_cross_entropy(
            hidden, w_out.astype(hidden.dtype), jnp.maximum(labels, 0),
            valid=valid, vocab_size=cfg.vocab_size, chunk=knobs["loss_chunk"])
        loss = loss_sum / jnp.maximum(n_valid, 1.0)
        return loss, {"loss": loss}

    return train_loss


def init_encdec_cache(cfg: ModelConfig, batch: int, cache_len: int, tp: int,
                      compute_dtype):
    Lc = cfg.num_layers
    gs = kv_store_heads(cfg, tp)
    return {
        "k": jnp.zeros((Lc, batch, cache_len, gs, cfg.head_dim), compute_dtype),
        "v": jnp.zeros((Lc, batch, cache_len, gs, cfg.head_dim), compute_dtype),
        "pos": jnp.full((Lc, cache_len), -1, jnp.int32),
        "cross_k": jnp.zeros((Lc, batch, cfg.encoder_seq,
                              cfg.num_kv_heads, cfg.head_dim), compute_dtype),
        "cross_v": jnp.zeros((Lc, batch, cfg.encoder_seq,
                              cfg.num_kv_heads, cfg.head_dim), compute_dtype),
    }


def make_prefill(cfg: ModelConfig, knobs, tp: int):
    compute_dtype = L.dtype_of(knobs["compute_dtype"])

    def prefill(params, batch, cache_len: int):
        """Encode frames + prime the decoder with the prompt tokens."""
        enc_out = encode(cfg, params, batch["frames"], knobs)
        B = enc_out.shape[0]
        cache = init_encdec_cache(cfg, B, cache_len, tp, compute_dtype)

        # per-layer cross K/V via a scan over stacked decoder params
        def body(_, p_l):
            ck, cv = _cross_kv(cfg, p_l["xattn"], enc_out)
            return (), (ck, cv)
        _, (cks, cvs) = lax.scan(body, (), params["dec_blocks"])
        cache["cross_k"] = cks.astype(compute_dtype)
        cache["cross_v"] = cvs.astype(compute_dtype)

        tokens = batch["tokens"]
        S = tokens.shape[1]
        gs = kv_store_heads(cfg, tp)
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
        positions = jnp.arange(S)
        x = x + params["dec_pos"][:S].astype(compute_dtype)

        def dbody(h, p_l):
            hn = L.apply_norm(h, p_l["ln1"], cfg)
            q, k, v = L.project_qkv(p_l["attn"], hn, cfg, positions,
                                    use_rope=False)
            kf, vf = L.repeat_kv(k, cfg.num_heads), L.repeat_kv(v, cfg.num_heads)
            if S > knobs["attn_chunk_threshold"]:
                ctx = L.chunked_attention(
                    q, kf, vf, q_pos=positions, k_pos=positions, causal=True,
                    window=None, chunk_q=knobs["attn_chunk"],
                    chunk_k=knobs["attn_chunk"])
            else:
                ctx = L.full_attention(q, kf, vf, q_pos=positions,
                                       k_pos=positions, causal=True,
                                       window=None)
            h = h + L.attn_output(p_l["attn"], ctx, hn.dtype)
            ck, cv = _cross_kv(cfg, p_l["xattn"], enc_out)
            h = h + _cross_attn(cfg, p_l["xattn"],
                                L.apply_norm(h, p_l["ln_x"], cfg), ck, cv)
            h = h + L.mlp_apply(p_l["mlp"], L.apply_norm(h, p_l["ln2"], cfg),
                                cfg)
            return h, (L.repeat_kv(k, gs), L.repeat_kv(v, gs))

        if knobs["remat"]:
            dbody = jax.checkpoint(dbody)
        x, (ks_, vs_) = lax.scan(dbody, x, params["dec_blocks"])
        x = L.apply_norm(x, params["final_norm"], cfg)
        cache["k"] = lax.dynamic_update_slice_in_dim(
            cache["k"], ks_.astype(compute_dtype), 0, axis=2)
        cache["v"] = lax.dynamic_update_slice_in_dim(
            cache["v"], vs_.astype(compute_dtype), 0, axis=2)
        pos_row = jnp.where(jnp.arange(cache_len) < S, jnp.arange(cache_len),
                            -1)
        cache["pos"] = jnp.broadcast_to(pos_row, (cfg.num_layers, cache_len))
        w_out = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x[:, -1, :] @ w_out.astype(compute_dtype)
                  ).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), cache

    return prefill


# ---------------------------------------------------------------------------
# Paged serving path (DESIGN.md §13): encoder pass as a fixed pre-chunk
# ---------------------------------------------------------------------------

def init_paged_cache(cfg: ModelConfig, num_blocks: int, block_size: int,
                     tp: int, compute_dtype, num_rows: int = 0):
    """Decoder KV block pool + per-row cross-attention carried state.

    The decoder's self-attention KV pages like any dense model; the
    encoder output enters serving as *carried state* — per-layer cross
    K/V of fixed shape (enc_seq is a config constant), one row per
    engine request row, installed once by :func:`make_encode_prechunk`
    and read-only for the request's whole lifetime."""
    Lc = cfg.num_layers
    gs = kv_store_heads(cfg, tp)
    return {
        "k": jnp.zeros((Lc, num_blocks, block_size, gs, cfg.head_dim),
                       compute_dtype),
        "v": jnp.zeros((Lc, num_blocks, block_size, gs, cfg.head_dim),
                       compute_dtype),
        "cross_k": jnp.zeros((Lc, num_rows, cfg.encoder_seq,
                              cfg.num_kv_heads, cfg.head_dim), compute_dtype),
        "cross_v": jnp.zeros((Lc, num_rows, cfg.encoder_seq,
                              cfg.num_kv_heads, cfg.head_dim), compute_dtype),
    }


def make_encode_prechunk(cfg: ModelConfig, knobs, tp: int):
    """The encoder pass as a fixed pre-chunk: run the (fixed-shape)
    encoder once at admission and install each request's per-layer cross
    K/V into its cache row. The chunked decoder prefill then never
    touches the encoder — enc-dec admission is 'one pre-chunk, then the
    ordinary chunk stream'."""

    def encode_prechunk(params, cache, frames, rows):
        """frames (B, T_enc, d); rows (B,) int32 -> cache. Rows aimed at
        an out-of-range index (padding) drop their write."""
        enc_out = encode(cfg, params, frames, knobs)

        def body(_, p_l):
            ck, cv = _cross_kv(cfg, p_l["xattn"], enc_out)
            return (), (ck, cv)
        _, (cks, cvs) = lax.scan(body, (), params["dec_blocks"])
        # cks (L, B, T_enc, Hkv, hd): scatter the admitted rows
        new_ck = cache["cross_k"].at[:, rows].set(
            cks.astype(cache["cross_k"].dtype), mode="drop")
        new_cv = cache["cross_v"].at[:, rows].set(
            cvs.astype(cache["cross_v"].dtype), mode="drop")
        return {**cache, "cross_k": new_ck, "cross_v": new_cv}

    return encode_prechunk


def _paged_dec_backbone(cfg, params, x, tables, qpos, wvalid, cache, *,
                        rows=None):
    """Decoder scan over the paged pool: self-attention through block
    tables (:func:`_paged_attn` — rope is inert under learned positions),
    cross-attention against the row-aligned carried cross K/V. ``rows``
    (chunk mode) gathers the prefilling subset of cross rows; decode mode
    (rows=None) is row-aligned full-width."""
    mutable = {"k": cache["k"], "v": cache["v"]}

    def body(carry, xs):
        h, mut = carry
        p_l, cross_k, cross_v, idx = xs
        cache_l = _layer_slice(mut, idx)
        hn = L.apply_norm(h, p_l["ln1"], cfg)
        a_out, a_cache = _paged_attn(cfg, p_l["attn"], hn, cache_l,
                                     tables, qpos, wvalid, True)
        h = h + a_out
        if rows is not None:
            ck = jnp.take(cross_k, rows, axis=0, mode="clip")
            cv = jnp.take(cross_v, rows, axis=0, mode="clip")
        else:
            ck, cv = cross_k, cross_v
        h = h + _cross_attn(cfg, p_l["xattn"],
                            L.apply_norm(h, p_l["ln_x"], cfg), ck, cv)
        h = h + L.mlp_apply(p_l["mlp"], L.apply_norm(h, p_l["ln2"], cfg),
                            cfg)
        return (h, _layer_put(mut, a_cache, idx)), None

    (x, mutable), _ = lax.scan(
        body, (x, mutable),
        (params["dec_blocks"], cache["cross_k"], cache["cross_v"],
         jnp.arange(cfg.num_layers)))
    new_cache = {**mutable, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    return L.apply_norm(x, params["final_norm"], cfg), new_cache


def _dec_embed(cfg, params, tokens, qpos, compute_dtype):
    """Token embedding + learned decoder positions (parked/padded rows
    clip to position 0 — their outputs are discarded)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    pe = jnp.take(params["dec_pos"],
                  jnp.clip(qpos, 0, params["dec_pos"].shape[0] - 1), axis=0)
    return x + pe.astype(compute_dtype)


def make_prefill_chunk_paged(cfg: ModelConfig, knobs, tp: int):
    """Fixed-shape chunked decoder-prompt deposit through block tables —
    same contract as the decoder-only path (tokens/tables/rows/pos0/
    n_valid), with cross-attention to the carried encoder state the only
    extra term."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])

    def prefill_chunk(params, cache, tokens, block_tables, rows, pos0,
                      n_valid):
        """tokens (B,C) int32; block_tables (B,NB); rows, pos0, n_valid
        (B,) -> (last-valid-position logits (B,Vp), cache)."""
        B, C = tokens.shape
        qpos = pos0[:, None] + jnp.arange(C)[None, :]
        wvalid = jnp.arange(C)[None, :] < n_valid[:, None]
        x = _dec_embed(cfg, params, tokens, qpos, compute_dtype)
        x, new_cache = _paged_dec_backbone(cfg, params, x, block_tables,
                                           qpos, wvalid, cache, rows=rows)
        last = jnp.clip(n_valid - 1, 0, C - 1)
        hidden = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0]
        w_out = (params["embed"].T if cfg.tie_embeddings
                 else params["lm_head"])
        logits = (hidden @ w_out.astype(compute_dtype)).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return prefill_chunk


def make_decode_step_paged(cfg: ModelConfig, knobs, tp: int):
    """Batched one-token decode through block tables, row-aligned with
    the carried cross K/V (row i of the batch IS engine row i)."""
    compute_dtype = L.dtype_of(knobs["compute_dtype"])

    def decode_step(params, cache, tokens, positions, block_tables):
        """tokens (B,1) int32, positions (B,), block_tables (B,NB) ->
        (logits (B,Vp), cache)."""
        qpos = positions[:, None]
        wvalid = (positions >= 0)[:, None]
        x = _dec_embed(cfg, params, tokens, qpos, compute_dtype)
        x, new_cache = _paged_dec_backbone(cfg, params, x, block_tables,
                                           qpos, wvalid, cache)
        w_out = (params["embed"].T if cfg.tie_embeddings
                 else params["lm_head"])
        logits = (x[:, 0, :] @ w_out.astype(compute_dtype)
                  ).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return decode_step


def make_decode_step(cfg: ModelConfig, knobs, tp: int):
    compute_dtype = L.dtype_of(knobs["compute_dtype"])

    def decode_step(params, cache, token, pos):
        """Self-attn cache rides in the scan carry (in-place update, aliases
        with donation); the immutable cross K/V streams through xs."""
        B = token.shape[0]
        x = jnp.take(params["embed"], token, axis=0).astype(compute_dtype)
        x = x + lax.dynamic_slice_in_dim(params["dec_pos"], pos, 1, axis=0
                                         ).astype(compute_dtype)
        mutable = {k: cache[k] for k in ("k", "v", "pos")}

        def layer_slice(tree, idx):
            return jax.tree_util.tree_map(
                lambda c: lax.dynamic_index_in_dim(c, idx, 0, keepdims=False),
                tree)

        def layer_put(tree, new, idx):
            return jax.tree_util.tree_map(
                lambda c, n: lax.dynamic_update_index_in_dim(
                    c, n.astype(c.dtype), idx, 0), tree, new)

        def body(carry, xs):
            h, mut = carry
            p_l, cross_k, cross_v, idx = xs
            cache_l = layer_slice(mut, idx)
            cache_l["cross_k"] = cross_k
            cache_l["cross_v"] = cross_v
            hn = L.apply_norm(h, p_l["ln1"], cfg)
            positions = jnp.full((1,), pos)
            q, k, v = L.project_qkv(p_l["attn"], hn, cfg, positions,
                                    use_rope=False)
            gs = cache_l["k"].shape[2]
            kc, vc = L.repeat_kv(k, gs), L.repeat_kv(v, gs)
            W = cache_l["k"].shape[1]
            slot = pos % W
            nk = lax.dynamic_update_slice_in_dim(cache_l["k"], kc, slot, axis=1)
            nv = lax.dynamic_update_slice_in_dim(cache_l["v"], vc, slot, axis=1)
            npos = lax.dynamic_update_slice_in_dim(
                cache_l["pos"], jnp.full((1,), pos, jnp.int32), slot, axis=0)
            kf, vf = L.repeat_kv(nk, cfg.num_heads), L.repeat_kv(nv, cfg.num_heads)
            okay = (npos >= 0) & (npos <= pos)
            sc = jnp.einsum("bqhk,bthk->bhqt", q, kf).astype(jnp.float32)
            sc = sc / (cfg.head_dim ** 0.5)
            sc = sc + jnp.where(okay, 0.0, L.NEG_INF)[None, None, None, :]
            prob = jax.nn.softmax(sc, axis=-1).astype(hn.dtype)
            ctx = jnp.einsum("bhqt,bthk->bqhk", prob, vf)
            h = h + L.attn_output(p_l["attn"], ctx, hn.dtype)
            h = h + _cross_attn(cfg, p_l["xattn"],
                                L.apply_norm(h, p_l["ln_x"], cfg),
                                cache_l["cross_k"], cache_l["cross_v"])
            h = h + L.mlp_apply(p_l["mlp"], L.apply_norm(h, p_l["ln2"], cfg),
                                cfg)
            mut = layer_put(mut, {"k": nk, "v": nv, "pos": npos}, idx)
            return (h, mut), None

        (x, mutable), _ = lax.scan(
            body, (x, mutable),
            (params["dec_blocks"], cache["cross_k"], cache["cross_v"],
             jnp.arange(cfg.num_layers)))
        new_cache = {**mutable, "cross_k": cache["cross_k"],
                     "cross_v": cache["cross_v"]}
        x = L.apply_norm(x, params["final_norm"], cfg)
        w_out = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        logits = (x[:, 0, :] @ w_out.astype(compute_dtype)
                  ).astype(jnp.float32)
        vocab_ok = jnp.arange(cfg.padded_vocab) < cfg.vocab_size
        return jnp.where(vocab_ok, logits, L.NEG_INF), new_cache

    return decode_step
