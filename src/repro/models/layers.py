"""Shared neural-net layers: norms, RoPE, attention (full + chunked
online-softmax), gated MLPs, chunked cross-entropy.

Pure JAX, params are plain dicts of arrays. All matmul-heavy ops accept a
``compute_dtype`` and cast weights/activations on entry; normalization and
softmax statistics are computed in float32.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

DTYPES = {
    "float32": jnp.float32,
    "bfloat16": jnp.bfloat16,
    "float16": jnp.float16,
}

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free when
                 # a row is fully masked (e.g. ring-buffer slots not yet valid)


def dtype_of(name: str):
    return DTYPES[name]


def constrain(x, sharding):
    """Apply an activation sharding constraint when one is configured.
    Without this XLA may shard remat-saved residual streams on the model
    axis (replicating the batch!) — observed 51GB/device on yi-9b."""
    if sharding is None:
        return x
    return lax.with_sharding_constraint(x, sharding)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_dim: int, dtype=jnp.float32):
    """Truncated-normal fan-in init (std = 1/sqrt(in_dim))."""
    std = in_dim ** -0.5
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32)
            * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rmsnorm(x, w, *, eps: float = 1e-5, unit_offset: bool = False):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * lax.rsqrt(var + eps)
    scale = (1.0 + w.astype(jnp.float32)) if unit_offset else w.astype(jnp.float32)
    return (y * scale).astype(x.dtype)


def layernorm(x, w, b, *, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(x.dtype)


def apply_norm(x, p, cfg):
    if cfg.norm_type == "layernorm":
        return layernorm(x, p["w"], p["b"], eps=cfg.norm_eps)
    return rmsnorm(x, p["w"], eps=cfg.norm_eps,
                   unit_offset=cfg.rmsnorm_unit_offset)


def init_norm(cfg, dtype=jnp.float32):
    if cfg.norm_type == "layernorm":
        return {"w": jnp.ones((cfg.d_model,), dtype),
                "b": jnp.zeros((cfg.d_model,), dtype)}
    w = jnp.zeros if cfg.rmsnorm_unit_offset else jnp.ones
    return {"w": w((cfg.d_model,), dtype)}


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions: (...,) int -> cos/sin (..., head_dim//2) float32."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (S, hd/2) or (B, S, hd/2). Half-split style."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:   # (S, half) -> broadcast over batch and heads
        c = cos[None, :, None, :]
        s = sin[None, :, None, :]
    else:               # (B, S, half)
        c = cos[:, :, None, :]
        s = sin[:, :, None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = xf1 * c - xf2 * s
    o2 = xf2 * c + xf1 * s
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def sinusoidal_pos(seq: int, d_model: int):
    """Whisper-style sinusoid table (seq, d_model), float32."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = jnp.arange(seq, dtype=jnp.float32)[:, None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def init_attention(cfg, key, dtype=jnp.float32):
    d, h, hkv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), d, dtype),
        "wk": dense_init(ks[1], (d, hkv, hd), d, dtype),
        "wv": dense_init(ks[2], (d, hkv, hd), d, dtype),
        "wo": dense_init(ks[3], (h, hd, d), h * hd, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((hkv, hd), dtype)
        p["bv"] = jnp.zeros((hkv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qk_head_norm(x, w, eps):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)


def project_qkv(p, x, cfg, positions, *, x_kv=None, kv_positions=None,
                use_rope=True):
    """Project to q (B,S,H,hd) and k,v (B,T,Hkv,hd), with rope + qk-norm.

    ``x_kv`` enables cross-attention (keys/values from another sequence).
    """
    x_kv = x if x_kv is None else x_kv
    kv_positions = positions if kv_positions is None else kv_positions
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x_kv, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x_kv, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = _qk_head_norm(q, p["q_norm"], cfg.norm_eps)
        k = _qk_head_norm(k, p["k_norm"], cfg.norm_eps)
    if use_rope and cfg.pos_embed == "rope":
        cos_q, sin_q = rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)
        cos_k, sin_k = rope_cos_sin(kv_positions, cfg.head_dim, cfg.rope_theta)
        q = apply_rope(q, cos_q, sin_q)
        k = apply_rope(k, cos_k, sin_k)
    return q, k, v


def repeat_kv(k, num_heads: int):
    """(B,T,Hkv,hd) -> (B,T,H,hd) by repeating each kv head H/Hkv times."""
    hkv = k.shape[2]
    if hkv == num_heads:
        return k
    return jnp.repeat(k, num_heads // hkv, axis=2)


PAD_POS = 2 ** 30   # sentinel position for padded kv slots


def _mask_bias(q_pos, k_pos, *, causal: bool, window):
    """Additive mask bias (..., Sq, Sk) from absolute positions.

    ``window``: 0 / None = unlimited; may be a traced scalar (per-layer
    dynamic window, e.g. hymba global-vs-SWA layers). Sentinel positions
    (>= PAD_POS/2) are always masked — chunk padding must not leak into
    non-causal attention (hypothesis-found edge case).
    """
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = dk < PAD_POS // 2
    ok &= jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), bool)
    if causal:
        ok &= dk <= dq
    if window is not None:
        w = jnp.asarray(window)
        ok &= jnp.where(w > 0, dk > dq - w, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def full_attention(q, k, v, *, q_pos, k_pos, causal=True, window=None,
                   softcap: float = 0.0, extra_mask=None):
    """Dense attention. q (B,S,H,hd), k/v (B,T,H,hd) (kv already repeated).

    ``extra_mask``: optional (B, T) validity mask for cache slots.
    """
    hd = q.shape[-1]
    scores = jnp.einsum("bshk,bthk->bhst", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(hd)
    if softcap > 0:
        scores = softcap * jnp.tanh(scores / softcap)
    bias = _mask_bias(q_pos, k_pos, causal=causal, window=window)
    scores = scores + bias  # (B,H,S,T) + (S,T) or (B,1?,S,T)
    if extra_mask is not None:
        scores = scores + jnp.where(extra_mask, 0.0, NEG_INF)[:, None, None, :]
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhst,bthk->bshk", probs, v)


def chunked_attention(q, k, v, *, q_pos, k_pos, causal=True, window=None,
                      softcap: float = 0.0, chunk_q: int = 512,
                      chunk_k: int = 512):
    """Flash-style online-softmax attention via lax.scan over q and kv blocks.

    Never materializes the (S, T) score matrix; peak memory is
    O(chunk_q * chunk_k) per head. This is the backend-portable oracle path;
    ``repro.kernels.flash_attention`` is the Pallas TPU version.
    q: (B,S,H,hd); k,v: (B,T,H,hd); q_pos (S,), k_pos (T,) absolute positions.
    """
    B, S, H, hd = q.shape
    T = k.shape[1]
    cq = min(chunk_q, S)
    ck = min(chunk_k, T)
    nq, nk = -(-S // cq), -(-T // ck)
    pad_q, pad_k = nq * cq - S, nk * ck - T
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_pos = jnp.pad(q_pos, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        # padded kv slots get the sentinel position: always masked
        k_pos = jnp.pad(k_pos, (0, pad_k), constant_values=PAD_POS)

    qb = q.reshape(B, nq, cq, H, hd).transpose(1, 0, 2, 3, 4)
    kb = k.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nk, ck, H, hd).transpose(1, 0, 2, 3, 4)
    qpb = q_pos.reshape(nq, cq)
    kpb = k_pos.reshape(nk, ck)
    scale = 1.0 / math.sqrt(hd)

    def q_block(carry, qin):
        qc, qp = qin   # (B,cq,H,hd), (cq,)

        def kv_block(state, kin):
            m, l, acc = state
            kc, vc, kp = kin
            s = jnp.einsum("bshk,bthk->bhst", qc, kc).astype(jnp.float32) * scale
            if softcap > 0:
                s = softcap * jnp.tanh(s / softcap)
            s = s + _mask_bias(qp, kp, causal=causal, window=window)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            acc_new = (acc * corr[..., None]
                       + jnp.einsum("bhst,bthk->bhsk", p.astype(qc.dtype), vc
                                    ).astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, cq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, H, cq), jnp.float32)
        a0 = jnp.zeros((B, H, cq, hd), jnp.float32)
        # checkpoint each kv block: backward recomputes the (cq, ck) prob
        # tiles instead of saving them for every block pair (flash-bwd
        # memory behaviour; the saved state per step is O(cq·hd), not cq·ck)
        (m, l, acc), _ = lax.scan(jax.checkpoint(kv_block), (m0, l0, a0),
                                  (kb, vb, kpb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return carry, out.transpose(0, 2, 1, 3).astype(qc.dtype)  # (B,cq,H,hd)

    _, outs = lax.scan(q_block, (), (qb, qpb))
    out = outs.transpose(1, 0, 2, 3, 4).reshape(B, nq * cq, H, hd)
    return out[:, :S]


def attn_output(p, ctx_heads, out_dtype):
    return jnp.einsum("bshk,hkd->bsd", ctx_heads,
                      p["wo"].astype(ctx_heads.dtype)).astype(out_dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(cfg, key, dtype=jnp.float32):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_act in ("swiglu", "geglu"):
        return {"w_gate": dense_init(ks[0], (d, f), d, dtype),
                "w_up": dense_init(ks[1], (d, f), d, dtype),
                "w_down": dense_init(ks[2], (f, d), f, dtype)}
    return {"w_up": dense_init(ks[0], (d, f), d, dtype),
            "w_down": dense_init(ks[1], (f, d), f, dtype)}


def mlp_apply(p, x, cfg):
    if cfg.mlp_act in ("swiglu", "geglu"):
        g = jnp.einsum("bsd,df->bsf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["w_up"].astype(x.dtype))
        act = jax.nn.silu if cfg.mlp_act == "swiglu" else jax.nn.gelu
        h = act(g) * u
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x,
                                   p["w_up"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def chunked_cross_entropy(hidden, w_out, labels, *, valid, vocab_size: int,
                          chunk: int = 512):
    """Cross-entropy without materializing full (B,S,V) logits.

    hidden (B,S,d), w_out (d,Vp), labels (B,S) int32, valid (B,S) bool.
    Logits are computed per sequence-chunk inside a scan; statistics in f32.
    Padded vocab entries (>= vocab_size) are masked out. Returns
    (sum_loss, sum_valid) so callers control normalization.
    """
    B, S, d = hidden.shape
    Vp = w_out.shape[1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S
    if pad:
        hidden = jnp.pad(hidden, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        valid = jnp.pad(valid, ((0, 0), (0, pad)))
    hb = hidden.reshape(B, n, c, d).transpose(1, 0, 2, 3)
    lb = labels.reshape(B, n, c).transpose(1, 0, 2)
    vb = valid.reshape(B, n, c).transpose(1, 0, 2)
    vocab_ok = (jnp.arange(Vp) < vocab_size)

    vocab_ids = jnp.arange(Vp)

    def body(carry, xs):
        loss_sum, n_valid = carry
        h, lbl, ok = xs
        logits = jnp.einsum("bsd,dv->bsv", h, w_out.astype(h.dtype)
                            ).astype(jnp.float32)
        logits = jnp.where(vocab_ok, logits, NEG_INF)
        # vocab-parallel-safe lse and gold: only elementwise ops + reductions
        # over the (possibly model-sharded) vocab dim — XLA reduces locally
        # then inserts small (B,c)-sized all-reduces. (take_along_axis here
        # partitions catastrophically: full-logit gathers.)
        mx = jnp.max(logits, axis=-1)
        lse = mx + jnp.log(jnp.sum(jnp.exp(logits - mx[..., None]), axis=-1))
        gold_mask = vocab_ids[None, None, :] == lbl[..., None]
        gold = jnp.sum(jnp.where(gold_mask, logits, 0.0), axis=-1)
        nll = (lse - gold) * ok.astype(jnp.float32)
        return (loss_sum + jnp.sum(nll),
                n_valid + jnp.sum(ok.astype(jnp.float32))), None

    (loss_sum, n_valid), _ = lax.scan(
        jax.checkpoint(body),
        (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.float32)),
        (hb, lb, vb))
    return loss_sum, n_valid
