"""Mixture-of-Experts FFN: GShard-style grouped top-k dispatch.

Tokens are processed in groups of ``moe_group_size``; within each group a
capacity-bounded one-hot dispatch tensor routes tokens to experts. This keeps
the (G, E, C) dispatch tensors small and SPMD-friendly — experts shard on the
"model" mesh axis, groups follow the batch sharding, and XLA inserts the
dispatch all-to-all/all-gather. Dropless within capacity_factor; overflow
tokens fall through on the residual path (standard Switch behaviour).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init


def init_moe(cfg, key, dtype=jnp.float32):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": dense_init(ks[0], (d, e), d, jnp.float32),  # router in f32
        "w_gate": dense_init(ks[1], (e, d, f), d, dtype),
        "w_up": dense_init(ks[2], (e, d, f), d, dtype),
        "w_down": dense_init(ks[3], (e, f, d), f, dtype),
    }
    return p


def expert_capacity(cfg, group: int) -> int:
    cap = int(group * cfg.top_k / cfg.num_experts * cfg.capacity_factor)
    return max(cap, cfg.top_k)


def _route_group(p, xg, cfg):
    """One token group: xg (G, d) -> (out (G, d), aux metrics)."""
    G, d = xg.shape
    E, K = cfg.num_experts, cfg.top_k
    C = expert_capacity(cfg, G)

    logits = jnp.einsum("gd,de->ge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (G, E)
    gate_vals, idx = jax.lax.top_k(probs, K)                     # (G, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)             # renormalize

    onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)           # (G, K, E)
    # position of each (token, k) entry within its expert queue: priority by
    # k slot first (all first-choices before second-choices), then token order
    flat = onehot.transpose(1, 0, 2).reshape(K * G, E)           # (K*G, E)
    pos = jnp.cumsum(flat, axis=0) - flat                        # (K*G, E)
    pos = pos.reshape(K, G, E).transpose(1, 0, 2)                # (G, K, E)
    pos_in_expert = jnp.sum(pos * onehot, axis=-1)               # (G, K)
    fits = pos_in_expert < C
    kept = onehot * fits[..., None]                              # (G, K, E)

    pos_onehot = jax.nn.one_hot(pos_in_expert.astype(jnp.int32), C,
                                dtype=jnp.float32)               # (G,K,C)
    # dispatch (G, E, C) / combine (G, E, C)
    dispatch = jnp.einsum("gke,gkc->gec", kept, pos_onehot)
    combine = jnp.einsum("gke,gkc,gk->gec", kept, pos_onehot, gate_vals)

    cd = xg.dtype
    expert_in = jnp.einsum("gec,gd->ecd", dispatch.astype(cd), xg)  # (E,C,d)
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(cd))
    out = jnp.einsum("gec,ecd->gd", combine.astype(cd), out_e)

    # Switch aux losses: load balance + router z-loss
    density = jnp.mean(onehot[:, 0, :], axis=0)                  # top-1 density
    density_proxy = jnp.mean(probs, axis=0)
    lb_loss = jnp.sum(density * density_proxy) * (E ** 2) / E
    z_loss = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    dropped = 1.0 - jnp.sum(kept) / (G * K)
    return out, (lb_loss, z_loss, dropped)


def moe_apply(p, x, cfg):
    """x (B, S, d) -> (out (B, S, d), aux dict). Groups follow batch sharding.
    Ragged token counts are zero-row padded up to a group multiple (padded
    rows route but their outputs are discarded)."""
    B, S, d = x.shape
    n_tokens = B * S
    G = min(cfg.moe_group_size, n_tokens)
    flat = x.reshape(n_tokens, d)
    pad = (-n_tokens) % G
    if pad:
        flat = jnp.pad(flat, ((0, pad), (0, 0)))
    xg = flat.reshape(-1, G, d)
    out, (lb, zl, dr) = jax.vmap(lambda t: _route_group(p, t, cfg))(xg)
    out = out.reshape(-1, d)
    if pad:
        out = out[:n_tokens]
    aux = {"moe_lb_loss": jnp.mean(lb), "moe_z_loss": jnp.mean(zl),
           "moe_dropped": jnp.mean(dr)}
    return out.reshape(B, S, d), aux


def moe_apply_dropless(p, x, cfg):
    """Serve-time routing: per-token top-k with no capacity coupling.

    Every token independently picks its top-k experts and combines their
    outputs under renormalized gates — no grouping, no position-in-expert
    queue, no capacity drops — so a token's output is a function of that
    token's hidden state alone. That is the chunk-parity property the
    continuous engine needs: splitting a prompt at any chunk boundary, or
    batching it with any set of neighbours, cannot change its routing.

    Capacity-vs-parity tradeoff: without the capacity bound every expert
    runs on every token (the combine zero-weights the non-selected ones),
    costing num_experts/top_k x the grouped FLOPs and giving up the
    (G, E, C) all-to-all layout. Serving pays that for token-identical
    chunked prefill; training keeps :func:`moe_apply` for the
    capacity-bounded, load-balanced (aux-loss) regime.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.top_k
    flat = x.reshape(B * S, d)
    logits = jnp.einsum("td,de->te", flat.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                      # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, K)                     # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, -1, keepdims=True), 1e-9)             # renormalize
    weights = jnp.sum(jax.nn.one_hot(idx, E, dtype=jnp.float32)
                      * gate_vals[..., None], axis=1)            # (T, E)
    cd = x.dtype
    g = jnp.einsum("td,edf->tef", flat, p["w_gate"].astype(cd))
    u = jnp.einsum("td,edf->tef", flat, p["w_up"].astype(cd))
    h = jax.nn.silu(g) * u
    out_e = jnp.einsum("tef,efd->ted", h, p["w_down"].astype(cd))
    out = jnp.einsum("te,ted->td", weights.astype(cd), out_e)
    aux = {"moe_lb_loss": jnp.zeros(()), "moe_z_loss": jnp.zeros(()),
           "moe_dropped": jnp.zeros(())}
    return out.reshape(B, S, d), aux
