"""Mamba2 (SSD — state-space duality) block, pure JAX.

Implements the chunked SSD algorithm of arXiv:2405.21060: intra-chunk
quadratic (attention-like) term + inter-chunk linear state recurrence via
``lax.scan``, O(S · chunk) memory. ngroups is fixed to 1 (all assigned
configs). ``repro.kernels.ssd_scan`` holds the Pallas TPU version of the
chunk kernel; this file is the oracle and the backend-portable path.

Decode maintains O(1) state: (conv_state (B, k-1, conv_dim),
ssm_state (B, H, P, N)).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init


def init_ssm(cfg, key, dtype=jnp.float32):
    d, di, n, h = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n  # x, B, C are conv'd together (mamba2 convention)
    ks = jax.random.split(key, 5)
    # dt bias init so softplus(dt_bias) spans [1e-3, 1e-1] (mamba default)
    u = jax.random.uniform(ks[3], (h,), jnp.float32)
    dt = jnp.exp(u * (jnp.log(0.1) - jnp.log(1e-3)) + jnp.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))  # inverse softplus
    return {
        "in_proj": dense_init(ks[0], (d, 2 * di + 2 * n + h), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.arange(1, h + 1, dtype=jnp.float32)),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": dt_bias.astype(jnp.float32),
        "gate_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[4], (di, d), di, dtype),
    }


def _segsum(x):
    """x (..., L) -> (..., L, L): S[i,j] = sum_{k=j+1..i} x[k], -inf above diag."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool))
    return jnp.where(mask, seg, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, initial_state=None):
    """Chunked SSD scan.

    xh (b,s,h,p): per-head inputs (already multiplied by nothing; dt applied
    here); dt (b,s,h) — positive rates; A (h,) — negative decay;
    Bm, Cm (b,s,n) — shared across heads (ngroups=1).
    Returns (y (b,s,h,p), final_state (b,h,p,n)).
    """
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    pad = (-s) % chunk
    if pad:
        # identity-pad ragged sequences: dt=0 makes the padded steps exact
        # no-ops on the state (decay exp(0)=1, contribution dt·x·B=0)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        s_out = s
        s = s + pad
    else:
        s_out = s
    c = s // chunk

    xd = (xh * dt[..., None]).reshape(b, c, chunk, h, p)
    dA = (dt * A).reshape(b, c, chunk, h).transpose(0, 3, 1, 2)  # (b,h,c,l)
    Bc = Bm.reshape(b, c, chunk, n)
    Cc = Cm.reshape(b, c, chunk, n)

    dA_cum = jnp.cumsum(dA, axis=-1)                             # (b,h,c,l)
    # 1) intra-chunk (quadratic within chunk)
    L = jnp.exp(_segsum(dA))                                     # (b,h,c,l,l)
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", Cc, Bc, L, xd)
    # 2) chunk-local states (contribution of each chunk to the running state)
    decay_states = jnp.exp(dA_cum[..., -1:] - dA_cum)            # (b,h,c,l)
    states = jnp.einsum("bcln,bhcl,bclhp->bchpn", Bc, decay_states, xd)
    # 3) inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cum[..., -1])                       # (b,h,c)

    def step(st, inp):
        s_c, dec = inp                                           # (b,h,p,n),(b,h)
        new = st * dec[..., None, None] + s_c
        return new, st                                           # emit PREVIOUS

    init = (jnp.zeros((b, h, p, n), xh.dtype) if initial_state is None
            else initial_state.astype(xh.dtype))
    final, prev_states = lax.scan(
        step, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)           # (b,c,h,p,n)
    # 4) state -> output within chunk
    state_decay = jnp.exp(dA_cum)                                # (b,h,c,l)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y[:, :s_out], final


def _causal_conv(x, w, b):
    """Depthwise causal conv1d. x (B,S,C), w (k,C), b (C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_k w[k] * x[t-k+1+i] — small k (4): unrolled adds, XLA fuses
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def ssm_apply(p, x, cfg, initial_state=None, return_state=False):
    """Full-sequence SSD block. x (B,S,d) -> (B,S,d)."""
    B, S, d = x.shape
    di, n, h, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = x.dtype

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z, xbc, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"].astype(cd),
                                   p["conv_b"].astype(cd)))
    xs, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"])                         # (B,S,h)
    A = -jnp.exp(p["A_log"])                                     # (h,)

    xh = xs.reshape(B, S, h, hp).astype(jnp.float32)
    # FIXED inner chunk, never shrunk to S: exp(a)·exp(b) != exp(a+b)
    # bitwise, so the chunked scan only composes exactly across engine
    # chunk boundaries when the inner ssd chunk grid is anchored at
    # position 0 globally (ssd_chunked identity-pads ragged tails)
    y, final = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                           Cm.astype(jnp.float32), cfg.ssm_chunk,
                           initial_state=initial_state)
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, S, di).astype(cd)
    # gated RMSNorm (mamba2)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * lax.rsqrt(var + 1e-5) * p["gate_norm"].astype(jnp.float32)
         ).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    if return_state:
        k = cfg.ssm_conv
        # conv state: last k-1 pre-activation xbc inputs
        zxbc_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)[1]
        conv_state = zxbc_raw[:, -(k - 1):, :] if S >= k - 1 else jnp.pad(
            zxbc_raw, ((0, 0), (k - 1 - S, 0), (0, 0)))
        return out, {"conv": conv_state.astype(cd), "ssm": final}
    return out


def ssm_apply_chunk(p, x, cfg, state, n_valid):
    """Chunk-resumed SSD block: one engine prefill chunk, bit-exact with
    the matching slice of :func:`ssm_apply` over the whole prompt.

    x (B, C, d) — the chunk's hidden states (tail rows may be padding);
    state — the carried-state pytree {conv (B, k-1, conv_dim) raw
    pre-activation xbc rows of the valid prefix, ssm (B, h, p, n)} from
    the previous chunk (all-zero at position 0 — identical to the
    monolithic left zero-pad / zero initial state); n_valid (B,) — valid
    rows in this chunk. Returns (out (B, C, d), new state).

    Exactness requires the caller to split prompts at multiples of
    ``cfg.ssm_chunk`` (the engine's ``chunk_multiple`` capability): the
    inner ssd chunk grid then lands on the same global boundaries as the
    monolithic scan, so every decay product is the same float sequence.
    """
    B, C, d = x.shape
    di, n, h, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = x.dtype
    k = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"].astype(cd))
    z, xbc_raw, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    valid = (jnp.arange(C)[None, :] < n_valid[:, None])          # (B, C)

    # conv with the carried window as left context (zeros at position 0
    # == the monolithic zero pad; same unrolled-adds order as
    # _causal_conv so the first chunk is bit-identical)
    window = jnp.concatenate([state["conv"].astype(cd), xbc_raw], axis=1)
    w = p["conv_w"].astype(cd)
    xbc = sum(window[:, i:i + C, :] * w[i] for i in range(k))
    xbc = jax.nn.silu(xbc + p["conv_b"].astype(cd))
    xs, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    # softplus is always > 0: padding rows must be masked explicitly so
    # they are exact no-ops on the state (decay exp(0)=1, contribution 0)
    dt = jnp.where(valid[..., None], dt, 0.0)                    # (B,C,h)
    A = -jnp.exp(p["A_log"])

    xh = xs.reshape(B, C, h, hp).astype(jnp.float32)
    y, final = ssd_chunked(xh, dt, A, Bm.astype(jnp.float32),
                           Cm.astype(jnp.float32), cfg.ssm_chunk,
                           initial_state=state["ssm"])
    y = y + xh * p["D"][None, None, :, None]
    y = y.reshape(B, C, di).astype(cd)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * lax.rsqrt(var + 1e-5) * p["gate_norm"].astype(jnp.float32)
         ).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))

    # new conv state: the k-1 raw rows ending at the last VALID position
    # (window index n_valid-1 is absolute position pos0+n_valid-1); for
    # an all-padding row (n_valid == 0) this reproduces the old state
    idx = n_valid[:, None] + jnp.arange(k - 1)[None, :]          # (B, k-1)
    new_conv = jnp.take_along_axis(window, idx[..., None], axis=1)
    return out, {"conv": new_conv.astype(state["conv"].dtype), "ssm": final}


def ssm_decode_step(p, x1, state, cfg):
    """Single-token decode. x1 (B,1,d); state {conv (B,k-1,conv_dim),
    ssm (B,h,p,n)} -> (out (B,1,d), new state)."""
    B = x1.shape[0]
    di, n, h, hp = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    cd = x1.dtype
    k = cfg.ssm_conv

    zxbcdt = jnp.einsum("bsd,de->bse", x1, p["in_proj"].astype(cd))
    z, xbc_new, dt_raw = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    # roll conv window: state holds previous k-1 raw xbc rows
    window = jnp.concatenate([state["conv"], xbc_new], axis=1)   # (B,k,conv)
    w = p["conv_w"].astype(cd)
    xbc = sum(window[:, i, :] * w[i] for i in range(k)) + p["conv_b"].astype(cd)
    xbc = jax.nn.silu(xbc)[:, None, :]                           # (B,1,conv)
    xs, Bm, Cm = jnp.split(xbc, [di, di + n], axis=-1)

    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                                         # (B,h)
    xh = xs[:, 0].reshape(B, h, hp).astype(jnp.float32)
    Bv = Bm[:, 0].astype(jnp.float32)                            # (B,n)
    Cv = Cm[:, 0].astype(jnp.float32)
    ssm = state["ssm"].astype(jnp.float32)
    ssm = (ssm * dA[..., None, None]
           + jnp.einsum("bhp,bn->bhpn", xh * dt[..., None], Bv))
    y = jnp.einsum("bhpn,bn->bhp", ssm, Cv) + xh * p["D"][None, :, None]
    y = y.reshape(B, 1, di).astype(cd)
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    y = (yf * lax.rsqrt(var + 1e-5) * p["gate_norm"].astype(jnp.float32)
         ).astype(cd)
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"].astype(cd))
    new_state = {"conv": window[:, 1:, :], "ssm": ssm.astype(state["ssm"].dtype)}
    return out, new_state


def init_ssm_state(cfg, batch: int, dtype):
    di, n = cfg.ssm_d_inner, cfg.ssm_state
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * n), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, n),
                         jnp.float32),
    }
