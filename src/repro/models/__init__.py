"""Model zoo: config-driven transformer / MoE / SSM / hybrid / enc-dec LMs."""

from repro.models.registry import build_model  # noqa: F401
