"""Explicit (threadcomm) trainer: the paper's technique as a first-class
training feature, expressed through the unified ``Comm`` API.

The fwd/bwd runs inside a shard_map that is MANUAL over the unified data-
parallel rank space — process axes ("pod") × thread axes ("data") — exactly
the threadcomm construction: every (pod, data) coordinate is one unified
rank computing local gradients. Tensor parallelism ("model") stays auto.

Gradient sync is the paper's two-level hierarchical schedule, built from
DERIVED sub-communicators (DESIGN.md §2) and FUSED with a ZeRO-1 flat
optimizer:

    flat_g   = concat(all grad leaves)               # one flat f32 vector
    shard    = thread_comm.reduce_scatter(flat_g)    # fast domain (ICI)
    req      = process_comm.iallreduce(shard)        # slow domain, bytes/M,
                                                     #   issued on the "grad"
                                                     #   CommStream
    ... step bookkeeping overlaps the slow-domain sync ...
    shard    = req.wait()
    shard'   = AdamW(shard)                          # state lives as shards
    params   = unflatten(thread_comm.allgather(shard'))   # fast domain

so the inter-pod (slow) traffic is params/M bytes — the paper's "do the bulk
in the fast shared domain" insight — optimizer state is sharded over the
thread domain for free (ZeRO-1), and the slow-domain allreduce is a
nonblocking Request the step overlaps with local work (the MPIX-stream
pattern of arXiv:2208.13707).

grad_sync="flat" keeps the same state layout but reduces the FULL flat
vector over the root comm (process × thread) before slicing — the rank-
unaware MPI-everywhere baseline the paper compares against.

The root comm is activated in service mode (``comm.start()`` without a
``with``): the trainer is a long-lived parallel region, and the traced
requests/sub-comms stay inside its activation window.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, TrainConfig
from repro.core.comm import threadcomm_init
from repro.core.compat import HAS_PARTIAL_MANUAL, shard_map
from repro.dist.sharding import batch_pspec, named_sharding, param_pspecs
from repro.optim import cosine_schedule


class FlatAdamState(NamedTuple):
    step: jax.Array
    m: jax.Array        # (padded_len/DP,) f32 shard
    v: jax.Array
    master: jax.Array   # f32 master shard


class ExplicitTrainState(NamedTuple):
    params: Any         # model dtype, replicated over (pod, data), TP on model
    opt: FlatAdamState


def _tree_sizes(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return [int(np.prod(l.shape)) for l in leaves]


def flatten_tree(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([l.reshape(-1).astype(jnp.float32)
                            for l in leaves])


def unflatten_like(flat, tree, dtype_from_tree=True):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    out, off = [], 0
    for l in leaves:
        n = int(np.prod(l.shape))
        piece = flat[off:off + n].reshape(l.shape)
        out.append(piece.astype(l.dtype) if dtype_from_tree else piece)
        off += n
    return treedef.unflatten(out)


def padded_len(tree, dp: int) -> int:
    n = sum(_tree_sizes(tree))
    return ((n + dp - 1) // dp) * dp


def init_explicit_state(model, key, dp: int) -> ExplicitTrainState:
    params = model.init(key)
    plen = padded_len(params, dp)
    flat = flatten_tree(params)
    flat = jnp.pad(flat, (0, plen - flat.size))
    # host-side: full flat vector; jit in_shardings scatter it over "data"
    return ExplicitTrainState(
        params=params,
        opt=FlatAdamState(step=jnp.zeros((), jnp.int32),
                          m=jnp.zeros((plen,), jnp.float32),
                          v=jnp.zeros((plen,), jnp.float32),
                          master=flat))


def make_explicit_train_step(model, mesh_cfg: MeshConfig, tcfg: TrainConfig,
                             mesh: jax.sharding.Mesh):
    cfg = model.cfg
    lr_fn = cosine_schedule(tcfg.learning_rate, tcfg.warmup_steps,
                            tcfg.total_steps)
    proc_axes = tuple(mesh_cfg.process_axes)
    thread_axes = tuple(mesh_cfg.batch_axes)
    dp_axes = proc_axes + thread_axes

    # the root communicator over the unified DP rank space; thread_comm /
    # process_comm are the load-bearing derived sub-comms of the two-level
    # schedule. Service-mode activation: the trainer IS the parallel region.
    comm = threadcomm_init(mesh, process_axes=proc_axes,
                           thread_axes=thread_axes)
    comm.start()
    tcomm = comm.thread_comm()
    pcomm = comm.process_comm()
    dp = comm.size
    m_thread = comm.threads_per_process
    wire = (jnp.bfloat16 if tcfg.grad_comm_dtype == "bfloat16" else None)

    def inner(state: ExplicitTrainState, batch):
        (loss, metrics), grads = jax.value_and_grad(
            model.train_loss, has_aux=True)(state.params, batch)
        flat_g = flatten_tree(grads)
        plen = state.opt.master.size * m_thread  # global padded length
        flat_g = jnp.pad(flat_g, (0, plen - flat_g.size))

        opt = state.opt
        step = opt.step + 1

        if tcfg.grad_sync == "flat":
            # rank-unaware: full bytes cross every domain, then local slice
            full = comm.allreduce(flat_g) / dp
            rank = tcomm.local_rank()
            shard_len = plen // m_thread
            g_shard = lax.dynamic_slice_in_dim(full, rank * shard_len,
                                               shard_len)
        else:  # "threadcomm": hierarchical two-level via derived sub-comms
            g_shard = (tcomm.reduce_scatter(flat_g)
                       if tcomm.size > 1 else flat_g)
            if pcomm.size > 1:
                # nonblocking slow-domain sync on the "grad" stream; the
                # wire dtype compresses inter-pod bytes (level-1 gradient
                # compression). Only this stream orders against the
                # request — everything between issue and wait() may
                # overlap the inter-pod transfer.
                with comm.stream("grad"):
                    req = pcomm.iallreduce(g_shard, wire_dtype=wire)
                g_shard = req.wait()
            g_shard = g_shard / dp

        # global grad-norm from shards (for clipping)
        gn2 = jnp.sum(jnp.square(g_shard))
        if tcomm.size > 1:
            gn2 = tcomm.allreduce(gn2)
        gnorm = jnp.sqrt(gn2)
        scale = jnp.where(tcfg.grad_clip > 0,
                          jnp.minimum(1.0, tcfg.grad_clip / (gnorm + 1e-9)),
                          1.0)

        # fused flat AdamW on the shard (ZeRO-1)
        t = step.astype(jnp.float32)
        g = g_shard * scale
        m = tcfg.beta1 * opt.m + (1 - tcfg.beta1) * g
        v = tcfg.beta2 * opt.v + (1 - tcfg.beta2) * jnp.square(g)
        mhat = m / (1 - tcfg.beta1 ** t)
        vhat = v / (1 - tcfg.beta2 ** t)
        lr = lr_fn(opt.step)
        new_master = opt.master - lr * (
            mhat / (jnp.sqrt(vhat) + tcfg.eps)
            + tcfg.weight_decay * opt.master)

        # fast-domain allgather of the UPDATED parameters (cast first: move
        # bf16, not f32 — half the intra-pod bytes)
        cast = new_master.astype(
            jax.tree_util.tree_leaves(state.params)[0].dtype)
        full_new = (tcomm.allgather(cast, tiled=True)
                    if tcomm.size > 1 else cast)
        new_params = unflatten_like(full_new.astype(jnp.float32),
                                    state.params)

        metrics = {**metrics, "grad_norm": gnorm, "lr": lr}
        metrics = jax.tree_util.tree_map(
            lambda x: comm.allreduce(x) / dp, metrics)
        new_state = ExplicitTrainState(
            params=new_params,
            opt=FlatAdamState(step=step, m=m, v=v, master=new_master))
        return new_state, metrics

    # manual over the unified DP rank space; "model" stays auto (TP) where
    # the jax/XLA stack supports partial-manual regions. Old XLA miscompiles
    # all-gather/ppermute inside manual subgroups, so there we take the
    # whole mesh manual: TP-degree-redundant compute, identical numerics.
    shard_spec = P(thread_axes) if thread_axes else P()
    state_in_specs = ExplicitTrainState(
        params=jax.tree_util.tree_map(lambda _: P(), model_params_struct(model)),
        opt=FlatAdamState(step=P(), m=shard_spec, v=shard_spec,
                          master=shard_spec))
    manual_axes = set(dp_axes) if HAS_PARTIAL_MANUAL else None
    mapped = shard_map(
        inner, mesh=mesh, axis_names=manual_axes,
        in_specs=(state_in_specs, P(dp_axes)),
        out_specs=(state_in_specs, P()), check_vma=False)

    # jit-level shardings: TP over "model" via the (FSDP-free) param rules
    tp_mesh_cfg = dataclasses.replace(mesh_cfg, batch_axes=())
    sample = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    tp_specs = param_pspecs(cfg, tp_mesh_cfg, sample)
    st_shard = ExplicitTrainState(
        params=named_sharding(mesh, tp_specs),
        opt=FlatAdamState(
            step=NamedSharding(mesh, P()),
            m=NamedSharding(mesh, shard_spec),
            v=NamedSharding(mesh, shard_spec),
            master=NamedSharding(mesh, shard_spec)))
    b_shard = NamedSharding(mesh, batch_pspec(mesh_cfg))
    return jax.jit(mapped, in_shardings=(st_shard, b_shard),
                   out_shardings=(st_shard, None), donate_argnums=(0,))


def model_params_struct(model):
    return jax.eval_shape(model.init, jax.random.PRNGKey(0))
