"""Checkpointing: atomic, keep-last-k, elastic (mesh-shape-agnostic), with
optional async save.

Format: one directory per step, ``step_<n>/arrays.npz`` + ``meta.json``.
Arrays are stored by tree-path name with logical (unsharded) shapes, so a
checkpoint written on a 1×8 mesh restores onto a 2×4 (or any) mesh — the
elastic re-mesh that realizes the paper's "dynamically create and shrink
[the parallel environment]" (§6) for training jobs. Writes go to a tmp dir
then ``os.replace`` (atomic on POSIX): a killed job can never leave a
half-written step visible.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import numpy as np


def _path_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save(ckpt_dir: str, step: int, tree: Any, *, extra: Dict = None,
         keep: int = 3, async_save: bool = False):
    """Snapshot ``tree`` (any pytree of arrays) at ``step``."""
    leaves_with_paths, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {}
    for path, leaf in leaves_with_paths:
        # pull to host; works for sharded jax.Arrays too
        arrays[_path_name(path)] = np.asarray(jax.device_get(leaf))
    meta = {"step": int(step), "extra": extra or {},
            "names": sorted(arrays)}

    def _write():
        os.makedirs(ckpt_dir, exist_ok=True)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)           # atomic publish
        _cleanup(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, template: Any, *, step: int = None,
            shardings: Any = None):
    """Restore into the structure of ``template`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching pytree of
    NamedShardings — pass shardings built from a NEW mesh to re-shard the
    checkpoint elastically. Returns (tree, step, extra)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "meta.json")) as f:
        meta = json.load(f)
    npz = np.load(os.path.join(d, "arrays.npz"))

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves_with_paths))
    out = []
    for (path, leaf), shard in zip(leaves_with_paths, shard_leaves):
        name = _path_name(path)
        if name not in npz:
            raise KeyError(f"checkpoint missing leaf {name}")
        arr = npz[name]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {name}: ckpt {arr.shape} vs "
                f"template {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        out.append(jax.device_put(arr, shard) if shard is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), step, meta["extra"]
