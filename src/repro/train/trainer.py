"""Production trainer: pjit train_step with three gradient-sync modes.

The threadcomm technique enters here (DESIGN.md §2): the "pod" mesh axis is
the paper's process domain, intra-pod axes are the thread domain.

  grad_sync="spmd"        XLA-inserted collectives end to end (baseline).
  grad_sync="threadcomm"  explicit trainer over the unified ``Comm`` API
                          (train/explicit.py): the root ThreadComm's derived
                          thread_comm/process_comm sub-communicators compose
                          the two-level hierarchical schedule — fast-domain
                          reduce_scatter, then a nonblocking slow-domain
                          ``iallreduce`` Request on a CommStream moving only
                          params/M bytes inter-pod, overlapped with step
                          bookkeeping, then fast-domain allgather.
  grad_sync="flat"        deliberately rank-unaware baseline (MPI-everywhere
                          analogue): one root-comm allreduce of the FULL
                          flat gradient across every domain.

Fault-tolerance hooks: the step function is pure; checkpoint.py snapshots
(params, opt, data step) atomically, restores onto any mesh (elastic).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig, TrainConfig
from repro.dist.sharding import batch_pspec, named_sharding, param_pspecs
from repro.optim import adamw_init, adamw_update, cosine_schedule


class TrainState(NamedTuple):
    params: Any
    opt: Any


def init_train_state(model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def state_pspecs(cfg: ModelConfig, mesh_cfg: MeshConfig, state: TrainState,
                 moe_fsdp: bool = True, fsdp: bool = True):
    """Optimizer state mirrors parameter sharding (ZeRO via FSDP specs)."""
    pspec = param_pspecs(cfg, mesh_cfg, state.params, moe_fsdp=moe_fsdp,
                         fsdp=fsdp)
    mirror = lambda tree: (None if tree is None else pspec)
    return TrainState(
        params=pspec,
        opt=type(state.opt)(step=P(), m=pspec, v=pspec,
                            master=mirror(state.opt.master)))


def make_train_step(model, mesh_cfg: MeshConfig, tcfg: TrainConfig,
                    mesh: jax.sharding.Mesh = None):
    """Build the (jit-able, donation-friendly) train step. When ``mesh`` is
    given, returns a jit'd function with explicit in/out shardings; otherwise
    a plain function (single-device tests)."""
    cfg = model.cfg
    lr_fn = cosine_schedule(tcfg.learning_rate, tcfg.warmup_steps,
                            tcfg.total_steps)
    def loss_and_grads(params, batch):
        k = tcfg.microbatches
        if k <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                model.train_loss, has_aux=True)(params, batch)
            return loss, metrics, grads

        # gradient accumulation: scan over k microbatches; grads accumulate
        # in f32 at parameter sharding; activations live one microbatch at
        # a time (the standard big-model memory lever)
        mb = jax.tree_util.tree_map(
            lambda x: x.reshape(k, x.shape[0] // k, *x.shape[1:]), batch)

        def body(acc, b):
            (loss, metrics), grads = jax.value_and_grad(
                model.train_loss, has_aux=True)(params, b)
            acc = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), acc, grads)
            return acc, (loss, metrics)

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        grads, (losses, metricss) = jax.lax.scan(body, zeros, mb)
        grads = jax.tree_util.tree_map(lambda g: g / k, grads)
        metrics = jax.tree_util.tree_map(jnp.mean, metricss)
        return jnp.mean(losses), metrics, grads

    def apply_updates(state: TrainState, grads, metrics):
        lr = lr_fn(state.opt.step)
        new_params, new_opt, om = adamw_update(
            grads, state.opt, state.params, lr=lr, beta1=tcfg.beta1,
            beta2=tcfg.beta2, eps=tcfg.eps, weight_decay=tcfg.weight_decay,
            grad_clip=tcfg.grad_clip)
        return TrainState(new_params, new_opt), {**metrics, **om}

    if tcfg.grad_sync in ("threadcomm", "flat") and mesh is not None:
        # explicit threadcomm trainer: manual over the unified DP rank
        # space with the hierarchical (or naive-flat) schedule fused into a
        # ZeRO-1 flat optimizer — see train/explicit.py
        from repro.train.explicit import make_explicit_train_step
        return make_explicit_train_step(model, mesh_cfg, tcfg, mesh)

    def step_fn(state: TrainState, batch):
        _, metrics, grads = loss_and_grads(state.params, batch)
        return apply_updates(state, grads, metrics)

    if mesh is None:
        return step_fn

    sample_state = jax.eval_shape(
        lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
    st_specs = state_pspecs(cfg, mesh_cfg, sample_state,
                            moe_fsdp=tcfg.moe_fsdp, fsdp=tcfg.fsdp)
    st_shard = named_sharding(mesh, st_specs)
    b_shard = NamedSharding(mesh, batch_pspec(mesh_cfg))
    return jax.jit(step_fn,
                   in_shardings=(st_shard, b_shard),
                   out_shardings=(st_shard, None),
                   donate_argnums=(0,))


def make_eval_step(model, mesh_cfg: MeshConfig, mesh=None):
    def eval_step(params, batch):
        _, metrics = model.train_loss(params, batch)
        return metrics
    if mesh is None:
        return eval_step
    return jax.jit(eval_step)
