from repro.train.trainer import TrainState, make_train_step, init_train_state  # noqa: F401
from repro.train import checkpoint  # noqa: F401
