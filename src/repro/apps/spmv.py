"""PETSc case study (paper §4.3): 27-point stencil SpMV (MatMult) over a
threadcomm.

The paper drives PETSc's MatMult from an OpenMP parallel region through a
threadcomm and matches/beats MPI-everywhere (Fig. 6; 27-point stencil on a
128³ cube). Here the matrix-free stencil operator is decomposed in slabs
along z over the unified threadcomm ranks; the halo exchange is the
rank-addressed p2p of repro.core.p2p (eager cells — one boundary plane is
n² × 4B, comfortably a few cells).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import p2p

# 27-point stencil weights: center 26, all 26 neighbours -1 (a standard
# 3D Laplacian-like operator; SPD up to boundary effects).
_CENTER = 26.0
_NEIGHBOR = -1.0


def _apply_stencil(xp: jax.Array) -> jax.Array:
    """xp: (nz+2, ny, nx) with z-halos attached; zero-padded in y/x.
    Returns (nz, ny, nx)."""
    nz = xp.shape[0] - 2
    xp = jnp.pad(xp, ((0, 0), (1, 1), (1, 1)))
    out = None
    for dz in (0, 1, 2):
        for dy in (0, 1, 2):
            for dx in (0, 1, 2):
                w = _CENTER if (dz, dy, dx) == (1, 1, 1) else _NEIGHBOR
                blk = lax.dynamic_slice(
                    xp, (dz, dy, dx),
                    (nz, xp.shape[1] - 2, xp.shape[2] - 2)) * w
                out = blk if out is None else out + blk
    return out


def stencil_matmult_ref(x: jax.Array) -> jax.Array:
    """Single-device oracle. x: (n, n, n)."""
    xp = jnp.pad(x, ((1, 1), (0, 0), (0, 0)))
    return _apply_stencil(xp)


def make_distributed_matmult(axes, n_ranks: int):
    """MatMult over slab-decomposed x: per-rank (nz_local, ny, nx).
    Call inside shard_map/ThreadComm.run; halos via threadcomm p2p."""

    def matmult(x_local):
        rank = lax.axis_index(axes)
        from_left, from_right = p2p.halo_exchange_1d(x_local, axes, n_ranks)
        # non-periodic boundary: first/last slab see zero halos
        zero = jnp.zeros_like(from_left)
        left = jnp.where(rank == 0, zero, from_left)
        right = jnp.where(rank == n_ranks - 1, zero, from_right)
        xp = jnp.concatenate([left, x_local, right], axis=0)
        return _apply_stencil(xp)

    return matmult


def cg_solve_ref(b: jax.Array, iters: int = 20):
    """Few CG iterations against the stencil operator (oracle for the
    solver-style usage in the PETSc study)."""
    x = jnp.zeros_like(b)
    r = b - stencil_matmult_ref(x)
    p = r
    rs = jnp.vdot(r, r)
    for _ in range(iters):
        ap = stencil_matmult_ref(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        rs = rs_new
    return x
