from repro.apps.spmv import (stencil_matmult_ref, make_distributed_matmult,
                             cg_solve_ref)  # noqa: F401
