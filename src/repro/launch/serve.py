"""Serving traffic driver: arrival traces through the continuous-batching
engine vs the static-batch baseline (DESIGN.md §8).

Generates a Poisson/burst arrival trace, drives one or both engines over
it in wall-clock time, and reports per-request latency percentiles plus
useful-token throughput. With ``--json`` the measurements land in
``BENCH_serve.json`` (the CI serving artifact), including a verified
static-vs-continuous comparison row and a greedy parity check.

The continuous engine runs its prompt deposits *chunked* (fixed-size
chunk rows batched across requests, interleaved with decode micro-steps)
and, for comparison, once more with monolithic prefill — the artifact
records TTFT p50/p95 for both plus prefill compile counts on a
mixed-prompt-length trace (chunked compiles are independent of the number
of distinct prompt lengths; monolithic pays one XLA compile per length).
A further comparison run swaps the slot pool for the *paged* KV substrate
(DESIGN.md §9) at the exact same HBM budget and records bytes per
resident token, peak concurrency and trace-level token identity.

With ``--fabric replicated|disagg|both --ranks N`` the driver instead
runs the multi-rank serving fabric comparison (DESIGN.md §10): the same
trace through a single paged engine and through the router-dispatched
fabric under each placement policy, recording aggregate tok/s, TTFT
percentiles per policy, per-rank utilization, KV-migration pricing and
greedy token identity (``BENCH_fabric.json``, schema
``repro-serve-bench-v8``).

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --engine both --requests 12 --slots 4 --prompt-len 16,256 \
      --prefill-chunk 64 --max-new-lo 4 --max-new-hi 32 \
      --json BENCH_serve.json

``benchmarks/bench_serve.py`` imports :func:`run_traffic` for the bench
harness rows; this module stays the human-facing entry point.
"""

from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.obs import metrics as obs_metrics
from repro.obs import residuals as obs_residuals
from repro.obs import trace as obs_trace
from repro.serve import (ContinuousEngine, ServeRequest, ServingFabric,
                         StaticEngine, make_trace)

#: registry families the ``--config`` sweep covers by default: one per
#: serving structure (dense, MoE, SSM, hybrid, enc-dec) — every family
#: the state-threaded chunk contract (DESIGN.md §13) must carry
FAMILY_ARCHS = ("gemma-2b", "olmoe-1b-7b", "mamba2-370m", "hymba-1.5b",
                "whisper-tiny")


def effective_chunk(caps, prefill_chunk: int) -> int:
    """Capability-aware chunk size: floor to the family's
    ``chunk_multiple`` (SSM/hybrid scans resume bit-exactly only on
    ``ssm_chunk`` boundaries), never below one multiple; 0 (monolithic)
    when the family cannot chunk at all."""
    if prefill_chunk <= 0 or not caps.chunked_prefill:
        return 0
    m = max(1, int(caps.chunk_multiple))
    return max(m, (prefill_chunk // m) * m)


def useful_tokens(row: np.ndarray, eos_id: int) -> int:
    """Tokens a request actually produced: up to and including the first
    EOS (or the full row when EOS never fires / is disabled)."""
    if eos_id >= 0:
        hits = np.flatnonzero(row == eos_id)
        if hits.size:
            return int(hits[0]) + 1
    return int(row.size)


def requests_from_trace(cfg, trace, *, dtype: str = "float32",
                        seed: int = 0) -> List[ServeRequest]:
    """Materialize one ServeRequest per trace entry with a distinct
    synthetic prompt (seeded per request id).

    Entries carrying a ``prefix_group`` (shared-prefix traces — system
    prompt / few-shot template workloads) open with their group's
    template tokens: one synthetic template per group, sliced to each
    entry's ``prefix_len``; the suffix stays the entry's own random
    tokens. Deterministic in ``seed``, so two engines driven from the
    same trace see byte-identical prompts."""
    templates: Dict[int, np.ndarray] = {}
    longest: Dict[int, int] = {}
    for e in trace:
        g = getattr(e, "prefix_group", -1)
        if g >= 0 and e.prefix_len > 0:
            longest[g] = max(longest.get(g, 0), e.prefix_len)
    for g, plen in longest.items():
        tb = make_synthetic_batch(cfg, 1, plen, seed=seed + 131 + g,
                                  compute_dtype=dtype)
        templates[g] = np.asarray(tb["tokens"])
    reqs = []
    for rid, entry in enumerate(trace):
        batch = make_synthetic_batch(cfg, 1, entry.prompt_len,
                                     seed=seed + 1000 + rid,
                                     compute_dtype=dtype)
        prompt = {k: np.asarray(v) for k, v in batch.items() if k != "labels"}
        g = getattr(entry, "prefix_group", -1)
        if g >= 0 and entry.prefix_len > 0 and "tokens" in prompt:
            toks = prompt["tokens"].copy()
            toks[:, :entry.prefix_len] = templates[g][:, :entry.prefix_len]
            prompt["tokens"] = toks
        reqs.append(ServeRequest(rid=rid, batch=prompt,
                                 max_new_tokens=entry.max_new,
                                 temperature=entry.temperature,
                                 seed=seed, arrival=entry.arrival))
    return reqs


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------

def _drive_wall_clock(target, requests: List[ServeRequest]) -> float:
    """Shared wall-clock traffic loop over anything with the serving
    drive surface (``submit``/``step``/``idle`` — an engine or a
    fabric): submit each request at its arrival time, run micro-steps
    until everything drains, return the makespan in seconds."""
    pending = sorted(requests, key=lambda r: r.arrival)
    n, i = len(pending), 0
    done = 0
    t0 = time.perf_counter()
    while done < n:
        now = time.perf_counter() - t0
        while i < n and pending[i].arrival <= now:
            target.submit(pending[i], now)
            i += 1
        if target.idle and i < n:
            time.sleep(min(1e-3, max(0.0, pending[i].arrival - now)))
            continue
        done += len(target.step(time.perf_counter() - t0))
    return time.perf_counter() - t0


def _attach_telemetry(stats: Dict) -> None:
    """When the tracer is live (``REPRO_TRACE=1``), stamp the trial's
    residual report, flat per-hop ratios, and the serialization-stall
    total onto the stats dict. The capture is trial-clean because every
    warm-up boundary (``engine.reset`` / ``fabric.close``) flushes the
    ledger before the measured drive starts."""
    tr = obs_trace.active()
    if tr is None:
        return
    rep = tr.residuals.report()
    stats["residual_report"] = rep
    for kind, row in rep["hops"].items():
        if row["n"]:
            stats[f"residual_{kind}_ratio"] = row["ratio"]
    stats["serialization_stall_s"] = rep["serialization_stall_s"]


def drive_continuous(eng: ContinuousEngine, requests: List[ServeRequest]
                     ) -> Dict[str, float]:
    """Wall-clock traffic loop through one continuous engine. Stats come
    from the one merged surface (:func:`repro.obs.metrics.snapshot`):
    latency percentiles, KV/prefix/spec accounting, and — when the
    registry is live — its counters/gauges/histograms."""
    makespan = _drive_wall_clock(eng, requests)
    toks = sum(useful_tokens(r.output[:r.generated], eng.eos_id)
               for r in requests)
    stats = obs_metrics.snapshot(engine=eng)
    stats.update(makespan_s=makespan, useful_tokens=float(toks),
                 tok_s=toks / makespan,
                 eager_admits=float(eng.scheduler.n_eager_admits),
                 deferred=float(eng.scheduler.n_deferred),
                 modeled_admit_cost_us=1e6
                 * eng.scheduler.modeled_admit_cost_s)
    _attach_telemetry(stats)
    return stats


def drive_static(eng: StaticEngine, requests: List[ServeRequest],
                 batch_size: int) -> Dict[str, float]:
    """Static-batch baseline: wait for ``batch_size`` arrivals, prefill
    them together, decode the whole batch to the slowest member. Requests
    are bucketed by prompt length (a static batch needs rectangular
    prompts), batches form FIFO within a bucket and run in order of their
    last member's arrival. The last partial batch is padded (repeat of
    its final row) so the jit shapes stay fixed; padding rows are not
    counted. Sampling is per-row — a mixed-temperature group samples each
    request at its own temperature; heterogeneous seeds in one group
    cannot be honored by the shared key chain and raise."""
    reqs = sorted(requests, key=lambda r: r.arrival)
    n = len(reqs)
    buckets: Dict[int, List[ServeRequest]] = {}
    for r in reqs:
        buckets.setdefault(r.prompt_len, []).append(r)
    groups = [rs[start:start + batch_size]
              for rs in buckets.values()
              for start in range(0, len(rs), batch_size)]
    groups.sort(key=lambda g: max(r.arrival for r in g))
    t0 = time.perf_counter()
    for group in groups:
        latest = max(r.arrival for r in group)
        while time.perf_counter() - t0 < latest:
            time.sleep(1e-3)
        seeds = {r.seed for r in group}
        if len(seeds) > 1:
            raise ValueError("drive_static: heterogeneous seeds in one "
                             f"static batch group: {sorted(seeds)}")
        rows = [r.batch for r in group]
        temps = [r.temperature for r in group]
        while len(rows) < batch_size:          # shape-stable padding
            rows.append(rows[-1])
            temps.append(temps[-1])
        batch = {k: np.concatenate([row[k] for row in rows])
                 for k in rows[0]}
        max_new = max(r.max_new_tokens for r in group)
        out = eng.generate(batch, max_new,
                           temperature=np.asarray(temps, np.float32),
                           seed=group[0].seed)
        now = time.perf_counter() - t0
        for j, r in enumerate(group):
            r.output = out[j, :r.max_new_tokens].copy()
            r.generated = useful_tokens(r.output, eng.eos_id)
            r.finish_time = now
    makespan = time.perf_counter() - t0
    toks = sum(r.generated for r in reqs)
    lat = np.array([r.finish_time - r.arrival for r in reqs])
    return {"n": float(n), "makespan_s": makespan,
            "useful_tokens": float(toks), "tok_s": toks / makespan,
            "latency_p50_s": float(np.percentile(lat, 50)),
            "latency_p95_s": float(np.percentile(lat, 95)),
            "latency_mean_s": float(lat.mean())}


def drive_fabric(fab: ServingFabric, requests: List[ServeRequest]
                 ) -> Dict[str, float]:
    """Wall-clock traffic loop through the serving fabric: the shared
    drive loop against the router's ``submit``/``step`` (dispatch →
    every rank → migrate) surface."""
    makespan = _drive_wall_clock(fab, requests)
    eos = fab.workers[0].engine.eos_id
    toks = sum(useful_tokens(r.output[:r.generated], eos) for r in requests)
    stats = obs_metrics.snapshot(extra=fab.stats())
    stats.update(makespan_s=makespan, useful_tokens=float(toks),
                 tok_s=toks / makespan)
    _attach_telemetry(stats)
    return stats


def _warm_fabric(fab: ServingFabric, cfg, *, dtype: str, seed: int,
                 prompt_len: int) -> None:
    """Compile every rank's jits off the clock (chunk + decode dispatch,
    and on the disaggregated path the migrate copy + state import), then
    reset the whole fabric — warm requests must leave no queue entries,
    leases, device state or accounting behind (PR-5 satellite: the
    scheduler's rid-keyed maps are exactly what this reset must clear)."""
    trace = make_trace(2 * fab.ranks, prompt_len=prompt_len, max_new=2,
                       arrival="all", seed=seed + 7)
    for req in requests_from_trace(cfg, trace, dtype=dtype, seed=seed + 7):
        fab.submit(req, 0.0)
    guard = 0
    while not fab.idle:
        fab.step(0.0)
        guard += 1
        if guard > 10_000:
            raise RuntimeError("fabric warm-up failed to drain")
    fab.reset()


def run_fabric(arch: str = "gemma-2b", *, smoke: bool = True,
               requests: int = 16, ranks: int = 2, slots: int = 4,
               prompt_len=(16, 256), max_new=(4, 32),
               arrival: str = "poisson", rate: float = 50.0,
               burst: int = 4, temperature: float = 0.0, eos_id: int = -1,
               seed: int = 0, prefill_chunk: int = 64,
               max_prefill_per_step: int = 2, block_size: int = 16,
               placements=("replicated", "disagg"),
               n_prefill_ranks: int = 1, speculate: int = 0) -> Dict:
    """Fabric-vs-single comparison (DESIGN.md §10): drive the same
    arrival trace through a single paged ``ContinuousEngine`` and then
    through an N-rank :class:`ServingFabric` under each requested
    placement policy. Records aggregate tok/s and TTFT p50/p95 per
    policy, per-rank utilization, the disaggregated path's KV-migration
    accounting, and greedy token-identity of the replicated path against
    the single-engine baseline (every fabric rank runs the same chunked
    paged engine, so placement must not change a single sampled token).
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    dtype = "float32" if smoke else "bfloat16"
    tcfg = TrainConfig(param_dtype=dtype, compute_dtype=dtype, remat=False,
                       loss_chunk=64, attn_chunk_threshold=4096)
    model = build_model(cfg, tcfg, ServeConfig(), tp=1)
    if model.decode_step_paged is None:
        raise ValueError(f"arch {cfg.name!r} has no paged decode path; "
                         "the serving fabric runs paged engines only")
    prefill_chunk = effective_chunk(model.capabilities, prefill_chunk)
    params = model.init(jax.random.PRNGKey(seed))
    plens = ((int(prompt_len),) if isinstance(prompt_len, int)
             else tuple(int(p) for p in prompt_len))
    pmax = max(plens)
    hi = max_new if isinstance(max_new, int) else max_new[1]
    cache_len = pmax + hi

    trace = make_trace(requests, prompt_len=plens, max_new=max_new,
                       arrival=arrival, rate=rate, burst=burst,
                       temperature=temperature, seed=seed)
    result: Dict = {"arch": cfg.name, "requests": requests, "ranks": ranks,
                    "slots_per_rank": slots, "prompt_len": list(plens),
                    "cache_len": cache_len, "arrival": arrival,
                    "rate": rate, "eos_id": eos_id,
                    "prefill_chunk": prefill_chunk,
                    "block_size": block_size,
                    "n_prefill_ranks": n_prefill_ranks,
                    "placements": list(placements)}

    # -- single-engine baseline (one paged engine, same per-rank size) --
    eng = ContinuousEngine(model, params, cache_len=cache_len,
                           num_slots=slots, eos_id=eos_id,
                           prefill_chunk=prefill_chunk,
                           max_prefill_per_step=max_prefill_per_step,
                           kv_layout="paged", block_size=block_size)
    warm = {k: np.asarray(v) for k, v in make_synthetic_batch(
        cfg, 1, plens[0], seed=seed, compute_dtype=dtype).items()
        if k != "labels"}
    eng.generate({k: np.concatenate([v] * min(2, eng.kv.num_slots))
                  for k, v in warm.items()}, 2)
    eng.reset()
    base_reqs = requests_from_trace(cfg, trace, dtype=dtype, seed=seed)
    result["single"] = drive_continuous(eng, base_reqs)

    # -- fabric runs, one per placement policy --
    for placement in placements:
        # speculative fabric ranks are replicated-only (a disaggregated
        # decode rank imports leases the verify pool cannot host) and
        # greedy-only; PR 9's token-identity guarantee keeps the spec
        # replicated fabric comparable to the non-spec single baseline
        spec_k = (speculate if (placement == "replicated"
                                and temperature == 0.0
                                and model.verify_step_paged is not None)
                  else 0)
        result[f"fabric_speculate_k_{placement}"] = spec_k
        fab = ServingFabric(model, params, ranks=ranks,
                            placement=placement, cache_len=cache_len,
                            slots_per_rank=slots, eos_id=eos_id,
                            prefill_chunk=prefill_chunk,
                            max_prefill_per_step=max_prefill_per_step,
                            block_size=block_size,
                            n_prefill_ranks=n_prefill_ranks,
                            speculate=spec_k)
        try:
            _warm_fabric(fab, cfg, dtype=dtype, seed=seed,
                         prompt_len=plens[0])
            reqs = requests_from_trace(cfg, trace, dtype=dtype, seed=seed)
            result[f"fabric_{placement}"] = drive_fabric(fab, reqs)
            ident = bool(all(
                np.array_equal(a.output[:a.generated],
                               b.output[:b.generated])
                for a, b in zip(base_reqs, reqs)))
            result[f"fabric_token_identical_{placement}"] = ident
            spd = (result[f"fabric_{placement}"]["tok_s"]
                   / result["single"]["tok_s"])
            result[f"fabric_{placement}"]["speedup_vs_single"] = spd
            # first-class comparison key: N ranks must beat one rank of
            # the same size — the number CI gates on (a fabric that
            # loses to its own single-engine baseline is a routing or
            # placement regression, not a measurement detail)
            result[f"speedup_vs_single_{placement}"] = spd
        finally:
            fab.close()
    return result


def run_family_rows(archs=FAMILY_ARCHS, *, smoke: bool = True,
                    requests: int = 6, slots: int = 4,
                    prompt_len: int = 24, max_new: int = 4,
                    prefill_chunk: int = 16, block_size: int = 8,
                    eos_id: int = -1, seed: int = 0) -> List[Dict]:
    """Per-family serving rows (``--config``, schema v7): drive a small
    same-arrival trace through each family's continuous *paged* chunked
    engine and report ``continuous_tok_s`` plus token identity against
    the family's static monolithic baseline. One row per registry
    family; a family whose structure forbids the path (patch_stub)
    reports its capability reason instead of faking a number."""
    rows: List[Dict] = []
    for arch in archs:
        cfg = get_smoke_config(arch) if smoke else get_config(arch)
        dtype = "float32" if smoke else "bfloat16"
        tcfg = TrainConfig(param_dtype=dtype, compute_dtype=dtype,
                           remat=False, loss_chunk=64,
                           attn_chunk_threshold=4096)
        model = build_model(cfg, tcfg, ServeConfig(), tp=1)
        caps = model.capabilities
        row: Dict = {"family": cfg.name, "block": cfg.block,
                     "chunked_prefill": bool(caps.chunked_prefill),
                     "paged_decode": bool(caps.paged_decode),
                     "carried_state": bool(caps.carried_state),
                     "prefix_cache": bool(caps.prefix_cache),
                     "kv_migration": bool(caps.kv_migration),
                     "speculative": bool(caps.speculative)}
        chunk = effective_chunk(caps, prefill_chunk)
        if not (chunk and caps.paged_decode):
            row["skipped"] = caps.reason
            rows.append(row)
            continue
        row["prefill_chunk"] = chunk
        params = model.init(jax.random.PRNGKey(seed))
        cache_len = prompt_len + max_new
        trace = make_trace(requests, prompt_len=prompt_len,
                           max_new=max_new, arrival="all", seed=seed)
        reqs = requests_from_trace(cfg, trace, dtype=dtype, seed=seed)
        eng = ContinuousEngine(model, params, cache_len=cache_len,
                               num_slots=slots, eos_id=eos_id,
                               prefill_chunk=chunk, kv_layout="paged",
                               block_size=block_size)
        stats = drive_continuous(eng, reqs)
        row["continuous_tok_s"] = stats["tok_s"]
        row["ttft_p95_s"] = stats.get("ttft_p95_s")
        row["state_bytes_per_slot"] = eng._carried_state_bytes()
        # static monolithic baseline on the same prompts: the greedy
        # tokens must be identical (the family-parity contract)
        batch = {k: np.concatenate([r.batch[k] for r in reqs])
                 for k in reqs[0].batch}
        s_out = StaticEngine(model, params, cache_len=cache_len,
                             eos_id=eos_id).generate(batch, max_new)
        row["static_tok_identical"] = bool(all(
            np.array_equal(s_out[j, :r.generated],
                           r.output[:r.generated])
            for j, r in enumerate(reqs)))
        rows.append(row)
    return rows


# ---------------------------------------------------------------------------
# End-to-end harness (imported by benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def run_traffic(arch: str = "gemma-2b", *, smoke: bool = True,
                requests: int = 12, slots: int = 4, prompt_len=16,
                max_new=(4, 32), arrival: str = "poisson",
                rate: float = 50.0, burst: int = 4, temperature: float = 0.0,
                engine: str = "both", ring: bool = False, eos_id: int = -1,
                seed: int = 0, parity_check: bool = True,
                prefill_chunk: int = 64, max_prefill_per_step: int = 2,
                chunk_compare: bool = True, paged_compare: bool = True,
                block_size: int = 16, prefix_compare: bool = True,
                shared_prefix_len: int = 0,
                share_ratio: float = 0.9, spec_compare: bool = True,
                speculate: int = 3, draft_arch: str = "self") -> Dict:
    """Build the model once, warm the jits, then drive the trace through
    the requested engine(s). Returns the full measurement dict.

    ``prompt_len`` is an int or a sequence cycled across the trace (e.g.
    ``(16, 256)`` interleaves short and long prompts — the trace that
    exposes prefill head-of-line blocking). With ``chunk_compare`` the
    continuous engine runs twice, chunked (``prefill_chunk``) and
    monolithic, and the result records the TTFT comparison plus prefill
    compile counts. Warm-up compiles one prompt shape off the clock; the
    monolithic engine must still compile every *other* distinct prompt
    length mid-traffic, which is exactly the cost the chunked path
    removes (its chunk jit never sees a new shape).

    With ``paged_compare`` (and an arch exposing the paged decode path)
    the continuous engine runs once more over a *paged* KV pool sized to
    the slot pool's HBM budget (``slots * cache_len`` tokens repartitioned
    into ``block_size``-token blocks, request rows no longer the scarce
    resource): the result records token-identity against the slot run,
    resident KV bytes/token, and peak concurrent requests at equal HBM —
    the paged engine must sustain strictly more.

    With ``prefix_compare`` (and a paged+chunkable arch) the driver also
    runs a shared-prefix trace (``shared_prefix_len`` template tokens,
    default ~3/4 of the longest prompt; ``share_ratio`` of requests in
    one of two template families) through three configurations: a paged
    engine without the radix prefix cache, a prefix-cached engine cold,
    and the same engine warm (``reset(preserve_prefix=True)`` — the
    repeat-tenant shape). All three must be token-identical; the warm
    pass's hit rate, prefill work saved, and TTFT improvement land as
    top-level keys (DESIGN.md §12).

    With ``spec_compare`` (greedy traces on a speculative-capable arch)
    the same trace runs once more through a paged engine with
    ``speculate=k`` draft–verify rounds (DESIGN.md §14):
    ``draft_arch="self"`` self-speculates (the target drafts on a second
    pool — full machinery, near-1.0 acceptance), any other name builds
    that config as the drafter. The result records ``spec_tok_s``
    alongside the non-speculative paged run's throughput, per-dispatch
    acceptance, and trace-level token identity — speculation must not
    change one greedy token (schema v7).
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    dtype = "float32" if smoke else "bfloat16"
    tcfg = TrainConfig(param_dtype=dtype, compute_dtype=dtype, remat=False,
                       loss_chunk=64, attn_chunk_threshold=4096)
    scfg = ServeConfig(ring_buffer=ring)
    model = build_model(cfg, tcfg, scfg, tp=1)
    # capability-aware chunk selection (DESIGN.md §13): floor the chunk
    # to the family's multiple; patch_stub frontends run monolithic; an
    # enc-dec family chunks on the paged path only, so its slot runs
    # deposit monolithically while the paged comparison still chunks
    caps = model.capabilities
    prefill_chunk = effective_chunk(caps, prefill_chunk)
    slot_chunk = prefill_chunk if caps.slot_chunk else 0
    params = model.init(jax.random.PRNGKey(seed))
    plens = ((int(prompt_len),) if isinstance(prompt_len, int)
             else tuple(int(p) for p in prompt_len))
    pmax = max(plens)
    hi = max_new if isinstance(max_new, int) else max_new[1]
    cache_len = (min(cfg.swa_window, pmax + hi)
                 if ring and cfg.swa_window else pmax + hi)

    trace = make_trace(requests, prompt_len=plens, max_new=max_new,
                       arrival=arrival, rate=rate, burst=burst,
                       temperature=temperature, seed=seed)
    result: Dict = {"arch": cfg.name, "requests": requests, "slots": slots,
                    "prompt_len": list(plens), "cache_len": cache_len,
                    "arrival": arrival, "rate": rate, "eos_id": eos_id,
                    "prefill_chunk": 0,     # effective value set below
                    "max_prefill_per_step": max_prefill_per_step,
                    "distinct_prompt_lens": len(set(plens))}

    warm = {k: np.asarray(v) for k, v in make_synthetic_batch(
        cfg, 1, plens[0], seed=seed, compute_dtype=dtype).items()
        if k != "labels"}

    def _drive_continuous(chunk: int, kv_layout: str = "slot",
                          num_blocks=None, n_rows=None, speculate=0,
                          draft_model=None, draft_params=None):
        # the engine's default scheduler prices admissions with the
        # engine's own (cache_len-clamped) chunk size
        eng = ContinuousEngine(
            model, params, cache_len=cache_len, num_slots=n_rows or slots,
            eos_id=eos_id, prefill_chunk=chunk,
            max_prefill_per_step=max_prefill_per_step,
            kv_layout=kv_layout, block_size=block_size,
            num_blocks=num_blocks, speculate=speculate,
            draft_model=draft_model, draft_params=draft_params)
        # warm the jits on ONE prompt shape off the clock, then reset the
        # engine — warm requests must leave neither stale device slot
        # state nor accounting rows behind
        eng.generate({k: np.concatenate([v] * min(2, eng.kv.num_slots))
                      for k, v in warm.items()}, 2)
        eng.reset()
        warm_compiles = eng.prefill_compiles
        reqs = requests_from_trace(cfg, trace, dtype=dtype, seed=seed)
        stats = drive_continuous(eng, reqs)
        stats["prefill_chunk"] = float(eng.prefill_chunk)
        stats["prefill_compiles_total"] = float(eng.prefill_compiles)
        stats["prefill_compiles_drive"] = float(
            eng.prefill_compiles - warm_compiles)
        stats.update(eng.kv_accounting())
        stats["block_deferrals"] = float(eng.scheduler.n_block_deferrals)
        if speculate:
            stats.update(eng.spec_stats())
            stats["decode_tokens_per_dispatch"] = \
                eng.decode_tokens_per_dispatch
        return stats, reqs

    if engine in ("continuous", "both"):
        result["continuous"], slot_reqs = _drive_continuous(slot_chunk)
        # effective chunk size, read back from the engine (clamped to the
        # slot capacity and floored to the family's chunk multiple; 0 =
        # explicit monolithic, e.g. enc-dec on the slot layout) — the
        # artifact records real behavior, and a monolithic run must not
        # fake a chunked-vs-monolithic comparison of two identical runs
        eff_chunk = int(result["continuous"]["prefill_chunk"])
        result["prefill_chunk"] = eff_chunk
        if eff_chunk and chunk_compare:
            result["continuous_monolithic"], _ = _drive_continuous(0)
            c, m = result["continuous"], result["continuous_monolithic"]
            if "ttft_p95_s" in c and "ttft_p95_s" in m:
                result["ttft_p95_chunked_s"] = c["ttft_p95_s"]
                result["ttft_p95_monolithic_s"] = m["ttft_p95_s"]
                result["chunked_ttft_p95_improved"] = bool(
                    c["ttft_p95_s"] < m["ttft_p95_s"])
            result["prefill_compiles_prompt_len_independent"] = bool(
                c["prefill_compiles_total"] <= 1.0)
        if (prefill_chunk and paged_compare
                and model.decode_step_paged is not None):
            # equal-HBM paged run: repartition the slot pool's token
            # capacity into leased blocks; request rows (cheap host state)
            # stop being the scarce resource, blocks gate admission
            nblocks = max(1, (slots * cache_len) // block_size)
            rows = min(requests, nblocks)
            result["continuous_paged"], paged_reqs = _drive_continuous(
                prefill_chunk, kv_layout="paged", num_blocks=nblocks,
                n_rows=rows)
            c, p = result["continuous"], result["continuous_paged"]
            result["block_size"] = block_size
            result["paged_num_blocks"] = nblocks
            result["paged_token_identical_trace"] = bool(all(
                np.array_equal(a.output[:a.generated], b.output[:b.generated])
                for a, b in zip(slot_reqs, paged_reqs)))
            result["paged_hbm_within_budget"] = bool(
                p["kv_bytes_total"] <= c["kv_bytes_total"])
            result["paged_max_concurrency"] = p["peak_concurrent"]
            result["slot_max_concurrency"] = c["peak_concurrent"]
            result["paged_more_concurrent_verified"] = bool(
                p["peak_concurrent"] > c["peak_concurrent"])
            result["paged_bytes_per_resident_token"] = \
                p["kv_bytes_per_resident_token"]
            result["slot_bytes_per_resident_token"] = \
                c["kv_bytes_per_resident_token"]

        if (prefill_chunk and spec_compare and speculate > 0
                and temperature == 0.0
                and model.verify_step_paged is not None):
            # speculative run over the SAME trace and paged pool
            # geometry as the non-spec paged comparison: k-token
            # draft–verify rounds, greedy parity required token-for-
            # token (DESIGN.md §14). Skipped (never faked) on sampled
            # traces and on families without the 'speculative'
            # capability.
            draft_model = draft_params = None
            if draft_arch not in ("self", arch):
                dcfg = (get_smoke_config(draft_arch) if smoke
                        else get_config(draft_arch))
                draft_model = build_model(dcfg, tcfg, scfg, tp=1)
                draft_params = draft_model.init(jax.random.PRNGKey(seed))
            nblocks = max(1, (slots * cache_len) // block_size)
            rows = min(requests, nblocks)
            result["continuous_spec"], spec_reqs = _drive_continuous(
                prefill_chunk, kv_layout="paged", num_blocks=nblocks,
                n_rows=rows, speculate=speculate,
                draft_model=draft_model, draft_params=draft_params)
            sp = result["continuous_spec"]
            ref = result.get("continuous_paged")
            ref_reqs = paged_reqs if ref is not None else slot_reqs
            if ref is None:
                ref = result["continuous"]
            result["speculate_k"] = speculate
            result["draft_arch"] = draft_arch
            result["spec_tok_s"] = sp["tok_s"]
            result["continuous_tok_s"] = ref["tok_s"]
            result["spec_accepted_per_dispatch"] = \
                sp["accepted_per_dispatch"]
            result["spec_acceptance_rate"] = sp["acceptance_rate"]
            result["spec_token_identical_trace"] = bool(all(
                np.array_equal(a.output[:a.generated],
                               b.output[:b.generated])
                for a, b in zip(ref_reqs, spec_reqs)))

        if (prefill_chunk and prefix_compare
                and model.decode_step_paged is not None
                and model.clone_paged_block is not None):
            bs = block_size
            spl = (int(shared_prefix_len) if shared_prefix_len > 0
                   else (3 * pmax // 4) // bs * bs)
            spl = max(bs, min(spl, pmax - 1))
            groups = 2
            trace_pfx = make_trace(
                requests, prompt_len=pmax, max_new=max_new,
                arrival=arrival, rate=rate, burst=burst,
                temperature=temperature, shared_prefix_len=spl,
                share_ratio=share_ratio, prefix_groups=groups, seed=seed)
            # pool sized for live requests PLUS the parked prefix index:
            # the shared templates, every request's private tail chain,
            # and headroom — the bench measures hit behavior, not
            # eviction churn (tests/test_prefix_cache.py covers that)
            nblocks_pfx = (slots * -(-cache_len // bs)
                           + groups * -(-spl // bs)
                           + requests * (-(-(pmax - spl) // bs) + 2))

            def _mk_pfx(prefix_cache: bool) -> ContinuousEngine:
                e = ContinuousEngine(
                    model, params, cache_len=cache_len, num_slots=slots,
                    eos_id=eos_id, prefill_chunk=prefill_chunk,
                    max_prefill_per_step=max_prefill_per_step,
                    kv_layout="paged", block_size=bs,
                    num_blocks=nblocks_pfx, prefix_cache=prefix_cache)
                e.generate({k: np.concatenate([v] * min(2, e.kv.num_slots))
                            for k, v in warm.items()}, 2)
                if prefix_cache:
                    # compile the CoW clone off the clock too (a self-
                    # clone is a no-op on the pool contents) — the first
                    # partial-block hit otherwise pays it mid-traffic
                    e.kv.swap_buffers(e._cow_clone(
                        e.kv.buffers, jnp.int32(0), jnp.int32(0)))
                e.reset()          # full reset: warm-up prompts must not
                return e           # pre-seed the trie

            base_reqs = requests_from_trace(cfg, trace_pfx, dtype=dtype,
                                            seed=seed)
            base_stats = drive_continuous(_mk_pfx(False), base_reqs)

            peng = _mk_pfx(True)
            cold_reqs = requests_from_trace(cfg, trace_pfx, dtype=dtype,
                                            seed=seed)
            cold_stats = drive_continuous(peng, cold_reqs)
            cold_stats.update(peng.prefix_stats())
            # warm: rows drain, the trie (and device KV) survives — the
            # repeat-tenant pass every hit block is already resident for
            peng.reset(preserve_prefix=True)
            warm_reqs = requests_from_trace(cfg, trace_pfx, dtype=dtype,
                                            seed=seed)
            warm_stats = drive_continuous(peng, warm_reqs)
            warm_stats.update(peng.prefix_stats())

            ident = bool(all(
                np.array_equal(a.output[:a.generated],
                               b.output[:b.generated])
                and np.array_equal(a.output[:a.generated],
                                   w.output[:w.generated])
                for a, b, w in zip(base_reqs, cold_reqs, warm_reqs)))
            result["prefix"] = {
                "shared_prefix_len": spl, "share_ratio": share_ratio,
                "prefix_groups": groups, "num_blocks": nblocks_pfx,
                "prompt_len": pmax, "baseline": base_stats,
                "cold": cold_stats, "warm": warm_stats,
            }
            result["prefix_token_identical"] = ident
            result["prefix_hit_rate"] = warm_stats["prefix_hit_rate"]
            result["prefill_tokens_saved"] = \
                warm_stats["prefill_tokens_saved"]
            result["prefill_dispatches_saved"] = \
                warm_stats["prefill_dispatches_saved"]
            if ("ttft_p95_s" in warm_stats and "ttft_p95_s" in cold_stats):
                result["prefix_ttft_p95_improved"] = bool(
                    warm_stats["ttft_p95_s"] < cold_stats["ttft_p95_s"])

    if engine in ("static", "both"):
        seng = StaticEngine(model, params, cache_len=cache_len, eos_id=eos_id)
        seng.generate({k: np.concatenate([v] * slots)
                       for k, v in warm.items()}, 2)    # warm jits
        result["static"] = drive_static(
            seng, requests_from_trace(cfg, trace, dtype=dtype, seed=seed),
            batch_size=slots)

    if engine == "both":
        spd = result["continuous"]["tok_s"] / result["static"]["tok_s"]
        result["speedup_tok_s"] = spd
        result["continuous_faster_verified"] = bool(spd > 1.0)

    if parity_check:
        # parity at the LONGEST prompt length: a multi-chunk deposit must
        # be token-identical to the monolithic static prefill. The decode
        # budget is capped by the trace's max_new ceiling — cache_len (and
        # therefore the paged engine's admittable capacity) is sized to
        # pmax + hi, so a fixed 8 would overflow it when hi < 8
        B = min(4, slots)
        par_new = min(8, hi)
        pbatch = make_synthetic_batch(cfg, B, pmax, seed=seed + 1,
                                      compute_dtype=dtype)
        prompt = {k: np.asarray(v) for k, v in pbatch.items()
                  if k != "labels"}
        s_out = StaticEngine(model, params, cache_len=cache_len,
                             eos_id=eos_id).generate(prompt, par_new)
        c_out = ContinuousEngine(model, params, cache_len=cache_len,
                                 num_slots=B, eos_id=eos_id,
                                 prefill_chunk=slot_chunk,
                                 max_prefill_per_step=max_prefill_per_step,
                                 ).generate(prompt, par_new)
        result["parity_token_identical"] = bool(np.array_equal(s_out, c_out))
        result["parity_prompt_len"] = pmax
        if (paged_compare and model.decode_step_paged is not None
                and prefill_chunk):
            p_out = ContinuousEngine(
                model, params, cache_len=cache_len, num_slots=B,
                eos_id=eos_id, prefill_chunk=prefill_chunk,
                max_prefill_per_step=max_prefill_per_step,
                kv_layout="paged",
                block_size=block_size).generate(prompt, par_new)
            result["parity_token_identical_paged"] = bool(
                np.array_equal(s_out, p_out))
    return result


def _collect_reports(obj) -> List[dict]:
    """Every sub-run residual report nested anywhere in a payload (the
    drivers stamp one per measured trial)."""
    reps: List[dict] = []
    if isinstance(obj, dict):
        rep = obj.get("residual_report")
        if isinstance(rep, dict):
            reps.append(rep)
        for v in obj.values():
            if isinstance(v, (dict, list)):
                reps.extend(_collect_reports(v))
    elif isinstance(obj, list):
        for v in obj:
            reps.extend(_collect_reports(v))
    return reps


def _finalize_payload(payload: Dict) -> Dict:
    """Schema v8: merge every sub-run's residual report into one
    payload-level ``residual_report`` with flat ``residual_<hop>_ratio``
    keys and the summed ``serialization_stall_s`` (all absent when
    telemetry was off)."""
    reps = _collect_reports(payload)
    if reps:
        merged = obs_residuals.merge_reports(reps)
        payload["residual_report"] = merged
        for kind, row in merged["hops"].items():
            if row["n"]:
                payload[f"residual_{kind}_ratio"] = row["ratio"]
        payload["serialization_stall_s"] = merged["serialization_stall_s"]
    return payload


def _write_trace(path) -> None:
    """``--trace-out``: export the tracer's ring as Chrome trace_event
    JSON (Perfetto / chrome://tracing)."""
    if not path:
        return
    tr = obs_trace.active()
    if tr is None:
        print(f"--trace-out {path}: tracing is off (set REPRO_TRACE=1)")
        return
    tr.write_chrome(path)
    print(f"wrote {path} ({tr.n_events} events, {tr.dropped} dropped)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=list(ARCH_NAMES))
    ap.add_argument("--config", default=None, metavar="NAME[,NAME...]",
                    help="per-family serving rows: drive each named "
                         "registry config (or 'families' = one per "
                         "serving structure) through the continuous "
                         "paged engine and emit continuous_tok_s rows "
                         "(schema v6) instead of the engine comparison")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="both",
                    choices=["static", "continuous", "both"])
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", default="16", metavar="N[,N...]",
                    help="prompt length, or a comma list cycled across "
                         "the trace (e.g. 16,256)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prompt chunk size for the continuous engine "
                         "(0 = monolithic prefill)")
    ap.add_argument("--max-prefill-per-step", type=int, default=2,
                    help="chunk-rows batched into one prefill dispatch")
    ap.add_argument("--no-chunk-compare", action="store_true",
                    help="skip the monolithic-prefill comparison run")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block for the paged comparison run")
    ap.add_argument("--no-paged-compare", action="store_true",
                    help="skip the paged-KV comparison run")
    ap.add_argument("--no-prefix-compare", action="store_true",
                    help="skip the radix prefix-cache comparison run")
    ap.add_argument("--shared-prefix-len", type=int, default=0,
                    help="template tokens shared by the prefix-compare "
                         "trace (0 = ~3/4 of the longest prompt)")
    ap.add_argument("--share-ratio", type=float, default=0.9,
                    help="fraction of prefix-compare requests drawn from "
                         "a shared template family")
    ap.add_argument("--speculate", type=int, default=3,
                    help="draft tokens per draft-verify round for the "
                         "speculative comparison run (0 = off)")
    ap.add_argument("--draft-arch", default="self",
                    help="drafter config for the speculative run; 'self' "
                         "= self-speculation (the target drafts on its "
                         "own second pool)")
    ap.add_argument("--no-spec-compare", action="store_true",
                    help="skip the speculative-decoding comparison run")
    ap.add_argument("--fabric", default="off",
                    choices=["off", "replicated", "disagg", "both"],
                    help="run the multi-rank serving fabric comparison "
                         "instead of the engine comparison (DESIGN.md §10)")
    ap.add_argument("--ranks", type=int, default=2,
                    help="engine ranks in the serving fabric")
    ap.add_argument("--prefill-ranks", type=int, default=1,
                    help="dedicated prefill ranks (disaggregated fabric)")
    ap.add_argument("--fabric-speculate", type=int, default=0,
                    help="draft tokens per draft-verify round on the "
                         "fabric's replicated ranks (0 = off; greedy "
                         "traces only, replicated placement only)")
    ap.add_argument("--max-new-lo", type=int, default=4)
    ap.add_argument("--max-new-hi", type=int, default=32)
    ap.add_argument("--arrival", default="poisson",
                    choices=["poisson", "burst", "all"])
    ap.add_argument("--rate", type=float, default=50.0,
                    help="arrival rate (req/s); burst spacing is 1/rate")
    ap.add_argument("--burst", type=int, default=4)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--eos-id", type=int, default=-1)
    ap.add_argument("--ring", action="store_true",
                    help="ring-buffer KV slots (sub-quadratic archs)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write measurements (e.g. BENCH_serve.json)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the telemetry ring as Chrome trace_event "
                         "JSON for Perfetto (needs REPRO_TRACE=1)")
    args = ap.parse_args()

    plens = [int(x) for x in str(args.prompt_len).split(",") if x]
    if args.config is not None:
        archs = (FAMILY_ARCHS if args.config in ("families", "all")
                 else tuple(x for x in args.config.split(",") if x))
        for a in archs:
            if a not in ARCH_NAMES:
                ap.error(f"--config: unknown arch {a!r} "
                         f"(known: {sorted(ARCH_NAMES)})")
        rows = run_family_rows(
            archs, smoke=args.smoke, requests=args.requests,
            slots=args.slots, prompt_len=plens[0],
            max_new=args.max_new_hi, prefill_chunk=args.prefill_chunk,
            block_size=args.kv_block_size, eos_id=args.eos_id,
            seed=args.seed)
        for row in rows:
            if "skipped" in row:
                print(f"{row['family']:>14}: skipped ({row['skipped']})")
                continue
            print(f"{row['family']:>14}: "
                  f"{row['continuous_tok_s']:8.1f} tok/s  "
                  f"chunk {row['prefill_chunk']}  "
                  f"state_bytes/slot {row['state_bytes_per_slot']}  "
                  f"token_identical={row['static_tok_identical']}")
        if args.json:
            payload = _finalize_payload(
                {"schema": "repro-serve-bench-v8", "families": rows})
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"wrote {args.json}")
        _write_trace(args.trace_out)
        return

    if args.fabric != "off":
        placements = (("replicated", "disagg") if args.fabric == "both"
                      else (args.fabric,))
        result = run_fabric(
            args.arch, smoke=args.smoke, requests=args.requests,
            ranks=args.ranks, slots=args.slots,
            prompt_len=plens[0] if len(plens) == 1 else plens,
            max_new=(args.max_new_lo, args.max_new_hi),
            arrival=args.arrival, rate=args.rate, burst=args.burst,
            temperature=args.temperature, eos_id=args.eos_id,
            seed=args.seed, prefill_chunk=args.prefill_chunk,
            max_prefill_per_step=args.max_prefill_per_step,
            block_size=args.kv_block_size, placements=placements,
            n_prefill_ranks=args.prefill_ranks,
            speculate=args.fabric_speculate)
        print(f"arch={result['arch']} requests={result['requests']} "
              f"ranks={result['ranks']} slots/rank="
              f"{result['slots_per_rank']} prompt_len="
              f"{result['prompt_len']}")
        for name in ("single", "fabric_replicated", "fabric_disagg"):
            if name not in result:
                continue
            m = result[name]
            ttft = (f"  ttft_p95 {m['ttft_p95_s'] * 1e3:.0f}ms"
                    if "ttft_p95_s" in m else "")
            print(f"{name:>18}: {m['tok_s']:8.1f} tok/s  "
                  f"makespan {m['makespan_s']:.2f}s  "
                  f"p50 {m['latency_p50_s'] * 1e3:.0f}ms  "
                  f"p95 {m['latency_p95_s'] * 1e3:.0f}ms{ttft}")
            for row in m.get("per_rank", ()):
                print(f"{'':>18}  rank {row['rank']} [{row['role']:>9}] "
                      f"util {row['utilization']:.2f}  "
                      f"dispatched {row['dispatched']:.0f}  "
                      f"migrated {row['migrated_in']:.0f}in/"
                      f"{row['migrated_out']:.0f}out  "
                      f"tokens {row['tokens']:.0f}")
            if "n_migrations" in m:
                print(f"{'':>18}  kv_migration: {m['n_migrations']:.0f} "
                      f"handoffs, {m['blocks_moved']:.0f} blocks, "
                      f"p95 {m.get('kv_migration_p95_us', 0.0):.1f}us "
                      f"modeled")
        for p in result["placements"]:
            print(f"   token_identical[{p}]="
                  f"{result.get(f'fabric_token_identical_{p}')}  "
                  f"speedup_vs_single[{p}]="
                  f"{result.get(f'speedup_vs_single_{p}', 0.0):.2f}x")
        if args.json:
            payload = _finalize_payload(
                {"schema": "repro-serve-bench-v8", **result})
            with open(args.json, "w") as f:
                json.dump(payload, f, indent=1)
            print(f"wrote {args.json}")
        _write_trace(args.trace_out)
        return

    result = run_traffic(
        args.arch, smoke=args.smoke, requests=args.requests,
        slots=args.slots, prompt_len=plens[0] if len(plens) == 1 else plens,
        max_new=(args.max_new_lo, args.max_new_hi), arrival=args.arrival,
        rate=args.rate, burst=args.burst, temperature=args.temperature,
        engine=args.engine, ring=args.ring, eos_id=args.eos_id,
        seed=args.seed, prefill_chunk=args.prefill_chunk,
        max_prefill_per_step=args.max_prefill_per_step,
        chunk_compare=not args.no_chunk_compare,
        paged_compare=not args.no_paged_compare,
        block_size=args.kv_block_size,
        prefix_compare=not args.no_prefix_compare,
        shared_prefix_len=args.shared_prefix_len,
        share_ratio=args.share_ratio,
        spec_compare=not args.no_spec_compare,
        speculate=args.speculate, draft_arch=args.draft_arch)

    print(f"arch={result['arch']} requests={result['requests']} "
          f"slots={result['slots']} cache_len={result['cache_len']} "
          f"prompt_len={result['prompt_len']} "
          f"prefill_chunk={result['prefill_chunk']}")
    for name in ("static", "continuous_monolithic", "continuous",
                 "continuous_paged", "continuous_spec"):
        if name in result:
            m = result[name]
            ttft = (f"  ttft_p95 {m['ttft_p95_s'] * 1e3:.0f}ms"
                    if "ttft_p95_s" in m else "")
            compiles = (f"  prefill_compiles {m['prefill_compiles_total']:.0f}"
                        if "prefill_compiles_total" in m else "")
            print(f"{name:>21}: {m['tok_s']:8.1f} tok/s  "
                  f"makespan {m['makespan_s']:.2f}s  "
                  f"p50 {m['latency_p50_s'] * 1e3:.0f}ms  "
                  f"p95 {m['latency_p95_s'] * 1e3:.0f}ms"
                  f"{ttft}{compiles}")
    if "speedup_tok_s" in result:
        print(f"    speedup: {result['speedup_tok_s']:.2f}x "
              f"(verified={result['continuous_faster_verified']})")
    if "chunked_ttft_p95_improved" in result:
        print(f"    chunked ttft_p95 {result['ttft_p95_chunked_s']*1e3:.0f}ms"
              f" vs monolithic {result['ttft_p95_monolithic_s']*1e3:.0f}ms "
              f"(improved={result['chunked_ttft_p95_improved']}, "
              f"compile-count prompt-len independent="
              f"{result.get('prefill_compiles_prompt_len_independent')})")
    if "paged_max_concurrency" in result:
        print(f"      paged: {result['paged_max_concurrency']:.0f} vs "
              f"{result['slot_max_concurrency']:.0f} peak concurrent at "
              f"equal HBM (block={result['block_size']} tok x "
              f"{result['paged_num_blocks']} blocks; more_concurrent="
              f"{result['paged_more_concurrent_verified']}, "
              f"bytes/resident-tok {result['paged_bytes_per_resident_token']:.0f}"
              f" vs {result['slot_bytes_per_resident_token']:.0f}, "
              f"token_identical={result['paged_token_identical_trace']})")
    if "spec_tok_s" in result:
        print(f"       spec: k={result['speculate_k']} "
              f"(draft={result['draft_arch']})  "
              f"{result['spec_tok_s']:.1f} tok/s vs "
              f"{result['continuous_tok_s']:.1f} non-spec  "
              f"accepted/dispatch "
              f"{result['spec_accepted_per_dispatch']:.2f}  "
              f"acceptance {result['spec_acceptance_rate']:.3f}  "
              f"token_identical={result['spec_token_identical_trace']}")
    if "prefix" in result:
        pfx = result["prefix"]
        warm_ttft = pfx["warm"].get("ttft_p95_s", 0.0)
        cold_ttft = pfx["cold"].get("ttft_p95_s", 0.0)
        print(f"     prefix: hit_rate {result['prefix_hit_rate']:.3f}  "
              f"tokens_saved {result['prefill_tokens_saved']:.0f}  "
              f"dispatches_saved {result['prefill_dispatches_saved']:.0f}  "
              f"cow {pfx['warm'].get('prefix_cow_clones', 0.0):.0f}  "
              f"ttft_p95 warm {warm_ttft * 1e3:.0f}ms vs cold "
              f"{cold_ttft * 1e3:.0f}ms "
              f"(improved={result.get('prefix_ttft_p95_improved')}, "
              f"token_identical={result['prefix_token_identical']}, "
              f"shared_len={pfx['shared_prefix_len']})")
    if "parity_token_identical" in result:
        print(f"     parity: token_identical="
              f"{result['parity_token_identical']} "
              f"paged={result.get('parity_token_identical_paged')} "
              f"(prompt_len={result.get('parity_prompt_len')})")
    if args.json:
        payload = _finalize_payload(
            {"schema": "repro-serve-bench-v8", **result})
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote {args.json}")
    _write_trace(args.trace_out)


if __name__ == "__main__":
    main()
