"""Serving launcher: batched generation driver over the Engine.

CPU demo:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
      --batch 4 --prompt-len 16 --max-new 32
On hardware, drop --smoke and pass a mesh (the dry-run decode cells prove
the production shardings lower; the Engine drives the same decode_step).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import Engine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--ring", action="store_true",
                    help="ring-buffer KV (sub-quadratic archs)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    dtype = "float32" if args.smoke else "bfloat16"
    tcfg = TrainConfig(param_dtype=dtype, compute_dtype=dtype, remat=False,
                       loss_chunk=64, attn_chunk_threshold=4096)
    scfg = ServeConfig(ring_buffer=args.ring)
    model = build_model(cfg, tcfg, scfg, tp=1)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = (min(cfg.swa_window, args.prompt_len + args.max_new)
                 if args.ring and cfg.swa_window
                 else args.prompt_len + args.max_new)
    eng = Engine(model, params, cache_len=cache_len)

    batch = make_synthetic_batch(cfg, args.batch, args.prompt_len,
                                 compute_dtype=dtype)
    prompt = {k: v for k, v in batch.items() if k != "labels"}
    t0 = time.time()
    out = eng.generate(prompt, max_new_tokens=args.max_new,
                       temperature=args.temperature)
    dt = time.time() - t0
    tput = args.batch * args.max_new / dt
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"cache_len={cache_len}")
    print(f"generated {out.shape} in {dt:.2f}s  ({tput:.1f} tok/s host)")
    print("sample tokens:", np.asarray(out[0][:16]).tolist())


if __name__ == "__main__":
    main()
