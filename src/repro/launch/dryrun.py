import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

# ^ MUST precede any jax import: jax locks the device count on first init.
# The 512 placeholder host devices exist ONLY for the dry-run; tests and
# benchmarks run with the real single CPU device.

import argparse          # noqa: E402
import dataclasses       # noqa: E402
import json              # noqa: E402
import time              # noqa: E402
import traceback         # noqa: E402

import jax               # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.config import (SHAPES, ServeConfig, TrainConfig,  # noqa: E402
                          shape_applicable)
from repro.configs import ARCH_NAMES, get_config, get_smoke_config  # noqa: E402
from repro.dist.sharding import (batch_pspec, cache_pspecs,  # noqa: E402
                                 named_sharding, param_pspecs)
from repro.launch.mesh import (make_mesh_from_config,  # noqa: E402
                               production_mesh_config)
from repro.models.registry import batch_spec, build_model  # noqa: E402
from repro.roofline.analysis import analyze_compiled  # noqa: E402
from repro.train.trainer import init_train_state, make_train_step  # noqa: E402

ARTIFACT_DIR = os.environ.get(
    "REPRO_ARTIFACT_DIR",
    os.path.join(os.path.dirname(__file__), "..", "..", "..",
                 "experiments", "artifacts"))


def _strip_batch_axes(spec_tree, batch_dims):
    """Replace the batch-dim axis with None (for shapes whose global batch
    does not divide the dp degree, e.g. long_500k's batch=1)."""
    def fix(spec):
        parts = list(spec)
        for i in batch_dims:
            if i < len(parts):
                parts[i] = None
        return P(*parts)
    return jax.tree_util.tree_map(
        fix, spec_tree, is_leaf=lambda s: isinstance(s, P))


def build_cell(arch: str, shape_name: str, mesh_name: str, *,
               smoke: bool = False, grad_sync: str = "spmd",
               act_mode: str = "sp", shard_mode: str = "2d",
               extra_train_kwargs=None):
    """Return (jitted_fn, arg_shapestructs, meta) for one dry-run cell.

    act_mode: residual-stream constraint at block boundaries —
      "batch": batch-sharded only (naive; remat-saved stacks replicate over
               the model axis → 79GB/device on yi-9b, does not fit),
      "sp":    + sequence dim sharded over "model" (Megatron sequence
               parallelism; saved activations shrink tp×). See §Perf.
    """
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, None, {"skipped": why}
    mesh_cfg = production_mesh_config(multi_pod=(mesh_name == "multi_pod"))
    if shard_mode == "dp_only":
        # small-model policy: no TP/FSDP, batch over ALL axes, weights
        # replicated — kills the weight-gather/TP-psum collective floor
        # that dominates tiny models over-sharded on 256+ chips (§Perf)
        mesh_cfg = dataclasses.replace(
            mesh_cfg,
            batch_axes=tuple(mesh_cfg.batch_axes) + tuple(mesh_cfg.model_axes),
            model_axes=())
    mesh = make_mesh_from_config(mesh_cfg)
    tp = mesh_cfg.tp
    dp = mesh_cfg.dp

    ring = shape.name == "long_500k"
    tkw = dict(param_dtype="bfloat16", compute_dtype="bfloat16",
               remat=True, grad_sync=grad_sync, loss_chunk=512,
               attn_chunk_threshold=2048, attn_chunk=512)
    if shape.kind == "train":
        # microbatch count: keep the remat-saved residual stack (the
        # dominant live activation, ~tokens_sp × d × L × 2B per device)
        # under ~1GB — calibrated on the measured yi-9b cell (§Perf)
        seq_sp = tp if (act_mode == "sp" and shape.seq_len % tp == 0) else 1
        tokens_dev = shape.global_batch * shape.seq_len / dp / seq_sp
        saved = tokens_dev * cfg.d_model * cfg.num_layers * 2
        mb = 1
        while (saved / mb > 0.5e9 and mb < 16
               and shape.global_batch % (2 * mb) == 0
               and (shape.global_batch // (2 * mb)) % dp == 0):
            mb *= 2
        tkw["microbatches"] = mb
        if cfg.d_model >= 6144:
            tkw["loss_chunk"] = 256   # bound CE logits temp on giant d/vocab
        # large kv blocks in the chunked-attention backward: carries scale
        # as S²/chunk_kv per layer (§Perf iteration 4)
        tkw["attn_chunk_kv"] = 2048
    if shard_mode == "dp_only":
        tkw["fsdp"] = False
    tkw.update(extra_train_kwargs or {})
    tcfg = TrainConfig(**tkw)
    scfg = ServeConfig(ring_buffer=ring)
    batch_div = shape.global_batch % dp == 0
    tp_axis = mesh_cfg.model_axes[0] if mesh_cfg.model_axes else None
    explicit = grad_sync != "spmd"
    if explicit:
        # the explicit threadcomm trainer runs fwd/bwd inside a shard_map
        # whose (pod, data) axes are MANUAL: constraints may only mention
        # auto axes, and jax.checkpoint-inside-manual-shard_map currently
        # miscompiles the SSD cumsum — measure collectives w/o remat
        tkw_update = {"remat": False, "microbatches": 1}
        tcfg = dataclasses.replace(tcfg, **tkw_update)
    # batch dim of activation constraints: only in auto (spmd) mode
    b_ax = None
    from repro.dist.sharding import batch_axes as _baxes
    if not explicit:
        b_ax = _baxes(mesh_cfg)
    act_sharding = None
    if batch_div and shape.kind == "train":
        seq_axis = (tp_axis if tp_axis and act_mode == "sp"
                    and shape.seq_len % mesh_cfg.axis_size(tp_axis) == 0
                    else None)
        act_sharding = NamedSharding(mesh, P(b_ax, seq_axis, None))
    elif batch_div:
        act_sharding = NamedSharding(mesh, P(b_ax, None, None))
    attn_sharding = None
    if batch_div and tp_axis and cfg.num_heads and cfg.num_heads % tp == 0:
        attn_sharding = NamedSharding(mesh, P(b_ax, None, tp_axis, None))
    model = build_model(cfg, tcfg, scfg, tp=tp, act_sharding=act_sharding,
                        attn_sharding=attn_sharding)

    params_sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_specs = param_pspecs(cfg, mesh_cfg, params_sds)
    p_shard = named_sharding(mesh, p_specs)
    batch_divisible = shape.global_batch % dp == 0
    b_pspec = (batch_pspec(mesh_cfg) if batch_divisible else P())
    b_shard = NamedSharding(mesh, b_pspec)
    bspec = batch_spec(cfg, shape, tcfg.compute_dtype)

    meta = {
        "arch": cfg.name, "shape": shape.name, "mesh": mesh_name,
        "kind": shape.kind, "devices": mesh_cfg.num_devices,
        "params": cfg.param_count(), "active_params": cfg.active_param_count(),
        "global_batch": shape.global_batch, "seq_len": shape.seq_len,
        "grad_sync": grad_sync,
    }

    if shape.kind == "train":
        step = make_train_step(model, mesh_cfg, tcfg, mesh=mesh)
        if grad_sync == "spmd":
            state_sds = jax.eval_shape(
                lambda k: init_train_state(model, k), jax.random.PRNGKey(0))
        else:
            from repro.train.explicit import init_explicit_state
            state_sds = jax.eval_shape(
                lambda k: init_explicit_state(model, k, dp=dp),
                jax.random.PRNGKey(0))
        # 6 * N_active * tokens (bwd included), per device
        tokens = shape.global_batch * shape.seq_len
        meta["model_flops_per_device"] = (
            6 * cfg.active_param_count() * tokens / mesh_cfg.num_devices)
        return step, (state_sds, bspec), meta

    from repro.models.registry import cache_len_for
    cache_len = cache_len_for(cfg, shape, scfg)
    meta["cache_len"] = cache_len

    if shape.kind == "prefill":
        cache_sds = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch, cache_len))
        c_specs = cache_pspecs(cfg, mesh_cfg, cache_sds)
        if not batch_divisible:
            c_specs = _strip_batch_axes(c_specs, (1,))
        fn = jax.jit(lambda p, b: model.prefill(p, b, cache_len),
                     in_shardings=(p_shard, b_shard),
                     out_shardings=(NamedSharding(mesh, P()),
                                    named_sharding(mesh, c_specs)))
        tokens = shape.global_batch * shape.seq_len
        meta["model_flops_per_device"] = (
            2 * cfg.active_param_count() * tokens / mesh_cfg.num_devices)
        return fn, (params_sds, bspec), meta

    # decode: one new token against a seq_len cache
    cache_sds = jax.eval_shape(
        lambda: model.init_cache(shape.global_batch, cache_len))
    c_specs = cache_pspecs(cfg, mesh_cfg, cache_sds)
    if not batch_divisible:
        c_specs = _strip_batch_axes(c_specs, (1,))
    c_shard = named_sharding(mesh, c_specs)
    tok_sds = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = jax.jit(model.decode_step,
                 in_shardings=(p_shard, c_shard, b_shard, None),
                 out_shardings=(NamedSharding(mesh, P()), c_shard),
                 donate_argnums=(1,))
    meta["model_flops_per_device"] = (
        2 * cfg.active_param_count() * shape.global_batch
        / mesh_cfg.num_devices)
    return fn, (params_sds, cache_sds, tok_sds, pos_sds), meta


def run_cell(arch: str, shape_name: str, mesh_name: str, *,
             smoke=False, grad_sync="spmd", shard_mode="2d", verbose=True,
             extra_train_kwargs=None):
    fn, args, meta = build_cell(arch, shape_name, mesh_name, smoke=smoke,
                                grad_sync=grad_sync, shard_mode=shard_mode,
                                extra_train_kwargs=extra_train_kwargs)
    meta = dict(meta, shard_mode=shard_mode)
    if fn is None:
        return {"meta": meta}
    t0 = time.time()
    lowered = fn.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    if verbose:
        print(compiled.memory_analysis())   # proves it fits
        cost = compiled.cost_analysis() or {}
        if isinstance(cost, (list, tuple)):  # old jax: per-device dicts
            cost = cost[0] if cost else {}
        print({k: v for k, v in cost.items()
               if k in ("flops", "bytes accessed")})

    # analytical compute/memory terms (HLO cost_analysis counts scan bodies
    # once — see roofline/analysis.py docstring)
    from repro.roofline.flops import cell_compute_flops, cell_memory_bytes
    cfg = get_smoke_config(arch) if smoke else get_config(arch)
    shape = SHAPES[shape_name]
    mesh_cfg = production_mesh_config(multi_pod=(mesh_name == "multi_pod"))
    comp = cell_compute_flops(cfg, shape)
    memb = cell_memory_bytes(cfg, shape, mesh_cfg,
                             cache_len=meta.get("cache_len"))
    analytic = {
        "computed_flops_per_device": comp["computed"] / mesh_cfg.num_devices,
        "bytes_per_device": memb["bytes"],
        "flops_breakdown": comp, "bytes_breakdown": memb,
    }
    analysis = analyze_compiled(
        compiled, model_flops=meta.get("model_flops_per_device"),
        analytic=analytic)
    return {"meta": meta, "analysis": analysis,
            "timings": {"lower_s": t_lower, "compile_s": t_compile}}


def artifact_path(arch, shape_name, mesh_name, grad_sync="spmd",
                  shard_mode="2d"):
    tag = "" if grad_sync == "spmd" else f"__{grad_sync}"
    if shard_mode != "2d":
        tag += f"__{shard_mode}"
    d = os.path.join(ARTIFACT_DIR, mesh_name)
    return os.path.join(d, f"{arch}__{shape_name}{tag}.json")


def all_cells():
    for arch in ARCH_NAMES:
        for shape_name in ("train_4k", "prefill_32k", "decode_32k",
                           "long_500k"):
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single_pod",
                    choices=["single_pod", "multi_pod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--grad-sync", default="spmd",
                    choices=["spmd", "threadcomm", "flat"])
    ap.add_argument("--shard-mode", default="2d", choices=["2d", "dp_only"])
    args = ap.parse_args()

    meshes = (["single_pod", "multi_pod"] if args.mesh == "both"
              else [args.mesh])
    cells = (list(all_cells()) if args.all
             else [(args.arch, args.shape)])
    n_ok = n_skip = n_fail = 0
    for mesh_name in meshes:
        for arch, shape_name in cells:
            path = artifact_path(arch, shape_name, mesh_name, args.grad_sync,
                                 args.shard_mode)
            if os.path.exists(path) and not args.force:
                print(f"[cached] {mesh_name}/{arch}/{shape_name}")
                n_ok += 1
                continue
            print(f"=== {mesh_name} :: {arch} :: {shape_name} "
                  f"(grad_sync={args.grad_sync}) ===", flush=True)
            try:
                res = run_cell(arch, shape_name, mesh_name, smoke=args.smoke,
                               grad_sync=args.grad_sync,
                               shard_mode=args.shard_mode)
            except Exception:
                traceback.print_exc()
                n_fail += 1
                continue
            if "analysis" not in res:
                print(f"[skip] {res['meta'].get('skipped')}")
                n_skip += 1
            else:
                terms = res["analysis"]["terms"]
                print(f"[ok] dominant={res['analysis']['dominant']} "
                      f"compute={terms['compute_s']:.4f}s "
                      f"memory={terms['memory_s']:.4f}s "
                      f"collective={terms['collective_s']:.4f}s "
                      f"fits_hbm={res['analysis']['fits_hbm']} "
                      f"(compile {res['timings']['compile_s']:.0f}s)",
                      flush=True)
                n_ok += 1
            os.makedirs(os.path.dirname(path), exist_ok=True)
            with open(path, "w") as f:
                json.dump(res, f, indent=1, default=str)
    print(f"dryrun done: ok={n_ok} skip={n_skip} fail={n_fail}")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
