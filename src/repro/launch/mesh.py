"""Production mesh construction.

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before any jax init).
"""

from __future__ import annotations

import jax

from repro.config import MULTI_POD, SINGLE_POD, MeshConfig
from repro.core.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def production_mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_mesh_from_config(mesh_cfg: MeshConfig) -> jax.sharding.Mesh:
    return make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
