"""Training launcher: config-driven driver over the trainer substrate.

On real hardware this runs the full configs over the production mesh; on
CPU use --smoke (reduced same-family configs) with a small mesh, e.g.:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
    python -m repro.launch.train --arch yi-9b --smoke --steps 50 \
      --mesh 2,2,2 --grad-sync threadcomm
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.config import MeshConfig, ServeConfig, TrainConfig
from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import SyntheticPipeline
from repro.dist.sharding import batch_pspec
from repro.launch.mesh import make_mesh_from_config
from repro.models.registry import build_model
from repro.train import checkpoint as ckpt
from repro.train.trainer import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(ARCH_NAMES))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--mesh", default="1",
                    help="comma mesh shape; 1=single device, 2,2,2=pod/data/model")
    ap.add_argument("--grad-sync", default="spmd",
                    choices=["spmd", "threadcomm", "flat"])
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    shape = tuple(int(x) for x in args.mesh.split(","))
    if shape == (1,):
        mesh_cfg = MeshConfig(shape=(1,), axis_names=("data",))
        mesh = None
    elif len(shape) == 3:
        mesh_cfg = MeshConfig(shape=shape, axis_names=("pod", "data", "model"),
                              process_axes=("pod",))
        mesh = make_mesh_from_config(mesh_cfg)
    else:
        mesh_cfg = MeshConfig(shape=shape, axis_names=("data", "model"))
        mesh = make_mesh_from_config(mesh_cfg)

    dtype = "float32" if args.smoke else "bfloat16"
    tcfg = TrainConfig(param_dtype=dtype, compute_dtype=dtype,
                       learning_rate=args.lr, warmup_steps=10,
                       total_steps=max(args.steps, 100),
                       grad_sync=args.grad_sync, remat=not args.smoke,
                       loss_chunk=min(64, args.seq),
                       attn_chunk_threshold=max(256, args.seq))
    model = build_model(cfg, tcfg, ServeConfig(), tp=mesh_cfg.tp)
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={shape} grad_sync={args.grad_sync}")

    pipe = SyntheticPipeline(cfg, batch=args.batch, seq_len=args.seq, seed=0)
    if args.grad_sync == "spmd" or mesh is None:
        state = init_train_state(model, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(model, mesh_cfg, tcfg))
    else:
        from repro.train.explicit import init_explicit_state
        state = init_explicit_state(model, jax.random.PRNGKey(0),
                                    dp=mesh_cfg.dp)
        step_fn = make_train_step(model, mesh_cfg, tcfg, mesh=mesh)
    b_shard = (NamedSharding(mesh, batch_pspec(mesh_cfg))
               if mesh is not None else None)

    start = 0
    if args.resume and args.ckpt_dir and ckpt.latest_step(args.ckpt_dir):
        state, start, _ = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed at step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = pipe.get_batch(i)
        batch = {k: (jax.device_put(jnp.asarray(v), b_shard)
                     if b_shard else jnp.asarray(v))
                 for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {float(metrics['loss']):.4f} "
                  f"({time.time() - t0:.1f}s)", flush=True)
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, state,
                      extra=pipe.state_dict(i + 1), keep=3)
    print("done.")


if __name__ == "__main__":
    main()
