"""Deterministic synthetic data pipeline.

Design goals for 1000+-node operation (DESIGN.md §6):
  * stateless addressing — batch ``step`` is a pure function of
    (seed, step), so any host can (re)compute any shard: restart and
    straggler fail-over need no data server and no coordination;
  * checkpointable — pipeline state is just the integer step;
  * shardable — ``shard_slice`` returns only the host's rows.

The token stream is a mixture of a Zipf-ish unigram draw and a structured
"copy run" pattern so the LM loss actually decreases during the end-to-end
example (pure-uniform tokens have irreducible loss = log V).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from repro.config import ModelConfig


@dataclass
class SyntheticPipeline:
    model: ModelConfig
    batch: int
    seq_len: int
    seed: int = 0

    # ------------------------------------------------------------------
    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def _tokens(self, rng, rows: int, cols: int) -> np.ndarray:
        v = self.model.vocab_size
        # zipf-ish unigram over a 1024-symbol head + uniform tail
        head = min(1024, v)
        ranks = np.arange(1, head + 1)
        probs = 1.0 / ranks
        probs /= probs.sum()
        toks = rng.choice(head, size=(rows, cols), p=probs).astype(np.int32)
        # structured copy runs: repeat the previous token with p=0.25
        rep = rng.random((rows, cols)) < 0.25
        for c in range(1, cols):
            toks[:, c] = np.where(rep[:, c], toks[:, c - 1], toks[:, c])
        return toks

    def get_batch(self, step: int) -> Dict[str, np.ndarray]:
        """Full global batch for ``step`` (deterministic)."""
        rng = self._rng(step)
        m, B, S = self.model, self.batch, self.seq_len
        if m.is_encoder_decoder:
            toks = self._tokens(rng, B, S + 1)
            frames = rng.standard_normal(
                (B, m.encoder_seq, m.d_model)).astype(np.float32)
            return {"frames": frames, "tokens": toks[:, :-1],
                    "labels": toks[:, 1:]}
        if m.frontend == "patch_stub":
            F = m.num_frontend_tokens
            toks = self._tokens(rng, B, S - F + 1)
            patch = rng.standard_normal((B, F, m.d_model)).astype(np.float32)
            return {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                    "patch_embeds": patch}
        toks = self._tokens(rng, B, S + 1)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def shard_slice(self, step: int, shard: int, num_shards: int):
        """Only this host's rows — identical to slicing the global batch."""
        full = self.get_batch(step)
        rows = self.batch // num_shards
        return {k: v[shard * rows:(shard + 1) * rows] for k, v in full.items()}

    # checkpointable state ------------------------------------------------
    def state_dict(self, step: int) -> Dict[str, int]:
        return {"seed": self.seed, "step": int(step)}

    @classmethod
    def from_state(cls, model: ModelConfig, batch: int, seq_len: int,
                   state: Dict[str, int]) -> "SyntheticPipeline":
        return cls(model, batch, seq_len, seed=state["seed"])
