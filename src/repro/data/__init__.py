from repro.data.pipeline import SyntheticPipeline  # noqa: F401
