"""Metrics registry + the canonical serving stats schema (DESIGN.md §15).

Two surfaces in one module:

**Push registry** — counters, gauges and histograms instrumented sites
update live (scheduler admissions, TTFT observations, block-pool
occupancy, queue depth). Enabled with the tracer (``REPRO_TRACE=1``) or
:func:`install`; disabled, every site is the sanitizer's one-global-
read-plus-None-check. ``snapshot()`` renders the registry as one plain
dict; ``reset()`` is the trial flush (wired into engine/fabric resets
so a warm trial's observations never aggregate into a measured one).

**Pull collectors** — the single schema for the stats the serving
objects used to assemble ad hoc: ``engine_kv_accounting`` /
``engine_prefix_stats`` / ``engine_spec_stats`` (previously
``ContinuousEngine`` methods), ``worker_utilization`` (previously
``EngineWorker``), and ``scheduler_census`` (previously inlined in
``ServingFabric.stats``). The old call sites remain as thin aliases
delegating here, so every bench artifact key keeps its name while the
schema has exactly one home. :func:`snapshot` merges any subset into
the one dict ``launch/serve.py`` and the bench drivers consume.

No imports from ``repro.serve`` — collectors duck-type their argument —
so serve modules can import this registry without a cycle.
"""

from __future__ import annotations

import os
import threading
from typing import Dict, Iterable, List, Optional

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Push registry
# ---------------------------------------------------------------------------

class Counter:
    """Monotonic accumulator (resets only at trial flush)."""

    __slots__ = ("value", "_lock")

    def __init__(self):
        self.value = 0.0
        self._lock = threading.Lock()

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self.value += n


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Streaming summary: count/total/min/max plus a bounded sample
    reservoir for percentiles (keeps the most recent ``cap`` samples —
    a serving trial's tail is what the percentiles should describe)."""

    __slots__ = ("count", "total", "min", "max", "_samples", "_cap",
                 "_lock")

    def __init__(self, cap: int = 4096):
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: List[float] = []
        self._cap = int(cap)
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self.count += 1
            self.total += v
            self.min = min(self.min, v)
            self.max = max(self.max, v)
            if len(self._samples) >= self._cap:
                self._samples.pop(0)
            self._samples.append(v)

    def summary(self) -> Dict[str, float]:
        with self._lock:
            if not self.count:
                return {"count": 0.0}
            s = np.asarray(self._samples)
            return {
                "count": float(self.count),
                "mean": self.total / self.count,
                "min": self.min,
                "max": self.max,
                "p50": float(np.percentile(s, 50)),
                "p95": float(np.percentile(s, 95)),
            }


class MetricsRegistry:
    """Named counter/gauge/histogram store with get-or-create access and
    one ``snapshot()``. Thread-safe: fabric rank threads update
    concurrently with the router."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._histograms.get(name)
            if h is None:
                h = self._histograms[name] = Histogram()
            return h

    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            histograms = dict(self._histograms)
        return {
            "counters": {k: c.value for k, c in sorted(counters.items())},
            "gauges": {k: g.value for k, g in sorted(gauges.items())},
            "histograms": {k: h.summary()
                           for k, h in sorted(histograms.items())},
        }

    def reset(self) -> None:
        """Trial flush: drop every metric (names re-create on next use)."""
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._histograms.clear()


# ---------------------------------------------------------------------------
# Pull collectors — the one stats schema (old call sites are thin aliases)
# ---------------------------------------------------------------------------

def engine_kv_accounting(engine) -> dict:
    """HBM-efficiency evidence for the traffic driver: total pool
    bytes, bytes pinned per resident token (time-averaged over
    non-idle steps), and peak concurrent in-flight requests."""
    if engine.kv_layout == "paged":
        total = engine.kv.kv_bytes
        cap_tokens = engine.kv.capacity_tokens
    else:
        total = int(sum(x.nbytes for x in
                        jax.tree_util.tree_leaves(engine.kv.buffers)))
        cap_tokens = engine.kv.num_slots * engine.cache_len
    per_tok = total / max(1, cap_tokens)
    resident = max(1, engine._resident_tok_sum)
    return {
        "kv_layout": engine.kv_layout,
        "kv_bytes_total": float(total),
        "kv_capacity_tokens": float(cap_tokens),
        "kv_bytes_per_token": per_tok,
        # reserved/resident > 1 is over-reservation: HBM pinned for
        # tokens that are not there (the slot pool's cache_len rounding)
        "kv_reserved_over_resident": engine._reserved_tok_sum / resident,
        "kv_bytes_per_resident_token":
            per_tok * engine._reserved_tok_sum / resident,
        "peak_concurrent": float(engine.peak_live),
    }


def engine_prefix_stats(engine) -> dict:
    """Prefix-cache evidence for BENCH_serve (empty when the cache is
    off): hit rate in *tokens*, prefill work saved, CoW/eviction
    counts, and the modeled hit-path cost."""
    pc = engine.prefix_cache
    if pc is None:
        return {}
    return {
        "prefix_lookups": float(engine.prefix_lookups),
        "prefix_hits": float(engine.prefix_hits),
        "prefix_hit_rate": (engine.prefix_hit_tokens
                            / max(1, engine.prefix_prompt_tokens)),
        "prefill_tokens_saved": float(engine.prefix_hit_tokens),
        "prefill_dispatches_saved": float(engine.prefill_dispatches_saved),
        "prefix_cow_clones": float(engine.prefix_cow_clones),
        "prefix_modeled_hit_cost_us":
            1e6 * engine.scheduler.modeled_prefix_hit_cost_s,
        **pc.stats(),
    }


def engine_spec_stats(engine) -> dict:
    """Speculative-decoding evidence for BENCH_serve (empty when
    speculation is off): per-dispatch acceptance and the modeled §3.2
    round cost the scheduler aggregated."""
    if not engine.speculate:
        return {}
    return {"speculate_k": float(engine.speculate),
            **engine.scheduler.spec_stats()}


def worker_utilization(worker) -> dict:
    """One per-rank row of the fabric bench artifact."""
    return {
        "rank": worker.rank,
        "role": worker.role,
        "steps": float(worker.total_steps),
        "busy_steps": float(worker.busy_steps),
        "utilization": (worker.busy_steps / worker.total_steps
                        if worker.total_steps else 0.0),
        "dispatched": float(worker.n_dispatched),
        "migrated_in": float(worker.n_migrated_in),
        "migrated_out": float(worker.n_migrated_out),
        "finished": float(worker.n_finished),
        "tokens": float(worker.tokens_out),
        # residual predicted work (0 after a drained trial) — the
        # JSQ key the router was balancing on
        "predicted_load_s": float(worker._load_s),
    }


def scheduler_census(scheduler, prefix: str = "router_") -> dict:
    """Trial-scoped census from a scheduler's rid-keyed accounting map:
    everything submitted this trial, what is still in flight, the
    arrival window, and the hop's admission accounting."""
    log = scheduler.req_log
    out = {
        prefix + "eager_admits": float(scheduler.n_eager_admits),
        prefix + "deferred": float(scheduler.n_deferred),
        prefix + "dispatch_cost_us": 1e6 * scheduler.modeled_admit_cost_s,
        prefix + "submitted": float(len(log)),
        prefix + "in_flight": float(sum(1 for r in log.values()
                                        if r.state != "done")),
    }
    if log:
        arr = [r.arrival for r in log.values()]
        out["arrival_span_s"] = max(arr) - min(arr)
    return out


def snapshot(engine=None, scheduler=None, workers: Iterable = (),
             registry: Optional[MetricsRegistry] = None,
             extra: Optional[dict] = None) -> dict:
    """The one merged stats dict the drivers consume: latency
    percentiles from the scheduler's finished list, the engine's
    KV/prefix/spec accounting, per-rank utilization rows, and (when the
    push registry is live) its counters/gauges/histograms."""
    out: dict = {}
    if scheduler is not None:
        out.update(scheduler.latency_stats())
    if engine is not None:
        if scheduler is None:
            out.update(engine.scheduler.latency_stats())
        out.update(engine.kv_accounting())
        out.update(engine.prefix_stats())
        out.update(engine.spec_stats())
    rows = [worker_utilization(w) for w in workers]
    if rows:
        out["per_rank"] = rows
    reg = registry if registry is not None else _REG
    if reg is not None:
        out["metrics"] = reg.snapshot()
    if extra:
        out.update(extra)
    return out


# ---------------------------------------------------------------------------
# Global activation — sanitizer pattern; REPRO_TRACE turns on the whole
# obs subsystem (tracer + registry) with one switch.
# ---------------------------------------------------------------------------

_REG: Optional[MetricsRegistry] = None


def active() -> Optional[MetricsRegistry]:
    return _REG


def install() -> MetricsRegistry:
    global _REG
    _REG = MetricsRegistry()
    return _REG


def uninstall() -> None:
    global _REG
    _REG = None


def flush_trial() -> None:
    """Trial-boundary flush for reset/close hooks (no-op when off)."""
    reg = _REG
    if reg is not None:
        reg.reset()


def _truthy(v: str) -> bool:
    return v.strip().lower() in ("1", "true", "yes", "on")


if _truthy(os.environ.get("REPRO_TRACE", "")):
    install()
