"""Span tracer for the serving fabric (``REPRO_TRACE=1``, DESIGN.md §15).

Nestable spans with thread/rank/stream context over everything the
paper's threading story touches: comm ops and ``CommStream`` regions,
engine micro-steps (``prefill_chunk`` / ``decode`` / ``spec_round``),
scheduler admit/defer decisions, and the fabric's dispatch/migrate
hops. Events land in a bounded ring buffer (overflow drops oldest
first) and export as Chrome ``trace_event`` JSON, so a whole fabric
trial opens in Perfetto (or ``chrome://tracing``) as one per-rank
timeline — each engine rank a lane, its chunk/decode/verify dispatches
and migrations laid out against the router's hops.

Cost discipline mirrors the sanitizer (DESIGN.md §11): disabled, every
instrumented site is one module-global read plus a ``None`` check —
nothing allocates, nothing reads the clock. Enabled, the hot-path API
is ``complete(name, t0, t1)``: the caller reads ``perf_counter`` around
the timed region and the tracer records a single pre-timed "X" event
(no begin/end bookkeeping on the hot path). The structured API —
``span()`` as a context manager, or a manual handle whose ``end()``
must run on every path (enforced by the ``span-leak`` lint rule) — is
for region-shaped sites (stream regions, rank steps).

Rank attribution: fabric rank threads come from a ``ThreadPoolExecutor``
that re-assigns threads to ranks arbitrarily per step, so thread
identity is NOT rank identity. ``rank_scope(rank)`` pushes the rank
onto a thread-local stack for the duration of a rank's step; every
event emitted inside carries that rank as its Perfetto lane (``tid``).
Span nesting state is thread-local too, so concurrent rank threads
never interleave each other's stacks.

The tracer owns the trial's :class:`~repro.obs.residuals.ResidualLedger`
(``tracer.residuals``): ``hop()`` records a modeled-vs-measured pair
AND emits the hop's span in one call, and ``on_wait`` feeds the
serialization-stall detector from ``Request.wait``.
"""

from __future__ import annotations

import json
import os
import threading
import time
from collections import deque
from typing import Any, Dict, List, Optional

from repro.obs.residuals import ResidualLedger

#: default ring capacity — a smoke-scale fabric trial is ~10k events
DEFAULT_CAPACITY = 65536

#: Perfetto lane for events outside any rank scope (driver/router
#: threads get DRIVER_TID + a per-thread index)
DRIVER_TID = 1000


class Span:
    """Handle for an open span. Context-manager use is exception-safe
    by construction; manual use must call :meth:`end` on every path
    (the ``span-leak`` lint rule checks exactly this)."""

    __slots__ = ("_tracer", "name", "cat", "args", "t0", "tid", "parent",
                 "_open")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Dict[str, Any], t0: float, tid: int,
                 parent: Optional[str]):
        self._tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = t0
        self.tid = tid
        self.parent = parent
        self._open = True

    def end(self) -> None:
        if self._open:
            self._open = False
            self._tracer._end_span(self)

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> bool:
        self.end()
        return False


class Tracer:
    """Ring-buffered span recorder with per-thread nesting state."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError("ring capacity must be >= 1")
        self.capacity = int(capacity)
        self._events: deque = deque()
        self._lock = threading.Lock()
        self._tls = threading.local()
        self._t0 = time.perf_counter()
        self.dropped = 0          # events evicted oldest-first
        self.unbalanced = 0       # manual end() out of LIFO order
        self.residuals = ResidualLedger()
        # tid -> lane name for the Perfetto thread_name metadata
        self._lane_names: Dict[int, str] = {}
        self._next_driver_lane = DRIVER_TID

    # -- thread-local context ----------------------------------------------
    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def _ranks(self) -> List[int]:
        rk = getattr(self._tls, "ranks", None)
        if rk is None:
            rk = self._tls.ranks = []
        return rk

    def current_rank(self) -> Optional[int]:
        rk = self._ranks()
        return rk[-1] if rk else None

    def rank_scope(self, rank: int):
        """Attribute everything emitted on this thread to ``rank`` until
        exit — the fabric worker wraps each rank step in one of these
        (pool threads are reassigned to ranks arbitrarily, so thread
        identity cannot stand in for rank identity)."""
        return _RankScope(self, int(rank))

    def set_runnable(self, n: int) -> None:
        """Thread-local runnable-work hint for the stall detector: the
        count of live rows + queued requests this rank could be
        advancing right now. Set by the engine at each micro-step."""
        self._tls.runnable = int(n)

    def _runnable(self) -> int:
        return getattr(self._tls, "runnable", 0)

    def _tid(self) -> int:
        """Perfetto lane: the innermost rank scope, else a stable
        per-thread driver lane."""
        rank = self.current_rank()
        if rank is not None:
            with self._lock:
                self._lane_names.setdefault(rank, f"rank {rank}")
            return rank
        lane = getattr(self._tls, "lane", None)
        if lane is None:
            with self._lock:
                lane = self._next_driver_lane
                self._next_driver_lane += 1
                self._lane_names[lane] = threading.current_thread().name
            self._tls.lane = lane
        return lane

    # -- recording ---------------------------------------------------------
    def _us(self, t: float) -> float:
        return (t - self._t0) * 1e6

    def _emit(self, ev: dict) -> None:
        with self._lock:
            if len(self._events) >= self.capacity:
                self._events.popleft()    # ring: oldest-first eviction
                self.dropped += 1
            self._events.append(ev)

    def span(self, name: str, cat: str = "", **args) -> Span:
        """Open a span on this thread's stack. Use as a context manager
        (``with tr.span(...):``) or keep the handle and ``end()`` it on
        every path — the span-leak lint rule enforces the latter."""
        stack = self._stack()
        parent = stack[-1].name if stack else None
        sp = Span(self, name, cat, args, time.perf_counter(), self._tid(),
                  parent)
        stack.append(sp)
        return sp

    def _end_span(self, sp: Span) -> None:
        t1 = time.perf_counter()
        stack = self._stack()
        if stack and stack[-1] is sp:
            stack.pop()
        else:
            # manual-API misuse (end out of LIFO order, or a cross-
            # thread end): recover by removing it wherever it sits
            try:
                stack.remove(sp)
            except ValueError:
                pass
            self.unbalanced += 1
        args = dict(sp.args)
        if sp.parent is not None:
            args["parent"] = sp.parent
        self._emit({"name": sp.name, "cat": sp.cat or "span", "ph": "X",
                    "ts": self._us(sp.t0), "dur": (t1 - sp.t0) * 1e6,
                    "pid": 0, "tid": sp.tid, "args": args})

    def complete(self, name: str, t0: float, t1: float, cat: str = "",
                 **args) -> None:
        """Hot-path pre-timed event: the caller read ``perf_counter``
        around the region; no stack bookkeeping, one emit."""
        stack = self._stack()
        if stack:
            args["parent"] = stack[-1].name
        self._emit({"name": name, "cat": cat or "span", "ph": "X",
                    "ts": self._us(t0), "dur": (t1 - t0) * 1e6,
                    "pid": 0, "tid": self._tid(), "args": args})

    def instant(self, name: str, cat: str = "", **args) -> None:
        """Point event (scheduler admit/defer decisions)."""
        self._emit({"name": name, "cat": cat or "event", "ph": "i",
                    "ts": self._us(time.perf_counter()), "s": "t",
                    "pid": 0, "tid": self._tid(), "args": args})

    def counter(self, name: str, **values) -> None:
        """Perfetto counter track (block-pool occupancy, queue depth)."""
        self._emit({"name": name, "cat": "counter", "ph": "C",
                    "ts": self._us(time.perf_counter()),
                    "pid": 0, "tid": self._tid(), "args": values})

    def hop(self, kind: str, modeled_s: float, t0: float, t1: float,
            **args) -> None:
        """A priced hop: record the modeled-vs-measured pair in the
        residual ledger AND emit the hop's span in one call — every
        dispatch/migrate/admission hop in the trace carries its
        residual in ``args``."""
        measured = t1 - t0
        rank = self.current_rank()
        self.residuals.record(kind, modeled_s, measured, rank=rank)
        args["modeled_s"] = float(modeled_s)
        args["measured_s"] = float(measured)
        if modeled_s > 0:
            args["residual_ratio"] = measured / modeled_s
        self.complete(f"hop:{kind}", t0, t1, cat="residual", **args)

    def on_wait(self, op: str, t0: float, t1: float) -> None:
        """Comm completion point (``Request.wait``): emit the wait span
        and, when this thread's runnable hint is set, charge the blocked
        time to the serialization-stall detector."""
        runnable = self._runnable()
        if runnable > 0:
            self.residuals.stall(t1 - t0, rank=self.current_rank())
        self.complete(f"wait:{op}", t0, t1, cat="comm", runnable=runnable)

    # -- trial lifecycle ---------------------------------------------------
    def flush_trial(self) -> None:
        """Trial boundary (post-warm-up reset / fabric close): drop the
        residual pairs and stall accumulators so warm-up measurements —
        compile-dominated, hence wildly off-model — never aggregate into
        a measured trial's report. The event ring is kept: the timeline
        showing warm-up next to the trial is a feature."""
        self.residuals.reset()

    # -- export ------------------------------------------------------------
    def events(self) -> List[dict]:
        with self._lock:
            return list(self._events)

    @property
    def n_events(self) -> int:
        with self._lock:
            return len(self._events)

    def chrome_trace(self) -> dict:
        """Chrome ``trace_event`` JSON object: per-lane thread_name
        metadata (rank lanes sort first) + the ring's events by time."""
        with self._lock:
            events = list(self._events)
            lanes = dict(self._lane_names)
        meta: List[dict] = [
            {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
             "args": {"name": "repro-serve"}},
        ]
        for tid, lane_name in sorted(lanes.items()):
            meta.append({"name": "thread_name", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"name": lane_name}})
            meta.append({"name": "thread_sort_index", "ph": "M", "pid": 0,
                         "tid": tid, "args": {"sort_index": tid}})
        events.sort(key=lambda e: e["ts"])
        return {"displayTimeUnit": "ms",
                "traceEvents": meta + events,
                "metadata": {"dropped_events": self.dropped}}

    def export_json(self) -> str:
        return json.dumps(self.chrome_trace())

    def write_chrome(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.export_json())


class _RankScope:
    __slots__ = ("_tracer", "_rank")

    def __init__(self, tracer: Tracer, rank: int):
        self._tracer = tracer
        self._rank = rank

    def __enter__(self):
        self._tracer._ranks().append(self._rank)
        return self

    def __exit__(self, *exc):
        rk = self._tracer._ranks()
        if rk and rk[-1] == self._rank:
            rk.pop()
        return False


# ---------------------------------------------------------------------------
# Global activation — the sanitizer's exact pattern (DESIGN.md §11):
# instrumented sites read one module global and None-check it; when
# nothing is installed the telemetry is compiled out of the hot path.
# ---------------------------------------------------------------------------

_TRACER: Optional[Tracer] = None


def active() -> Optional[Tracer]:
    return _TRACER


def install(capacity: int = DEFAULT_CAPACITY) -> Tracer:
    global _TRACER
    _TRACER = Tracer(capacity=capacity)
    return _TRACER


def uninstall() -> None:
    global _TRACER
    _TRACER = None


def flush_trial() -> None:
    """Module-level trial flush for reset/close hooks: a no-op when
    tracing is off, a residual-ledger reset when on."""
    tr = _TRACER
    if tr is not None:
        tr.flush_trial()


def _truthy(v: str) -> bool:
    return v.strip().lower() in ("1", "true", "yes", "on")


if _truthy(os.environ.get("REPRO_TRACE", "")):
    install(capacity=int(os.environ.get("REPRO_TRACE_CAPACITY",
                                        str(DEFAULT_CAPACITY))))
