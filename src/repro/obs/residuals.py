"""Modeled-vs-measured cost residuals (DESIGN.md §15).

Every serving hop the §3.2 protocol model prices — admission, prefix
hit, KV migration, speculative verify round, router dispatch — has a
wall-clock twin the tracer measures at the same site. The ledger keeps
the (modeled, measured) pairs per hop kind, and :meth:`residual_report`
surfaces where the model is off by more than a factor (default 2×):
that divergence is the observability the paper's §2 pathology demands —
a hop whose measured cost dwarfs its modeled one is where threads are
serializing on shared communication state.

The ledger also owns the **serialization-stall detector**: time a rank
spends blocked inside a comm completion (``Request.wait`` /
``waitall``) while it *has runnable work* (live decode rows, queued
requests — the tracer's thread-local runnable hint, set by the engine
at each micro-step). Blocked-while-runnable is the paper's accidental
serialization, measured instead of inferred.

Everything here is trial-scoped: drivers flush the ledger at warm-up
boundaries (``ContinuousEngine.reset`` / ``ServingFabric.close`` call
``trace.flush_trial()``) so compile-heavy warm-up measurements never
pollute a measured trial's residuals — the same aliasing class as the
PR 5 ``req_log`` reset bug.
"""

from __future__ import annotations

import math
import threading
from typing import Dict, List, Optional, Sequence, Tuple

#: hop kinds with a §3.2 modeled price (the report orders by this)
HOP_KINDS = ("admission", "prefix_hit", "migration", "spec_verify",
             "router_dispatch")


class ResidualLedger:
    """Accumulates (modeled, measured) cost pairs per hop kind, plus
    serialization-stall time. Thread-safe: fabric rank threads record
    concurrently with the router thread."""

    def __init__(self):
        self._lock = threading.Lock()
        # kind -> list of (modeled_s, measured_s, rank)
        self._hops: Dict[str, List[Tuple[float, float, int]]] = {}
        self._stall_s = 0.0
        self._stall_events = 0
        self._stall_by_rank: Dict[int, float] = {}

    # -- recording ---------------------------------------------------------
    def record(self, kind: str, modeled_s: float, measured_s: float,
               rank: Optional[int] = None) -> None:
        """One hop: its protocol-model price and its wall-clock twin."""
        row = (float(modeled_s), float(measured_s),
               -1 if rank is None else int(rank))
        with self._lock:
            self._hops.setdefault(kind, []).append(row)

    def stall(self, dt_s: float, rank: Optional[int] = None) -> None:
        """A rank spent ``dt_s`` blocked on comm completion while its
        runnable hint was set — accidental serialization, measured."""
        r = -1 if rank is None else int(rank)
        with self._lock:
            self._stall_s += float(dt_s)
            self._stall_events += 1
            self._stall_by_rank[r] = (self._stall_by_rank.get(r, 0.0)
                                      + float(dt_s))

    # -- reporting ---------------------------------------------------------
    def counts(self) -> Dict[str, int]:
        with self._lock:
            return {k: len(v) for k, v in self._hops.items()}

    def report(self, factor: float = 2.0) -> dict:
        """Per-hop aggregate modeled vs measured, flagging hop kinds
        whose aggregate ratio is off by more than ``factor`` in either
        direction. Seconds throughout; ``ratio = measured / modeled``."""
        with self._lock:
            hops_copy = {k: list(v) for k, v in self._hops.items()}
            stall_s = self._stall_s
            stall_events = self._stall_events
            stall_by_rank = dict(self._stall_by_rank)
        hops: Dict[str, dict] = {}
        flagged: List[str] = []
        order = [k for k in HOP_KINDS if k in hops_copy]
        order += [k for k in hops_copy if k not in HOP_KINDS]
        for kind in order:
            rows = hops_copy[kind]
            modeled = sum(r[0] for r in rows)
            measured = sum(r[1] for r in rows)
            ratio = measured / modeled if modeled > 0 else math.inf
            per = [r[1] / r[0] for r in rows if r[0] > 0]
            n_off = sum(1 for p in per if p > factor or p < 1.0 / factor)
            hops[kind] = {
                "n": len(rows),
                "modeled_s": modeled,
                "measured_s": measured,
                "ratio": ratio,
                "n_off": n_off,
                "worst_over": max(per, default=0.0),
                "worst_under": min(per, default=0.0),
            }
            if not (1.0 / factor <= ratio <= factor):
                flagged.append(kind)
        return {
            "factor": float(factor),
            "hops": hops,
            "flagged": flagged,
            "serialization_stall_s": stall_s,
            "stall_events": stall_events,
            "stall_by_rank": {str(k): v for k, v in stall_by_rank.items()},
        }

    def reset(self) -> None:
        """Trial boundary: drop every pair and the stall accumulators."""
        with self._lock:
            self._hops.clear()
            self._stall_s = 0.0
            self._stall_events = 0
            self._stall_by_rank.clear()


def merge_reports(reports: Sequence[dict], factor: float = 2.0) -> dict:
    """Recombine per-run residual reports (one per driver sub-trial)
    into one: hop sums add, ratios recompute from the merged sums, and
    stall time totals. The bench payload carries the merged view so one
    artifact answers "where is the model off" for the whole trial set."""
    merged: Dict[str, dict] = {}
    stall_s = 0.0
    stall_events = 0
    stall_by_rank: Dict[str, float] = {}
    for rep in reports:
        if not rep:
            continue
        stall_s += rep.get("serialization_stall_s", 0.0)
        stall_events += rep.get("stall_events", 0)
        for r, v in rep.get("stall_by_rank", {}).items():
            stall_by_rank[r] = stall_by_rank.get(r, 0.0) + v
        for kind, row in rep.get("hops", {}).items():
            m = merged.setdefault(kind, {
                "n": 0, "modeled_s": 0.0, "measured_s": 0.0, "n_off": 0,
                "worst_over": 0.0, "worst_under": math.inf})
            m["n"] += row["n"]
            m["modeled_s"] += row["modeled_s"]
            m["measured_s"] += row["measured_s"]
            m["n_off"] += row["n_off"]
            m["worst_over"] = max(m["worst_over"], row["worst_over"])
            m["worst_under"] = min(m["worst_under"], row["worst_under"])
    flagged = []
    for kind, m in merged.items():
        m["ratio"] = (m["measured_s"] / m["modeled_s"]
                      if m["modeled_s"] > 0 else math.inf)
        if m["worst_under"] is math.inf:
            m["worst_under"] = 0.0
        if not (1.0 / factor <= m["ratio"] <= factor):
            flagged.append(kind)
    return {
        "factor": float(factor),
        "hops": merged,
        "flagged": flagged,
        "serialization_stall_s": stall_s,
        "stall_events": stall_events,
        "stall_by_rank": stall_by_rank,
    }
