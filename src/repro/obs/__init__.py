"""Serving-fabric observability (DESIGN.md §15): span tracing, metrics
registry, and modeled-vs-measured cost residuals.

Off by default and compiled out of the hot path when off — every
instrumented site is one module-global read plus a ``None`` check, the
sanitizer's pattern (DESIGN.md §11). ``REPRO_TRACE=1`` (or
:func:`install`) turns on the whole subsystem: the span tracer
(:mod:`repro.obs.trace`), the push-metrics registry
(:mod:`repro.obs.metrics`), and the residual ledger the tracer owns
(:mod:`repro.obs.residuals`).
"""

from __future__ import annotations

from repro.obs import metrics, residuals, trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.residuals import ResidualLedger, merge_reports
from repro.obs.trace import Span, Tracer


def install(capacity: int = trace.DEFAULT_CAPACITY) -> Tracer:
    """Turn on the full subsystem (tracer + registry); returns the
    tracer. Equivalent to launching under ``REPRO_TRACE=1``."""
    metrics.install()
    return trace.install(capacity=capacity)


def uninstall() -> None:
    trace.uninstall()
    metrics.uninstall()


def flush_trial() -> None:
    """Trial-boundary flush (residual ledger + push registry); wired
    into ``ContinuousEngine.reset`` and ``ServingFabric.close`` so warm
    trials never aggregate into measured ones. No-op when off."""
    trace.flush_trial()
    metrics.flush_trial()


__all__ = [
    "MetricsRegistry", "ResidualLedger", "Span", "Tracer",
    "flush_trial", "install", "merge_reports", "metrics", "residuals",
    "trace", "uninstall",
]
