"""Executable collectives over a unified rank space, built from
``lax.ppermute`` + the schedules in :mod:`repro.core.schedules`.

This is the schedule/lowering layer: user code goes through the ``Comm``
methods in :mod:`repro.core.comm` (the unified communicator API), which
delegate here. All functions are designed to be called INSIDE
``jax.shard_map`` (or ``ThreadComm.run``). ``axes`` may be a single
mesh-axis name or a tuple —
a tuple spans the flattened (process-major) unified rank space, exactly the
threadcomm construction.

Two implementations exist for most ops:
  * schedule-explicit (ppermute rounds) — the paper's point-to-point-based
    stock algorithms (§4.2: "most collective algorithms consist of internal
    point-to-point communications"),
  * fused/native (psum & friends) — the paper's "shared-memory/atomics
    reimplementation" analogue on TPU.
The benchmarks compare them; the trainer uses the hierarchical composition.
"""

from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import schedules as sch

Axes = Union[str, Tuple[str, ...]]


def axis_size(axes: Axes) -> int:
    """Total size of (possibly tuple) mapped axes — static inside shard_map."""
    return lax.psum(1, axes) if isinstance(axes, str) else lax.psum(1, axes)


def unified_rank(axes: Axes):
    """Flattened process-major rank index (traced int32)."""
    if isinstance(axes, str):
        return lax.axis_index(axes)
    return lax.axis_index(axes)  # jax linearizes tuple axes row-major


def _rounds_to_perms(rounds):
    return [[(s, d) for (s, d) in rnd] for rnd in rounds]


# ---------------------------------------------------------------------------
# Barrier
# ---------------------------------------------------------------------------

def barrier(token, axes: Axes, mode: str = "msg"):
    """Synchronization in dataflow terms: the returned token depends on every
    rank's input token.

    mode="msg":    dissemination algorithm, lg N ppermute rounds — the
                   paper's point-to-point MPI_Barrier (Fig. 4 'MPI_Barrier
                   (pt2pt)').
    mode="atomic": one fused psum — the paper's shared-atomics
                   reimplementation (Fig. 4 'MPI_Barrier (atomics)').
    """
    token = jnp.asarray(token, jnp.float32)
    n = axis_size(axes)
    if mode == "atomic":
        return lax.pmax(token, axes)
    for rnd in sch.dissemination_rounds(int(n)):
        received = lax.ppermute(token, axes, rnd)
        token = jnp.maximum(token, received)
    return token


# ---------------------------------------------------------------------------
# Reduce / Bcast (binomial trees)
# ---------------------------------------------------------------------------

def reduce(x, axes: Axes, root: int = 0, schedule: str = "binomial"):
    """Sum-reduce to ``root``. Non-root ranks return partial garbage (like
    MPI_Reduce's undefined recv buffers). schedule='psum' is the fused
    analogue (valid everywhere)."""
    if schedule == "psum":
        return lax.psum(x, axes)
    n = int(axis_size(axes))
    for rnd in sch.binomial_reduce_rounds(n, root):
        received = lax.ppermute(x, axes, rnd)   # non-receivers get zeros
        x = x + received
    return x


def bcast(x, axes: Axes, root: int = 0):
    """Binomial broadcast from ``root`` over the unified rank space."""
    n = int(axis_size(axes))
    rank = unified_rank(axes)
    for rnd in sch.binomial_bcast_rounds(n, root):
        received = lax.ppermute(x, axes, rnd)
        dsts = np.array([d for (_, d) in rnd]) if rnd else np.array([], int)
        is_dst = jnp.any(rank == jnp.asarray(dsts)) if len(dsts) else False
        x = jnp.where(is_dst, received, x)
    return x


# ---------------------------------------------------------------------------
# Allreduce
# ---------------------------------------------------------------------------

def allreduce(x, axes: Axes, schedule: str = "psum", wire_dtype=None):
    """``wire_dtype`` compresses the on-wire representation (e.g. bfloat16
    halves the bytes of an f32 gradient reduce) while accumulating in the
    input dtype. Implemented on the pt2pt recursive-doubling schedule —
    the paper's point-to-point collective — which also dodges an XLA bug
    in low-precision reduce computations under manual axes."""
    if wire_dtype is not None:
        wire = jnp.dtype(wire_dtype)
        n = int(axis_size(axes))
        if n <= 1:
            return x
        if n & (n - 1) == 0:
            for rnd in sch.recursive_doubling_rounds(n):
                recv = lax.ppermute(x.astype(wire), axes, rnd)
                x = x + recv.astype(x.dtype)
            return x
        # non-power-of-two: ring accumulate (n-1 rounds). Wire casts per
        # hop, accumulation stays in the input dtype — never a fused psum
        # in the wire dtype.
        ring = sch.ring_rounds(n)[0]
        carry = x
        for _ in range(n - 1):
            carry = lax.ppermute(carry.astype(wire), axes, ring).astype(x.dtype)
            x = x + carry
        return x
    if schedule == "psum":
        return lax.psum(x, axes)
    if schedule == "recursive_doubling":
        n = int(axis_size(axes))
        for rnd in sch.recursive_doubling_rounds(n):
            x = x + lax.ppermute(x, axes, rnd)
        return x
    if schedule == "ring":
        return _ring_allreduce(x, axes)
    if schedule == "reduce_bcast":
        n = int(axis_size(axes))
        x = reduce(x, axes, root=0, schedule="binomial")
        # mask non-root partials before broadcasting
        x = jnp.where(unified_rank(axes) == 0, x, jnp.zeros_like(x))
        return bcast(x, axes, root=0)
    raise ValueError(f"unknown allreduce schedule {schedule!r}")


def _ring_allreduce(x, axes: Axes):
    """Bandwidth-optimal ring: reduce-scatter + allgather, 2(n-1) steps.
    Explicit-schedule variant for tests/benchmarks (python-unrolled; use
    'psum' or hierarchical for big meshes)."""
    n = int(axis_size(axes))
    rank = unified_rank(axes)
    shape = x.shape
    flat = x.reshape(-1)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    ring = sch.ring_rounds(n)[0]

    # reduce-scatter
    for t in range(n - 1):
        send_idx = (rank - t) % n
        blk = jnp.take(chunks, send_idx, axis=0)
        recv = lax.ppermute(blk, axes, ring)
        recv_idx = (rank - t - 1) % n
        chunks = chunks.at[recv_idx].add(recv)
    # allgather
    for t in range(n - 1):
        send_idx = (rank - t + 1) % n
        blk = jnp.take(chunks, send_idx, axis=0)
        recv = lax.ppermute(blk, axes, ring)
        recv_idx = (rank - t) % n
        chunks = chunks.at[recv_idx].set(recv)

    out = chunks.reshape(-1)
    if pad:
        out = out[:flat.size - pad] if pad else out
    return out[:np.prod(shape, dtype=int)].reshape(shape)


# ---------------------------------------------------------------------------
# Allgather / ReduceScatter / AllToAll (native, tuple-axes capable)
# ---------------------------------------------------------------------------

def allgather(x, axes: Axes, tiled: bool = True):
    return lax.all_gather(x, axes, tiled=tiled)


def reduce_scatter(x, axes: Axes):
    return lax.psum_scatter(x, axes, scatter_dimension=0, tiled=True)


def alltoall(x, axes: Axes):
    return lax.all_to_all(x, axes, split_axis=0, concat_axis=0, tiled=True)


# ---------------------------------------------------------------------------
# Hierarchical (threadcomm-aware) allreduce — the paper's technique
# ---------------------------------------------------------------------------

def hierarchical_allreduce(x, *, process_axes: Tuple[str, ...],
                           thread_axes: Tuple[str, ...]):
    """Two-level allreduce: reduce-scatter over the fast intra-process
    domain, allreduce the 1/M shard over the slow inter-process domain,
    allgather back. Inter-process traffic drops M× vs flat."""
    if not thread_axes:
        return lax.psum(x, process_axes)
    shape, dtype = x.shape, x.dtype
    flat = x.reshape(-1)
    m = int(axis_size(thread_axes))
    pad = (-flat.size) % m
    if pad:
        flat = jnp.pad(flat, (0, pad))
    shard = lax.psum_scatter(flat, thread_axes, scatter_dimension=0,
                             tiled=True)
    if process_axes:
        shard = lax.psum(shard, process_axes)
    full = lax.all_gather(shard, thread_axes, tiled=True)
    if pad:
        full = full[:-pad]
    return full.reshape(shape).astype(dtype)


# ---------------------------------------------------------------------------
# Point-to-point (ppermute-based sendrecv over unified ranks)
# ---------------------------------------------------------------------------

def sendrecv(x, axes: Axes, pairs: Sequence[Tuple[int, int]]):
    """Explicit message round over unified ranks: each (src, dst) delivers
    src's shard to dst; ranks not named as dst receive zeros."""
    return lax.ppermute(x, axes, list(pairs))
