"""Unified communicator API: one ``Comm`` interface over the N×M rank space.

This is the communication layer's single entry point (DESIGN.md §2). The
root communicator is a :class:`ThreadComm` built from mesh axes — the
paper's MPIX threadcomm fusing the process domain (slow, inter-pod axes)
with the thread domain (fast, intra-pod axes) into one process-major rank
space. Every *derived* communicator shares the same method surface:

    root = threadcomm_init(mesh, process_axes, thread_axes)
    with root.start():
        tcomm = root.thread_comm()        # fast-domain sub-comm family
        pcomm = root.process_comm()       # slow-domain sub-comm family
        sub   = root.split(color, key)    # MPI_Comm_split over unified ranks
        dup   = root.dup()                # same group, fresh context
        y = sub.allreduce(x)              # collectives are METHODS
        req = pcomm.iallreduce(x)         # nonblocking -> Request
        ... overlap compute ...
        y = req.wait()

Sub-communicators follow MPIX stream semantics (arXiv:2208.13707): a
``CommStream`` binds a comm to a named execution stream; requests issued on
a stream are serialized against each other via ``lax.optimization_barrier``
tokens, while independent streams may overlap. ``split`` returns an
axis-aligned :class:`AxisComm` (lowering to native psum/ppermute over mesh
axis names — the fast path) whenever the color classes coincide with a mesh
sub-grid, and a generic :class:`GroupComm` (merged ring schedules over the
full unified rank space) otherwise.

Lifetime rules extend the paper's §2 activation-window semantics: derived
comms, groups, attributes AND requests die at ``finish`` — using any of
them afterwards raises :class:`ThreadCommError`.
"""

from __future__ import annotations

import math
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

# runtime threadcomm sanitizer (REPRO_SANITIZE=1, DESIGN.md §11): every
# hook below is a single global read + None check when disabled
from repro.analysis.sanitizer import active as _san_active
# span tracer + stall detector (REPRO_TRACE=1, DESIGN.md §15) — the
# same one-global-read-plus-None-check discipline when disabled
from repro.obs.trace import active as _tr_active
from repro.core import collectives as coll
from repro.core import p2p as p2p_mod
from repro.core import protocol
from repro.core.compat import shard_map


class ThreadCommError(RuntimeError):
    """Misuse of the communicator lifecycle / activation-window rules."""


CommError = ThreadCommError  # preferred alias for new code


# ---------------------------------------------------------------------------
# Requests (nonblocking operations)
# ---------------------------------------------------------------------------

class Request:
    """Handle for a nonblocking operation.

    Carries the operation's (traced or concrete) result plus an ordering
    token. ``wait()`` returns the result; ``test()`` polls completion
    without blocking. Like every threadcomm-derived object, a request is
    only valid inside the activation window that issued it (paper §2): a
    ``wait`` after ``finish`` raises :class:`ThreadCommError`.

    ``model_overhead_s`` carries the protocol model's request-object cost
    (0 for the eager-fast path that skips request allocation — §3.2).
    """

    __slots__ = ("comm", "op", "_value", "_epoch", "_done", "stream",
                 "model_overhead_s")

    def __init__(self, comm: "Comm", op: str, value,
                 stream: Optional["CommStream"] = None,
                 model_overhead_s: float = 0.0):
        self.comm = comm
        self.op = op
        self._value = value
        self._epoch = comm._root._epoch
        self._done = False
        self.stream = stream
        self.model_overhead_s = model_overhead_s
        san = _san_active()
        if san is not None:
            san.on_request(self)

    def _check_window(self):
        self.comm._root._check_not_freed()
        if self._epoch != self.comm._root._epoch:
            raise ThreadCommError(
                f"request({self.op}) outlived its activation window "
                "(derived objects die at finish)")

    def wait(self):
        """Complete the operation and return its result. A runtime failure
        of the operation (device error, poisoned buffer) surfaces HERE —
        wait() is the completion point — not at a later use site."""
        self._check_window()
        self._done = True
        san = _san_active()
        if san is not None:
            san.on_request_complete(self)
        value = self._value
        leaves = jax.tree_util.tree_leaves(value)
        if not any(isinstance(l, jax.core.Tracer) for l in leaves):
            tr = _tr_active()
            if tr is None:
                jax.block_until_ready(value)   # host-level completion
            else:
                # the completion point is where accidental serialization
                # bites: time the block, and let the stall detector
                # charge it when this thread had runnable work
                t0 = time.perf_counter()
                jax.block_until_ready(value)   # host-level completion
                tr.on_wait(self.op, t0, time.perf_counter())
        return value

    def test(self) -> Tuple[bool, Optional[object]]:
        """(done, result_or_None) without blocking. Under a trace every op
        is scheduled into the dataflow graph, so it reports done."""
        self._check_window()
        if self._done:
            return True, self._value
        leaves = jax.tree_util.tree_leaves(self._value)
        ready = all(bool(getattr(l, "is_ready", lambda: True)())
                    for l in leaves)
        if ready:
            self._done = True
            san = _san_active()
            if san is not None:
                san.on_request_complete(self)
            return True, self._value
        return False, None


def waitall(requests: Sequence[Request]) -> List[object]:
    """MPI_Waitall: complete every request, preserving order."""
    return [r.wait() for r in requests]


def testall(requests: Sequence[Request]) -> bool:
    """MPI_Testall: True iff every request has completed."""
    return all(r.test()[0] for r in requests)


class CommStream:
    """A named execution stream bound to a comm (the MPIX stream analogue).

    Requests issued while the stream is entered are serialized against each
    other by threading an ``optimization_barrier`` token from each issue to
    the next — explicit program-order for communication, independent of any
    other stream. Use one stream per overlap domain, e.g.::

        with comm.stream("grad") as s:
            req = pcomm.iallreduce(shard)   # ordered on "grad"
        ... backward / optimizer math overlaps here ...
        shard = req.wait()
    """

    def __init__(self, comm: "Comm", name: str):
        self.comm = comm
        self.name = name
        self._token = None
        self._requests: List[Request] = []
        self._obs_span = None

    def __enter__(self) -> "CommStream":
        self.comm._root._check_active()
        san = _san_active()
        if san is not None:       # program order flows into the stream
            san.on_stream_enter(self)
        tr = _tr_active()
        if tr is not None:        # stream-region span, closed in __exit__
            self._obs_span = tr.span(f"stream:{self.name}", cat="comm")
        self.comm._root._stream_stack.append(self)
        return self

    def __exit__(self, *exc):
        stack = self.comm._root._stream_stack
        if stack and stack[-1] is self:
            stack.pop()
        sp = self._obs_span
        if sp is not None:
            self._obs_span = None
            sp.end()
        return False

    # ---- token plumbing (called by Comm.icollective) ----
    def _gate(self, x):
        if self._token is None:
            return x
        gated, _ = lax.optimization_barrier((x, self._token))
        return gated

    def _record(self, req: Request):
        leaves = jax.tree_util.tree_leaves(req._value)
        if leaves:
            self._token = leaves[0]
        self._requests.append(req)

    def synchronize(self) -> List[object]:
        """Complete every request issued on this stream (in order)."""
        out = waitall(self._requests)
        self._requests = []
        return out

    def ordered(self, value):
        """Thread an arbitrary pytree through this stream's program order:
        ``value`` is gated on the stream's last recorded token and its first
        leaf becomes the new tail. This is how non-collective work (e.g. the
        serving engine's prefill inserts and decode micro-steps, DESIGN.md
        §8) joins a stream's serialization context without going through
        ``icollective`` — same MPIX-stream semantics, ordering *within* the
        stream, none against other streams."""
        self.comm._root._check_active()
        leaves = jax.tree_util.tree_leaves(value)
        if not leaves:
            return value
        if self._token is not None:
            leaves = list(lax.optimization_barrier(
                tuple(leaves) + (self._token,)))[:-1]
            value = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(value), leaves)
        # record a COPY of a 1-element slice: the caller may donate `value`
        # into its next step (the serving engine does), which must not
        # delete the stream tail — and the tail must not pin a full buffer
        # (the prefill stream's first leaf is a whole KV page)
        self._token = jnp.copy(leaves[0].ravel()[:1])
        return value


# ---------------------------------------------------------------------------
# Derived-object handle (rank subsets) — kept from the MPIX group API
# ---------------------------------------------------------------------------

@dataclass
class Group:
    """A subset of unified ranks derived from an active comm. Valid only
    within the activation window that created it (paper §2)."""
    comm: "Comm"
    ranks: Tuple[int, ...]
    _epoch: int = 0

    def _check(self):
        self.comm._root._check_active()
        if self._epoch != self.comm._root._epoch:
            raise ThreadCommError(
                "group outlived its threadcomm activation window "
                "(derived objects die at MPIX_Threadcomm_finish)")

    @property
    def size(self) -> int:
        self._check()
        return len(self.ranks)

    def translate(self, rank: int) -> int:
        self._check()
        return self.ranks[rank]


# ---------------------------------------------------------------------------
# The unified Comm interface
# ---------------------------------------------------------------------------

class Comm:
    """Common surface of every communicator (root and derived).

    Collectives/p2p are methods; ``i``-prefixed variants return
    :class:`Request`. Subclasses provide ``_axes()`` (mesh axis names the
    op spans, or None for the generic ppermute path), ``size``,
    ``families()`` (host-side unified-rank lists), and the blocking
    collective implementations.
    """

    _root: "ThreadComm"

    # -- lifecycle ---------------------------------------------------------
    def _check(self):
        self._root._check_active()
        if self._birth_epoch != self._root._epoch:
            raise ThreadCommError(
                "communicator outlived its parent's activation window "
                "(derived comms die at finish)")

    @property
    def _birth_epoch(self) -> int:
        return self._epoch_at_birth

    # -- identity ----------------------------------------------------------
    @property
    def size(self) -> int:  # pragma: no cover - overridden
        raise NotImplementedError

    def families(self) -> List[List[int]]:
        """Host-side: the concurrent sub-comm instances this object stands
        for, each as a list of unified ranks ordered by local rank. The
        root comm is a single family spanning every rank."""
        raise NotImplementedError

    def translate(self, local_rank: int, family: int = 0) -> int:
        """Local rank -> unified (root) rank, MPI_Group_translate_ranks."""
        self._check()
        return self.families()[family][local_rank]

    def local_rank(self):
        """Traced local rank of the calling device (inside shard_map)."""
        raise NotImplementedError

    # -- derivation --------------------------------------------------------
    def dup(self) -> "Comm":
        """Same group(s), fresh communication context (MPI_Comm_dup). The
        dup is still a derived object: it dies at the parent's finish."""
        self._check()
        return self._clone()

    def _clone(self) -> "Comm":  # pragma: no cover - overridden
        raise NotImplementedError

    def split(self, color: Sequence[int], key: Optional[Sequence[int]] = None
              ) -> "Comm":
        """MPI_Comm_split over each family: local ranks with equal
        ``color[local_rank]`` form a sub-comm, ordered by
        ``(key[local_rank], local_rank)``. color < 0 == MPI_UNDEFINED (the
        rank joins no sub-comm and passes collectives through untouched).

        Returns an :class:`AxisComm` when the classes tile an axis-aligned
        mesh sub-grid in natural order (the fast path), else a
        :class:`GroupComm`.
        """
        self._check()
        color = list(color)
        if len(color) != self.size:
            raise ThreadCommError(
                f"split color has {len(color)} entries for a size-"
                f"{self.size} comm")
        if key is not None and len(key) != self.size:
            raise ThreadCommError("split key length must equal comm size")
        groups: Dict[Tuple[int, int], List[Tuple[int, int, int]]] = {}
        for fam_idx, fam in enumerate(self.families()):
            for lr, ur in enumerate(fam):
                c = color[lr]
                if c < 0:
                    continue
                k = key[lr] if key is not None else lr
                groups.setdefault((fam_idx, c), []).append((k, lr, ur))
        ordered = [tuple(ur for _, _, ur in sorted(v))
                   for _, v in sorted(groups.items())]
        natural = key is None or all(
            list(g) == sorted(g) for g in ordered)
        if natural:
            axes = self._root._axis_aligned(ordered)
            if axes is not None:
                return AxisComm(self._root, axes)
        return GroupComm(self._root, ordered)

    def stream(self, name: str) -> CommStream:
        """A named execution stream bound to this comm (MPIX stream)."""
        self._check()
        return CommStream(self, name)

    def _current_stream(self) -> Optional[CommStream]:
        stack = self._root._stream_stack
        return stack[-1] if stack else None

    # -- blocking collectives (subclass responsibility) --------------------
    def allreduce(self, x, schedule: str = "psum", wire_dtype=None):
        raise NotImplementedError

    def reduce(self, x, root: int = 0, schedule: str = "binomial"):
        raise NotImplementedError

    def bcast(self, x, root: int = 0):
        raise NotImplementedError

    def barrier(self, token, mode: str = "msg"):
        raise NotImplementedError

    def allgather(self, x, tiled: bool = True):
        raise NotImplementedError

    def reduce_scatter(self, x):
        raise NotImplementedError

    def alltoall(self, x):
        raise NotImplementedError

    def send_recv(self, x, pairs, *, force_protocol: Optional[str] = None):
        raise NotImplementedError

    # -- nonblocking layer -------------------------------------------------
    def icollective(self, op: str, x, *args, **kw) -> Request:
        """Issue collective ``op`` nonblocking: returns a :class:`Request`
        carrying the result plus a stream-ordering token."""
        self._check()
        stream = self._current_stream()
        if stream is not None:
            x = stream._gate(x)
        value = getattr(self, op)(x, *args, **kw)
        req = Request(self, op, value, stream=stream)
        if stream is not None:
            stream._record(req)
        return req

    def iallreduce(self, x, schedule: str = "psum", wire_dtype=None) -> Request:
        return self.icollective("allreduce", x, schedule, wire_dtype)

    def ireduce(self, x, root: int = 0, schedule: str = "binomial") -> Request:
        return self.icollective("reduce", x, root, schedule)

    def ibcast(self, x, root: int = 0) -> Request:
        return self.icollective("bcast", x, root)

    def ibarrier(self, token, mode: str = "msg") -> Request:
        return self.icollective("barrier", token, mode)

    def iallgather(self, x, tiled: bool = True) -> Request:
        return self.icollective("allgather", x, tiled)

    def ireduce_scatter(self, x) -> Request:
        return self.icollective("reduce_scatter", x)

    def _is_interthread(self) -> bool:
        """True when every message on this comm stays inside one process
        (the fast shared domain) — drives protocol selection and the
        request-skip fast path, which are interthread-only (§3.2)."""
        return all(len({self._root.process_of(r) for r in fam}) <= 1
                   for fam in self.families())

    def isend(self, x, pairs, *, force_protocol: Optional[str] = None
              ) -> Request:
        """Nonblocking rank-addressed message round. Under the static SPMD
        schedule send and receive are one fused permute (DESIGN.md §7), so
        the request's value is the RECEIVED buffer. The request carries the
        protocol model's request-object overhead — zero on the eager-fast
        path, which skips request allocation (paper §3.2; interthread
        comms only — slow-domain messages always pay the request)."""
        self._check()
        stream = self._current_stream()
        if stream is not None:
            x = stream._gate(x)
        nbytes = x.size * x.dtype.itemsize
        interthread = self._is_interthread()
        proto = force_protocol or protocol.select_protocol(
            int(nbytes), interthread=interthread)
        value = self.send_recv(x, pairs, force_protocol=proto)
        req = Request(self, f"sendrecv[{proto}]", value, stream=stream,
                      model_overhead_s=protocol.request_overhead(
                          int(nbytes), proto))
        if stream is not None:
            stream._record(req)
        return req

    irecv = isend  # SPMD: the matching receive of the same fused permute


# ---------------------------------------------------------------------------
# AxisComm: comms whose families tile mesh axes (fast, native lowering)
# ---------------------------------------------------------------------------

class AxisComm(Comm):
    """A family of sub-communicators spanning ``axes`` of the root mesh —
    one instance per coordinate of the complement axes, all operating
    concurrently (exactly MPI_Comm_split with color = complement coords).
    Collectives lower to the native / schedule-explicit implementations in
    :mod:`repro.core.collectives` over the axis names."""

    def __init__(self, root: "ThreadComm", axes: Tuple[str, ...]):
        self._root = root
        self.axes = tuple(axes)
        self._epoch_at_birth = root._epoch
        sizes = root._axis_sizes
        self._size = math.prod(sizes[a] for a in self.axes) if self.axes else 1

    @property
    def size(self) -> int:
        return self._size

    def _clone(self) -> "AxisComm":
        return AxisComm(self._root, self.axes)

    def families(self) -> List[List[int]]:
        root = self._root
        comp = [a for a in root.unified_axes if a not in self.axes]
        fams: Dict[Tuple[int, ...], List[Tuple[int, int]]] = {}
        for ur in range(root.size):
            coords = root.coords_of(ur)
            fkey = tuple(coords[a] for a in comp)
            lr = 0
            for a in self.axes:
                lr = lr * root._axis_sizes[a] + coords[a]
            fams.setdefault(fkey, []).append((lr, ur))
        return [[ur for _, ur in sorted(v)] for _, v in sorted(fams.items())]

    def local_rank(self):
        r = np.int32(0)
        for ax in self.axes:
            r = r * self._root._axis_sizes[ax] + lax.axis_index(ax)
        return r

    # -- collectives -------------------------------------------------------
    def allreduce(self, x, schedule: str = "psum", wire_dtype=None):
        self._check()
        if not self.axes:
            return x
        return coll.allreduce(x, self.axes, schedule=schedule,
                              wire_dtype=wire_dtype)

    def reduce(self, x, root: int = 0, schedule: str = "binomial"):
        self._check()
        if not self.axes:
            return x
        return coll.reduce(x, self.axes, root=root, schedule=schedule)

    def bcast(self, x, root: int = 0):
        self._check()
        if not self.axes:
            return x
        return coll.bcast(x, self.axes, root=root)

    def barrier(self, token, mode: str = "msg"):
        self._check()
        if not self.axes:
            return token
        return coll.barrier(token, self.axes, mode=mode)

    def allgather(self, x, tiled: bool = True):
        self._check()
        if not self.axes:
            return x
        return coll.allgather(x, self.axes, tiled=tiled)

    def reduce_scatter(self, x):
        self._check()
        if not self.axes:
            return x
        return coll.reduce_scatter(x, self.axes)

    def alltoall(self, x):
        self._check()
        if not self.axes:
            return x
        return coll.alltoall(x, self.axes)

    def send_recv(self, x, pairs, *, force_protocol: Optional[str] = None):
        """One message round addressed by LOCAL ranks; applies to every
        family concurrently. Protocol selection (eager padding vs 1-copy)
        follows core.p2p, using this comm's domain (interthread vs
        interprocess) for the thresholds."""
        self._check()
        proto = force_protocol or protocol.select_protocol(
            int(x.size * x.dtype.itemsize),
            interthread=self._is_interthread())
        recv, _ = p2p_mod.send_recv(x, self.axes, list(pairs),
                                    force_protocol=proto)
        return recv


# ---------------------------------------------------------------------------
# GroupComm: arbitrary rank classes (merged ring schedules)
# ---------------------------------------------------------------------------

class GroupComm(Comm):
    """Sub-comms over arbitrary unified-rank classes. Collectives run as
    ring schedules over the FULL unified axes, with each class's ring
    merged into shared ``ppermute`` rounds (classes are disjoint, so their
    pairs compose). Ranks in no class pass through untouched.

    Generic and correct for any partition; prefer an axis-aligned
    :class:`AxisComm` (what ``split`` returns when it can) for bandwidth-
    optimal native lowering.
    """

    def __init__(self, root: "ThreadComm", groups: Sequence[Sequence[int]]):
        self._root = root
        self._epoch_at_birth = root._epoch
        self.groups: Tuple[Tuple[int, ...], ...] = tuple(
            tuple(g) for g in groups)
        seen = set()
        for g in self.groups:
            for r in g:
                if r in seen:
                    raise ThreadCommError(
                        f"rank {r} appears in two split classes")
                seen.add(r)
        sizes = {len(g) for g in self.groups}
        self._uniform = len(sizes) == 1
        self._max_k = max(sizes) if sizes else 1
        # host tables over the full unified space
        S = root.size
        pos = np.zeros(S, np.int32)
        ksz = np.ones(S, np.int32)
        member = np.zeros(S, bool)
        for g in self.groups:
            for i, r in enumerate(g):
                pos[r], ksz[r], member[r] = i, len(g), True
        self._pos_np, self._ksz_np, self._member_np = pos, ksz, member

    @property
    def size(self) -> int:
        if not self._uniform:
            raise ThreadCommError(
                "size is per-class on a non-uniform split; use .groups")
        return self._max_k

    def _clone(self) -> "GroupComm":
        return GroupComm(self._root, self.groups)

    def families(self) -> List[List[int]]:
        return [list(g) for g in self.groups]

    def local_rank(self):
        ur = self._root.device_rank()
        return jnp.take(jnp.asarray(self._pos_np), ur)

    # -- merged ring rounds ------------------------------------------------
    def _ring_pairs(self, t: int) -> List[Tuple[int, int]]:
        """Pairs of round ``t`` (0-based): every class still propagating
        (k - 1 rounds for a class of size k) rotates by one."""
        pairs = []
        for g in self.groups:
            k = len(g)
            if t < k - 1:
                pairs.extend((g[i], g[(i + 1) % k]) for i in range(k))
        return pairs

    def _ring_accumulate(self, x, combine: Callable):
        axes = self._root.unified_axes
        carry, acc = x, x
        for t in range(self._max_k - 1):
            pairs = self._ring_pairs(t)
            if not pairs:
                break
            carry = lax.ppermute(carry, axes, pairs)
            acc = combine(acc, carry)
        return acc

    # -- collectives -------------------------------------------------------
    def allreduce(self, x, schedule: str = "ring", wire_dtype=None):
        self._check()
        if wire_dtype is not None:
            wire = jnp.dtype(wire_dtype)
            axes = self._root.unified_axes
            carry, acc = x, x
            for t in range(self._max_k - 1):
                carry = lax.ppermute(carry.astype(wire), axes,
                                     self._ring_pairs(t)).astype(x.dtype)
                acc = acc + carry
            return acc
        return self._ring_accumulate(x, lambda a, c: a + c)

    def reduce(self, x, root: int = 0, schedule: str = "ring"):
        """Sum-reduce; every class rank holds the class total (the ring
        accumulate is symmetric, so non-root 'garbage' equals the sum)."""
        self._check()
        return self._ring_accumulate(x, lambda a, c: a + c)

    def barrier(self, token, mode: str = "msg"):
        self._check()
        token = jnp.asarray(token, jnp.float32)
        return self._ring_accumulate(token, jnp.maximum)

    def bcast(self, x, root: int = 0):
        """Broadcast each class's ``root``-th member (by local rank) to the
        class: the value propagates one hop per round; non-members keep x."""
        self._check()
        axes = self._root.unified_axes
        pos = jnp.take(jnp.asarray(self._pos_np), self._root.device_rank())
        ksz = jnp.take(jnp.asarray(self._ksz_np), self._root.device_rank())
        dist = jnp.mod(pos - root, ksz)
        v = x
        for t in range(1, self._max_k):
            pairs = self._ring_pairs(t - 1)
            if not pairs:
                break
            recv = lax.ppermute(v, axes, pairs)
            v = jnp.where(dist == t, recv, v)
        return v

    def allgather(self, x, tiled: bool = True):
        """Gather over each class; requires uniform class size (SPMD output
        shapes must agree across every device). ``tiled=True`` (the
        interface-wide default, matching AxisComm) concatenates along the
        leading dim; ``tiled=False`` stacks a new (k, ...) dim."""
        self._check()
        if not self._uniform:
            raise ThreadCommError("allgather needs uniform split classes")
        k = self._max_k
        axes = self._root.unified_axes
        pos = jnp.take(jnp.asarray(self._pos_np), self._root.device_rank())
        out = jnp.zeros((k,) + x.shape, x.dtype)
        out = lax.dynamic_update_slice_in_dim(out, x[None], pos, axis=0)
        carry = x
        for t in range(1, k):
            carry = lax.ppermute(carry, axes, self._ring_pairs(0))
            out = lax.dynamic_update_slice_in_dim(
                out, carry[None], jnp.mod(pos - t, k), axis=0)
        if tiled:
            out = out.reshape((k * x.shape[0],) + x.shape[1:])
        return out

    def reduce_scatter(self, x):
        self._check()
        if not self._uniform:
            raise ThreadCommError("reduce_scatter needs uniform classes")
        k = self._max_k
        total = self.allreduce(x)
        flat = total.reshape(-1)
        if flat.size % k:
            raise ThreadCommError(
                f"reduce_scatter payload ({flat.size}) must be divisible "
                f"by the class size {k}")
        shard = flat.size // k
        pos = jnp.take(jnp.asarray(self._pos_np), self._root.device_rank())
        return lax.dynamic_slice_in_dim(flat, pos * shard, shard)

    def alltoall(self, x):
        raise NotImplementedError(
            "alltoall on arbitrary split classes; use an axis-aligned split")

    def send_recv(self, x, pairs, *, force_protocol: Optional[str] = None):
        """Message round addressed by LOCAL class ranks (same pairs applied
        in every class)."""
        self._check()
        unified = []
        for src, dst in pairs:
            for g in self.groups:
                unified.append((g[src % len(g)], g[dst % len(g)]))
        proto = force_protocol or protocol.select_protocol(
            int(x.size * x.dtype.itemsize),
            interthread=self._is_interthread())
        recv, _ = p2p_mod.send_recv(x, self._root.unified_axes, unified,
                                    force_protocol=proto)
        return recv


# ---------------------------------------------------------------------------
# Root communicator: the threadcomm
# ---------------------------------------------------------------------------

class _ActivationWindow:
    """Returned by ``ThreadComm.start()``. Activation is EAGER (start() is
    MPIX_Threadcomm_start); use as a context manager for the canonical
    start/finish pair, or call ``finish()`` explicitly for service-style
    long-lived activations (e.g. a trainer that stays resident)."""

    def __init__(self, comm: "ThreadComm"):
        self._comm = comm

    def __enter__(self) -> "ThreadComm":
        return self._comm

    def __exit__(self, *exc):
        self.finish()
        return False

    def finish(self):
        self._comm.finish()


class ThreadComm(Comm):
    """Root communicator over ``process_axes`` × ``thread_axes``: the
    paper's unified N×M rank space with process-major ordering, carrying
    the MPIX lifecycle (init → start → ... → finish → free) that bounds the
    lifetime of every derived object."""

    def __init__(self, mesh: jax.sharding.Mesh,
                 process_axes: Sequence[str],
                 thread_axes: Sequence[str]):
        names = mesh.axis_names
        for ax in (*process_axes, *thread_axes):
            if ax not in names:
                raise ThreadCommError(f"axis {ax!r} not in mesh {names}")
        if set(process_axes) & set(thread_axes):
            raise ThreadCommError("process and thread axes must be disjoint")
        self.mesh = mesh
        self.process_axes = tuple(process_axes)
        self.thread_axes = tuple(thread_axes)
        self._root = self
        self._active = False
        self._freed = False
        self._epoch = 0
        self._attrs: Dict = {}
        self._stream_stack: List[CommStream] = []
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_processes = math.prod(
            sizes[a] for a in self.process_axes) if self.process_axes else 1
        self.threads_per_process = math.prod(
            sizes[a] for a in self.thread_axes) if self.thread_axes else 1
        self._size = self.num_processes * self.threads_per_process
        self._axis_sizes = sizes

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def _check_not_freed(self):
        if self._freed:
            raise ThreadCommError("threadcomm already freed")

    def _check_active(self):
        self._check_not_freed()
        if not self._active:
            raise ThreadCommError(
                "threadcomm is inactive: call start() (MPIX_Threadcomm_start)"
                " before communicating")

    def _check(self):  # the root's own window never goes stale
        self._check_active()

    def start(self) -> _ActivationWindow:
        """Activate the communicator (MPIX_Threadcomm_start). Eager: the
        window opens at the call. ``with tc.start():`` closes it at exit
        (MPIX_Threadcomm_finish); bare ``tc.start()`` + ``tc.finish()`` is
        the service-mode spelling for long-lived activations."""
        self._check_not_freed()
        if self._active:
            raise ThreadCommError("threadcomm already active (nested start)")
        self._active = True
        return _ActivationWindow(self)

    def finish(self):
        """Close the activation window: derived comms, groups, attributes
        and outstanding requests all become invalid (paper §2)."""
        self._check_not_freed()
        if not self._active:
            raise ThreadCommError("finish without a matching start")
        san = _san_active()
        if san is not None:       # pending requests die with the window
            san.on_finish(self)
        self._active = False
        self._attrs.clear()        # attribute lifetime = activation window
        self._stream_stack.clear()
        self._epoch += 1

    def free(self):
        self._check_not_freed()
        if self._active:
            raise ThreadCommError("cannot free an active threadcomm "
                                  "(call finish first)")
        self._freed = True

    # ------------------------------------------------------------------
    # rank arithmetic (host side)
    # ------------------------------------------------------------------
    @property
    def size(self) -> int:
        return self._size

    @property
    def unified_axes(self) -> Tuple[str, ...]:
        return self.process_axes + self.thread_axes

    def rank_of(self, coords: dict) -> int:
        """Unified rank for mesh coordinates — process-major (paper §2)."""
        r = 0
        for ax in self.unified_axes:
            r = r * self._axis_sizes[ax] + coords[ax]
        return r

    def coords_of(self, rank: int) -> dict:
        out = {}
        for ax in reversed(self.unified_axes):
            out[ax] = rank % self._axis_sizes[ax]
            rank //= self._axis_sizes[ax]
        return out

    def process_of(self, rank: int) -> int:
        return rank // self.threads_per_process

    def thread_of(self, rank: int) -> int:
        return rank % self.threads_per_process

    def families(self) -> List[List[int]]:
        return [list(range(self.size))]

    def local_rank(self):
        return self.device_rank()

    def group(self, ranks: Sequence[int]) -> Group:
        self._check_active()
        return Group(self, tuple(ranks), _epoch=self._epoch)

    # attributes (paper: lifetime bounded by the activation window)
    def set_attr(self, key, value):
        self._check_active()
        self._attrs[key] = value

    def get_attr(self, key):
        self._check_active()
        return self._attrs.get(key)

    # ------------------------------------------------------------------
    # derivation
    # ------------------------------------------------------------------
    def _clone(self) -> "AxisComm":
        return AxisComm(self, self.unified_axes)

    def process_comm(self) -> AxisComm:
        """Slow-domain family: one sub-comm of the N processes per thread
        index (ranks differing only in process coords)."""
        self._check_active()
        return AxisComm(self, self.process_axes)

    def thread_comm(self) -> AxisComm:
        """Fast-domain family: one sub-comm of the M threads per process
        (the intra-pod / shared-memory analogue domain)."""
        self._check_active()
        return AxisComm(self, self.thread_axes)

    def _axis_aligned(self, groups: Sequence[Sequence[int]]
                      ) -> Optional[Tuple[str, ...]]:
        """If ``groups`` exactly tile some axes-subset sub-grid in row-major
        local order, return those axes (split fast path)."""
        from itertools import combinations
        all_ranks = sorted(r for g in groups for r in g)
        if all_ranks != list(range(self.size)):
            return None
        want = {tuple(g) for g in groups}
        axes_list = list(self.unified_axes)
        for k in range(len(axes_list), -1, -1):
            for axes in combinations(axes_list, k):
                fams = AxisComm(self, axes).families()
                if {tuple(f) for f in fams} == want:
                    return axes
        return None

    # ------------------------------------------------------------------
    # device-side rank (call inside shard_map)
    # ------------------------------------------------------------------
    def device_rank(self):
        r = np.int32(0)
        for ax in self.unified_axes:
            r = r * self._axis_sizes[ax] + lax.axis_index(ax)
        return r

    # ------------------------------------------------------------------
    # SPMD launcher
    # ------------------------------------------------------------------
    def run(self, fn: Callable, *args, in_specs=None, out_specs=None):
        """shard_map a function over the full unified mesh. Default specs
        shard the leading dim over all unified axes (SPMD over ranks)."""
        self._check_active()
        in_specs = in_specs if in_specs is not None else P(self.unified_axes)
        out_specs = out_specs if out_specs is not None else P(self.unified_axes)
        return shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                         out_specs=out_specs)(*args)

    # ------------------------------------------------------------------
    # collectives over the unified rank space
    # ------------------------------------------------------------------
    def allreduce(self, x, schedule: str = "psum", wire_dtype=None):
        self._check_active()
        if schedule == "hierarchical":
            return self._hierarchical_allreduce(x, wire_dtype=wire_dtype)
        if schedule == "hierarchical_tree":
            return self._hierarchical_tree_allreduce(x)
        return coll.allreduce(x, self.unified_axes, schedule=schedule,
                              wire_dtype=wire_dtype)

    def _hierarchical_allreduce(self, x, wire_dtype=None):
        """The paper's two-level schedule as a sub-comm composition:
        thread_comm.reduce_scatter → process_comm.allreduce (1/M bytes on
        the slow domain) → thread_comm.allgather."""
        tcomm, pcomm = self.thread_comm(), self.process_comm()
        if tcomm.size == 1:
            return pcomm.allreduce(x, wire_dtype=wire_dtype)
        shape, dtype = x.shape, x.dtype
        flat = x.reshape(-1)
        pad = (-flat.size) % tcomm.size
        if pad:
            flat = jnp.pad(flat, (0, pad))
        shard = tcomm.reduce_scatter(flat)
        if pcomm.size > 1:
            shard = pcomm.allreduce(shard, wire_dtype=wire_dtype)
        full = tcomm.allgather(shard, tiled=True)
        if pad:
            full = full[:-pad]
        return full.reshape(shape).astype(dtype)

    def _hierarchical_tree_allreduce(self, x):
        """Latency-oriented composition over derived comms (small payloads):
        thread_comm.reduce → process_comm.allreduce → thread_comm.bcast."""
        tcomm, pcomm = self.thread_comm(), self.process_comm()
        y = tcomm.reduce(x, root=0, schedule="binomial") if tcomm.size > 1 else x
        if pcomm.size > 1:
            y = pcomm.allreduce(y)
        return tcomm.bcast(y, root=0) if tcomm.size > 1 else y

    def barrier(self, token, mode: str = "msg"):
        self._check_active()
        return coll.barrier(token, self.unified_axes, mode=mode)

    def reduce(self, x, root: int = 0, schedule: str = "binomial"):
        self._check_active()
        return coll.reduce(x, self.unified_axes, root=root, schedule=schedule)

    def bcast(self, x, root: int = 0):
        self._check_active()
        return coll.bcast(x, self.unified_axes, root=root)

    def allgather(self, x, tiled: bool = True):
        self._check_active()
        return coll.allgather(x, self.unified_axes, tiled=tiled)

    def reduce_scatter(self, x):
        self._check_active()
        return coll.reduce_scatter(x, self.unified_axes)

    def alltoall(self, x):
        self._check_active()
        return coll.alltoall(x, self.unified_axes)

    def send_recv(self, x, pairs, *, force_protocol: Optional[str] = None):
        self._check_active()
        if force_protocol is None:
            return coll.sendrecv(x, self.unified_axes, pairs)
        recv, _ = p2p_mod.send_recv(x, self.unified_axes, list(pairs),
                                    force_protocol=force_protocol)
        return recv


def threadcomm_init(mesh, process_axes: Sequence[str] = (),
                    thread_axes: Optional[Sequence[str]] = None,
                    num_threads: Optional[int] = None) -> ThreadComm:
    """MPIX_Threadcomm_init analogue. ``num_threads``, when given, must match
    the thread-axes product (the paper's creation-parameter check)."""
    if thread_axes is None:
        thread_axes = tuple(a for a in mesh.axis_names
                            if a not in tuple(process_axes))
    tc = ThreadComm(mesh, process_axes, thread_axes)
    if num_threads is not None and num_threads != tc.threads_per_process:
        raise ThreadCommError(
            f"num_threads={num_threads} does not match the parallel region "
            f"width {tc.threads_per_process}")
    return tc
