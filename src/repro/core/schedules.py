"""Collective-communication schedules as pure rank arithmetic.

Each schedule returns a list of *rounds*; a round is a list of (src, dst)
pairs executed concurrently. These are the classic algorithms the paper's
MPICH implementation uses (dissemination barrier [Hensgen88], binomial
reduce/bcast, ring and recursive-doubling allreduce) plus the two-level
hierarchical composition that realizes the paper's "threadcomm-aware"
collectives (exploit the fast local domain first).

Pure python → property-testable (hypothesis) and directly consumable by
``lax.ppermute`` perms in :mod:`repro.core.collectives`.
"""

from __future__ import annotations

import math
from typing import List, Sequence, Tuple

Round = List[Tuple[int, int]]


def _ceil_log2(n: int) -> int:
    return max(1, math.ceil(math.log2(n))) if n > 1 else 0


# ---------------------------------------------------------------------------
# Barrier: dissemination (lg N rounds, every rank sends every round)
# ---------------------------------------------------------------------------

def dissemination_rounds(n: int) -> List[Round]:
    """Round k: rank i signals rank (i + 2^k) mod n. After ceil(lg n) rounds
    every rank has transitively heard from every other rank."""
    rounds = []
    k = 1
    while k < n:
        rounds.append([(i, (i + k) % n) for i in range(n)])
        k *= 2
    return rounds


# ---------------------------------------------------------------------------
# Binomial tree (reduce toward root / bcast away from root)
# ---------------------------------------------------------------------------

def binomial_reduce_rounds(n: int, root: int = 0) -> List[Round]:
    """Classic binomial-tree reduce. Works for any n; ranks are rotated so
    ``root`` is tree-rank 0. Round k (k=0..): tree-ranks with bit k set send
    to (rank - 2^k) and retire."""
    rounds = []
    k = 1
    while k < n:
        rnd = []
        for r in range(n):
            if (r % (2 * k)) == k:         # sender at this round
                src = (r + root) % n
                dst = ((r - k) + root) % n
                rnd.append((src, dst))
        rounds.append(rnd)
        k *= 2
    return rounds


def binomial_bcast_rounds(n: int, root: int = 0) -> List[Round]:
    """Reverse of the reduce tree: root fans out in lg n rounds."""
    return [[(d, s) for (s, d) in rnd]
            for rnd in reversed(binomial_reduce_rounds(n, root))]


# ---------------------------------------------------------------------------
# Allreduce schedules
# ---------------------------------------------------------------------------

def ring_rounds(n: int) -> List[Round]:
    """One ring step: i -> i+1. Ring allreduce = 2(n-1) such steps
    (reduce-scatter then allgather), bandwidth-optimal: 2(n-1)/n · bytes."""
    return [[(i, (i + 1) % n) for i in range(n)]]


def recursive_doubling_rounds(n: int) -> List[Round]:
    """Round k: exchange with partner (rank XOR 2^k). lg n rounds, full
    vector each round — latency-optimal for small messages. Requires n
    power of two."""
    assert n & (n - 1) == 0, f"recursive doubling needs power-of-two n, got {n}"
    rounds = []
    k = 1
    while k < n:
        rounds.append([(i, i ^ k) for i in range(n)])
        k *= 2
    return rounds


# ---------------------------------------------------------------------------
# Two-level hierarchical composition (the paper's threadcomm-aware pattern)
# ---------------------------------------------------------------------------

def two_level_allreduce_plan(n_proc: int, m_thread: int) -> dict:
    """Describe the hierarchical allreduce over N processes × M threads:
    1. intra-process reduce-scatter over the M 'threads' (fast domain),
    2. inter-process allreduce on the 1/M shard (slow domain),
    3. intra-process allgather.
    Inter-process bytes drop by M× vs a flat allreduce — the quantitative
    content of the paper's 'use shared memory for the local part' insight."""
    return {
        "phases": [
            ("reduce_scatter", "thread", m_thread),
            ("allreduce", "process", n_proc),
            ("allgather", "thread", m_thread),
        ],
        "slow_domain_fraction": 1.0 / m_thread,
    }


# ---------------------------------------------------------------------------
# Simulation (oracle for property tests)
# ---------------------------------------------------------------------------

def simulate_knowledge(n: int, rounds: Sequence[Round]) -> List[set]:
    """Dataflow simulation: each rank starts knowing {itself}; a (src, dst)
    message transfers src's current knowledge set. Returns final knowledge."""
    know = [{i} for i in range(n)]
    for rnd in rounds:
        incoming = [set() for _ in range(n)]
        for src, dst in rnd:
            incoming[dst] |= know[src]
        for i in range(n):
            know[i] |= incoming[i]
    return know


def simulate_reduce(n: int, rounds: Sequence[Round], values=None):
    """Simulate a sum-reduce over the given rounds (sender's accumulator is
    added into the receiver's). Returns final accumulators."""
    acc = list(values) if values is not None else [float(i) for i in range(n)]
    for rnd in rounds:
        inc = [0.0] * n
        for src, dst in rnd:
            inc[dst] += acc[src]
        for i in range(n):
            acc[i] += inc[i]
    return acc


# ---------------------------------------------------------------------------
# Cost model (alpha-beta) — used by benchmarks & protocol selection
# ---------------------------------------------------------------------------

def allreduce_cost(n: int, nbytes: int, *, alpha: float, beta: float,
                   schedule: str) -> float:
    """Classic alpha (per-message latency) + beta (sec/byte) cost model."""
    lg = _ceil_log2(n)
    if schedule == "ring":
        steps = 2 * (n - 1)
        return steps * alpha + 2 * (n - 1) / n * nbytes * beta
    if schedule == "recursive_doubling":
        return lg * alpha + lg * nbytes * beta
    if schedule == "reduce_bcast":  # binomial reduce + binomial bcast
        return 2 * lg * alpha + 2 * lg * nbytes * beta
    raise ValueError(schedule)


def hierarchical_allreduce_cost(n_proc: int, m_thread: int, nbytes: int, *,
                                alpha_fast: float, beta_fast: float,
                                alpha_slow: float, beta_slow: float) -> float:
    """reduce-scatter(fast) + allreduce(slow on 1/M bytes) + allgather(fast)."""
    rs = (m_thread - 1) * alpha_fast + (m_thread - 1) / m_thread * nbytes * beta_fast
    ar = allreduce_cost(n_proc, nbytes // m_thread, alpha=alpha_slow,
                        beta=beta_slow, schedule="ring")
    ag = (m_thread - 1) * alpha_fast + (m_thread - 1) / m_thread * nbytes * beta_fast
    return rs + ar + ag


def flat_allreduce_cost(n_total: int, nbytes: int, *, alpha_slow: float,
                        beta_slow: float) -> float:
    """Rank-unaware flat ring over the slow domain (MPI-everywhere analogue:
    every hop may cross the slow links)."""
    return allreduce_cost(n_total, nbytes, alpha=alpha_slow, beta=beta_slow,
                          schedule="ring")
