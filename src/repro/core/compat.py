"""JAX version compatibility for the communication substrate.

The repo targets the modern ``jax.shard_map`` API (keyword ``mesh``,
``check_vma``, partial-manual via ``axis_names``). Older installs (< 0.5)
only ship ``jax.experimental.shard_map.shard_map`` with ``check_rep`` and
the complementary ``auto`` frozenset, and ``jax.make_mesh`` without
``axis_types``. Every internal call site goes through these wrappers so the
rest of the codebase is version-agnostic.
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax

_HAS_NATIVE = hasattr(jax, "shard_map")
if not _HAS_NATIVE:
    from jax.experimental.shard_map import shard_map as _exp_shard_map

# Old XLA miscompiles all-gather/ppermute/axis-index inside PARTIAL-manual
# regions (manual-subgroup sharding check failures in the SPMD partitioner).
# Callers that can degrade to a fully-manual region (redundant compute on
# the auto axes) should consult this flag.
HAS_PARTIAL_MANUAL = _HAS_NATIVE


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: Optional[bool] = None):
    """``jax.shard_map`` facade.

    axis_names: axes MANUAL inside ``f`` (None = all mesh axes). On old jax
    this lowers to the ``auto=`` complement of the experimental API.
    """
    if _HAS_NATIVE:
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = set(axis_names)
        if check_vma is not None:
            kw["check_vma"] = check_vma
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs,
                          check_rep=bool(check_vma) if check_vma is not None
                          else False, **kw)


def make_mesh(shape: Sequence[int], names: Sequence[str]) -> jax.sharding.Mesh:
    """``jax.make_mesh`` with all axes auto, on any supported jax."""
    if hasattr(jax.sharding, "AxisType"):
        try:
            return jax.make_mesh(
                tuple(shape), tuple(names),
                axis_types=(jax.sharding.AxisType.Auto,) * len(tuple(names)))
        except TypeError:
            pass
    return jax.make_mesh(tuple(shape), tuple(names))
