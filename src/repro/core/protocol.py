"""Message-protocol model: eager / rendezvous / 1-copy (paper §3.2, Fig. 3).

The paper's interthread messaging picks a protocol by message size:

  * eager  (≤ 4 KiB):   copy into a bounded shared cell, receiver copies out
                        (2 copies) — plus a fast path that skips the request
                        object for single-cell messages (lower latency).
  * 1-copy (> 4 KiB):   receiver copies directly from the sender buffer
                        (threads share the address space — no mapping cost).
  * interprocess eager (≤ 16 KiB) / rendezvous (> 16 KiB): 2 copies through
                        the shared-memory pool + header/ack handshake.

On TPU the mechanism adapts (DESIGN.md §2): cells become VMEM staging
buffers, 1-copy becomes a direct HBM→HBM DMA (see kernels/msgq). This module
is the quantitative model — an alpha-beta fit that reproduces the crossover
structure of Fig. 3 and drives protocol selection in p2p.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

# thresholds from the paper's evaluation (§4.1)
EAGER_THRESHOLD_INTERTHREAD = 4096      # bytes
EAGER_THRESHOLD_INTERPROCESS = 16384    # bytes
DEFAULT_CELL_SIZE = 4096                # shared-memory cell payload

# every protocol name the model knows; anything else is a caller bug and
# raises ValueError instead of silently taking the 1-copy branch
PROTOCOLS = ("eager_fast", "eager", "one_copy", "rndv")


def validate_protocol(name: str) -> str:
    if name not in PROTOCOLS:
        raise ValueError(
            f"unknown protocol {name!r}; known protocols: {PROTOCOLS}")
    return name


@dataclass(frozen=True)
class HostModel:
    """Per-message overheads (seconds) + copy bandwidth (bytes/sec), an
    alpha-beta fit in the spirit of the Xeon 5317 numbers in Fig. 3."""
    t_envelope: float = 8e-8      # assemble envelope + enqueue + match
    t_request: float = 6e-8       # request-object alloc/dealloc (skippable)
    t_handshake: float = 25e-8    # rndv/1-copy header + ack round trip
    t_map: float = 0.0            # address mapping (0 between threads)
    bw_copy: float = 12e9         # single-core memcpy bandwidth
    cell: int = DEFAULT_CELL_SIZE


@dataclass(frozen=True)
class TPUModel:
    """TPU analogue used by kernels/msgq accounting: VMEM-staged (2-copy)
    vs direct HBM DMA (1-copy)."""
    t_issue: float = 1e-6         # DMA descriptor issue
    bw_hbm: float = 819e9         # HBM bandwidth (v5e)
    vmem_cell: int = 64 * 1024    # VMEM staging cell


def interthread_latency(nbytes: int, m: HostModel = HostModel(),
                        proto: Optional[str] = None) -> float:
    """Latency of one interthread message under the paper's protocol.

    The protocol branch is derived from ``nbytes`` against the *model's
    own* cell size (so pricing always agrees with ``select_protocol`` for
    the same ``HostModel``); pass ``proto`` to price a forced protocol —
    e.g. an eager-class message re-routed to the rendezvous discipline
    because it could never fit the bounded cell pool.
    """
    if proto is None:
        proto = select_protocol(nbytes, interthread=True, cell=m.cell)
    else:
        validate_protocol(proto)
    if proto == "eager_fast":
        # eager fast path: request object skipped (paper's small-msg win)
        return m.t_envelope + 2 * nbytes / m.bw_copy
    if proto == "eager":
        return m.t_envelope + m.t_request + 2 * nbytes / m.bw_copy
    # 1-copy / rndv: handshake + a single copy, no address-mapping cost
    return (m.t_envelope + m.t_request + m.t_handshake + m.t_map
            + nbytes / m.bw_copy)


def chunked_handoff_latency(nbytes: int, chunk_bytes: int,
                            m: HostModel = HostModel()) -> float:
    """Rendezvous payload handed over incrementally in ``chunk_bytes``
    pieces (paper §3.2: the sender deposits only as the receiver posts).

    One handshake establishes the transfer, then every chunk pays an
    envelope (the per-piece notify/ack) while the payload itself still
    crosses exactly once. This is the admission price of a *chunked
    prefill*: the prompt streams into its decode slot chunk-by-chunk,
    interleaved with decode micro-steps, instead of one monolithic copy.
    """
    if chunk_bytes < 1:
        raise ValueError("chunk_bytes must be >= 1")
    nchunks = max(1, -(-nbytes // chunk_bytes))
    return (m.t_envelope + m.t_request + m.t_handshake + m.t_map
            + nchunks * m.t_envelope + nbytes / m.bw_copy)


def paged_admission_latency(nbytes: int, chunk_bytes: int, block_bytes: int,
                            m: HostModel = HostModel()) -> float:
    """Admission price of a *paged* chunked deposit: the chunked handoff
    (one handshake + per-chunk envelopes, payload crossing once) plus a
    quarter-envelope per KV block the payload will occupy — the block
    table entry writes, priced like the multi-cell surcharge in
    :func:`interprocess_latency`. This is what a block-aware scheduler
    charges when the prompt lands in pool blocks leased through a table
    instead of one contiguous slot."""
    if block_bytes < 1:
        raise ValueError("block_bytes must be >= 1")
    nblocks = max(1, -(-nbytes // block_bytes))
    return (chunked_handoff_latency(nbytes, chunk_bytes, m)
            + nblocks * m.t_envelope * 0.25)


def prefix_hit_latency(nbytes: int, block_bytes: int,
                       m: HostModel = HostModel(),
                       cow_blocks: int = 0) -> float:
    """Admission price of the cache-hit fraction of a prompt (prefix
    caching, DESIGN.md §12).

    A radix-cache hit is the paper's shared-address-space argument
    applied to prefill: the KV for these tokens is already resident in
    the block pool, so admitting them is a *lease handoff*, not a
    recompute-and-copy. One rendezvous handshake claims the cached path,
    then each hit block pays the same quarter-envelope table-entry
    surcharge that :func:`paged_admission_latency` charges — and nothing
    else: the payload never crosses, which is the whole win over the
    chunked deposit. Each copy-on-write clone (a shared block the
    request must diverge from) adds one block-sized interthread copy,
    the only payload motion on the hit path.
    """
    if block_bytes < 1:
        raise ValueError("block_bytes must be >= 1")
    nblocks = max(0, -(-max(0, nbytes) // block_bytes))
    cost = m.t_handshake + nblocks * m.t_envelope * 0.25
    if cow_blocks > 0:
        cost += cow_blocks * interthread_latency(block_bytes, m)
    return cost


def kv_migration_latency(nbytes: int, block_bytes: int,
                         m: HostModel = HostModel()) -> float:
    """Price of migrating a finished prefill's KV to another rank
    *block-by-block* (the disaggregated serving fabric's handoff,
    DESIGN.md §10).

    One rendezvous handshake establishes the transfer — the decode rank
    has already leased the destination blocks (the posted receive), so
    the lease travels, not the recomputation — then every block is its
    own message priced under the protocol the *block* payload selects
    (KV blocks are normally 1-copy sized; a tiny tail block may ride the
    eager path). The per-block envelope is what bounds decode stalls:
    the receiver can start decoding as soon as the last block lands,
    and no single message ever exceeds one block.
    """
    if block_bytes < 1:
        raise ValueError("block_bytes must be >= 1")
    full, tail = divmod(max(0, nbytes), block_bytes)
    cost = m.t_handshake + full * interthread_latency(block_bytes, m)
    if tail:
        cost += interthread_latency(tail, m)
    return cost


def speculative_verify_latency(k: int, token_bytes: int = 4,
                               m: HostModel = HostModel()) -> float:
    """Price of one draft–verify round of speculative decoding
    (DESIGN.md §14): the drafter hands its k proposed tokens to the
    target's verify stream, the target runs ONE fused (k+1)-query
    dispatch (each teacher-forced token is its own envelope — the
    dispatch is one message batch, not k+1 handshakes), and the accepted
    prefix travels back to the drafter so it can resync.

    Three legs, all interthread (drafter and target are threads of one
    serving process, so payloads move at shared-address-space cost):

      1. draft handoff — k token ids, priced by the protocol their size
         selects (always eager_fast at practical k);
      2. verify dispatch — one handshake to claim the verify stream plus
         an envelope and payload-copy per teacher-forced token (k drafts
         + the current token);
      3. acceptance return — up to k+1 accepted token ids back.

    The round replaces up to k+1 single-token decode dispatches, each of
    which would have paid its own envelope — the model prices exactly the
    messaging the fusion saves, which is what the scheduler's
    ``spec_modeled_cost_s`` accounting aggregates."""
    if k < 1:
        raise ValueError("speculative_verify_latency: k must be >= 1")
    draft_handoff = interthread_latency(k * token_bytes, m)
    verify = (m.t_handshake + (k + 1) * m.t_envelope
              + (k + 1) * token_bytes / m.bw_copy)
    accept_return = interthread_latency((k + 1) * token_bytes, m)
    return draft_handoff + verify + accept_return


def interprocess_latency(nbytes: int, m: HostModel = HostModel()) -> float:
    """MPI-everywhere shared-memory messaging (eager / rndv, always 2-copy)."""
    if nbytes <= EAGER_THRESHOLD_INTERPROCESS:
        ncells = -(-nbytes // m.cell)
        return (m.t_envelope + m.t_request + 2 * nbytes / m.bw_copy
                + (ncells - 1) * m.t_envelope * 0.25)
    return (m.t_envelope + m.t_request + m.t_handshake
            + 2 * nbytes / m.bw_copy)


def select_protocol(nbytes: int, interthread: bool = True,
                    cell: int = DEFAULT_CELL_SIZE) -> str:
    if interthread:
        if nbytes <= min(cell, EAGER_THRESHOLD_INTERTHREAD):
            return "eager_fast"   # single cell: request object skipped
        if nbytes <= EAGER_THRESHOLD_INTERTHREAD:
            return "eager"        # multi-cell eager (cell < threshold configs)
        return "one_copy"
    return "eager" if nbytes <= EAGER_THRESHOLD_INTERPROCESS else "rndv"


def request_overhead(nbytes: int, proto: Optional[str] = None,
                     m: HostModel = HostModel()) -> float:
    """Request-object cost (seconds) of a nonblocking op under the paper's
    protocol: the eager fast path for single-cell messages SKIPS request
    allocation entirely (§3.2) — the small-message latency win that
    ``Comm.isend`` surfaces on its returned ``Request``."""
    proto = validate_protocol(proto) if proto else select_protocol(nbytes)
    return 0.0 if proto == "eager_fast" else m.t_request


def bandwidth(nbytes: int, latency_s: float) -> float:
    return nbytes / latency_s


def tpu_staged_copy_time(nbytes: int, m: TPUModel = TPUModel()) -> float:
    """2-copy through VMEM cells (eager analogue)."""
    ncells = -(-nbytes // m.vmem_cell)
    return ncells * m.t_issue + 2 * nbytes / m.bw_hbm


def tpu_direct_copy_time(nbytes: int, m: TPUModel = TPUModel()) -> float:
    """1-copy direct HBM DMA."""
    return m.t_issue + nbytes / m.bw_hbm
