"""The paper's primary contribution: MPIX Threadcomm adapted to JAX.

- comm.py:        the unified ``Comm`` API — root ThreadComm, split/dup
                  sub-communicators, Request-based nonblocking ops, and
                  stream-bound contexts (MPIX stream analogue)
- threadcomm.py:  back-compat facade over comm.py
- schedules.py:   dissemination/binomial/ring/recursive-doubling schedules
- collectives.py: executable shard_map collectives (explicit + fused + 2-level)
- p2p.py:         rank-addressed messaging w/ eager|1-copy protocol selection
- protocol.py:    the Fig.3 latency/bandwidth protocol model
- compat.py:      shard_map/make_mesh facade across jax versions
"""

from repro.core.comm import (AxisComm, Comm, CommError, CommStream,  # noqa: F401
                             Group, GroupComm, Request, ThreadComm,
                             ThreadCommError, threadcomm_init, testall,
                             waitall)
from repro.core import collectives, p2p, protocol, schedules  # noqa: F401
