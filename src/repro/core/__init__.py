"""The paper's primary contribution: MPIX Threadcomm adapted to JAX.

- threadcomm.py:  unified N×M rank space + MPIX lifecycle semantics
- schedules.py:   dissemination/binomial/ring/recursive-doubling schedules
- collectives.py: executable shard_map collectives (explicit + fused + 2-level)
- p2p.py:         rank-addressed messaging w/ eager|1-copy protocol selection
- protocol.py:    the Fig.3 latency/bandwidth protocol model
"""

from repro.core.threadcomm import (ThreadComm, ThreadCommError, Group,
                                   threadcomm_init)  # noqa: F401
from repro.core import collectives, p2p, protocol, schedules  # noqa: F401
