"""MPIX Threadcomm, adapted to JAX: a unified N×M rank space over a
hierarchical device mesh.

The paper (§2) fuses an N-process MPI world with M-thread OpenMP regions
into one communicator of N×M ranks with process-major ordering. Here the
"processes" are the slow-domain mesh axes (inter-pod) and the "threads" are
the fast-domain axes (intra-pod chips): ``rank = proc_index * M + thread_index``.

Lifecycle mirrors the MPIX API and is enforced:

    tc = threadcomm_init(mesh, process_axes, thread_axes)   # heavy, collective
    with tc.start():                                        # light, activates
        tc.allreduce(...)  /  tc.run(fn, ...)               # unified-rank comm
    # finish() implicit at context exit — derived objects invalidated
    tc.free()                                               # releases the comm

``init`` builds the rank table (the paper's heavy allreduce-on-thread-counts
step becomes a host-side enumeration of mesh coordinates). ``start`` is the
cheap per-region activation. Derived objects (groups) carry the activation
epoch and refuse to operate across ``finish`` — the paper's "threadcomm-
derived objects live within the activation window" rule.
"""

from __future__ import annotations

import contextlib
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Callable, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core import collectives as coll


class ThreadCommError(RuntimeError):
    pass


@dataclass
class Group:
    """A subset of unified ranks derived from an active threadcomm.
    Valid only within the activation window that created it (paper §2)."""
    comm: "ThreadComm"
    ranks: Tuple[int, ...]
    _epoch: int = 0

    def _check(self):
        self.comm._check_active()
        if self._epoch != self.comm._epoch:
            raise ThreadCommError(
                "group outlived its threadcomm activation window "
                "(derived objects die at MPIX_Threadcomm_finish)")

    @property
    def size(self) -> int:
        self._check()
        return len(self.ranks)

    def translate(self, rank: int) -> int:
        self._check()
        return self.ranks[rank]


class ThreadComm:
    """Unified communicator over ``process_axes`` × ``thread_axes``."""

    def __init__(self, mesh: jax.sharding.Mesh,
                 process_axes: Sequence[str],
                 thread_axes: Sequence[str]):
        names = mesh.axis_names
        for ax in (*process_axes, *thread_axes):
            if ax not in names:
                raise ThreadCommError(f"axis {ax!r} not in mesh {names}")
        if set(process_axes) & set(thread_axes):
            raise ThreadCommError("process and thread axes must be disjoint")
        self.mesh = mesh
        self.process_axes = tuple(process_axes)
        self.thread_axes = tuple(thread_axes)
        self._active = False
        self._freed = False
        self._epoch = 0
        self._attrs = {}
        # --- rank table (the 'heavy' init step) ---
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        self.num_processes = math.prod(sizes[a] for a in self.process_axes) \
            if self.process_axes else 1
        self.threads_per_process = math.prod(
            sizes[a] for a in self.thread_axes) if self.thread_axes else 1
        self.size = self.num_processes * self.threads_per_process
        self._axis_sizes = sizes

    # ------------------------------------------------------------------
    # lifecycle
    # ------------------------------------------------------------------
    def _check_not_freed(self):
        if self._freed:
            raise ThreadCommError("threadcomm already freed")

    def _check_active(self):
        self._check_not_freed()
        if not self._active:
            raise ThreadCommError(
                "threadcomm is inactive: call start() (MPIX_Threadcomm_start)"
                " before communicating")

    @contextlib.contextmanager
    def start(self):
        """Activate the communicator (MPIX_Threadcomm_start/finish pair)."""
        self._check_not_freed()
        if self._active:
            raise ThreadCommError("threadcomm already active (nested start)")
        self._active = True
        try:
            yield self
        finally:
            self._active = False
            self._attrs.clear()   # attribute lifetime = activation window
            self._epoch += 1

    def free(self):
        self._check_not_freed()
        if self._active:
            raise ThreadCommError("cannot free an active threadcomm "
                                  "(call finish first)")
        self._freed = True

    # ------------------------------------------------------------------
    # rank arithmetic (host side)
    # ------------------------------------------------------------------
    @property
    def unified_axes(self) -> Tuple[str, ...]:
        return self.process_axes + self.thread_axes

    def rank_of(self, coords: dict) -> int:
        """Unified rank for mesh coordinates — process-major (paper §2)."""
        r = 0
        for ax in self.unified_axes:
            r = r * self._axis_sizes[ax] + coords[ax]
        return r

    def coords_of(self, rank: int) -> dict:
        out = {}
        for ax in reversed(self.unified_axes):
            out[ax] = rank % self._axis_sizes[ax]
            rank //= self._axis_sizes[ax]
        return out

    def process_of(self, rank: int) -> int:
        return rank // self.threads_per_process

    def group(self, ranks: Sequence[int]) -> Group:
        self._check_active()
        return Group(self, tuple(ranks), _epoch=self._epoch)

    # attributes (paper: lifetime bounded by the activation window)
    def set_attr(self, key, value):
        self._check_active()
        self._attrs[key] = value

    def get_attr(self, key):
        self._check_active()
        return self._attrs.get(key)

    # ------------------------------------------------------------------
    # device-side rank (call inside shard_map)
    # ------------------------------------------------------------------
    def device_rank(self):
        r = np.int32(0)
        for ax in self.unified_axes:
            r = r * self._axis_sizes[ax] + lax.axis_index(ax)
        return r

    # ------------------------------------------------------------------
    # collectives over the unified rank space
    # ------------------------------------------------------------------
    def run(self, fn: Callable, *args,
            in_specs=None, out_specs=None):
        """shard_map a function over the full unified mesh. Default specs
        shard the leading dim over all unified axes (SPMD over ranks)."""
        self._check_active()
        in_specs = in_specs if in_specs is not None else P(self.unified_axes)
        out_specs = out_specs if out_specs is not None else P(self.unified_axes)
        return jax.shard_map(fn, mesh=self.mesh, in_specs=in_specs,
                             out_specs=out_specs)(*args)

    # The following helpers are meant to be CALLED INSIDE a shard_map /
    # tc.run region. They delegate to repro.core.collectives with the
    # unified axes so flat schedules span all N*M ranks.
    def allreduce(self, x, schedule: str = "psum"):
        self._check_active()
        if schedule == "hierarchical":
            return coll.hierarchical_allreduce(
                x, process_axes=self.process_axes,
                thread_axes=self.thread_axes)
        return coll.allreduce(x, self.unified_axes, schedule=schedule)

    def barrier(self, token, mode: str = "msg"):
        self._check_active()
        return coll.barrier(token, self.unified_axes, mode=mode)

    def reduce(self, x, root: int = 0, schedule: str = "binomial"):
        self._check_active()
        return coll.reduce(x, self.unified_axes, root=root, schedule=schedule)

    def bcast(self, x, root: int = 0):
        self._check_active()
        return coll.bcast(x, self.unified_axes, root=root)

    def allgather(self, x, tiled: bool = True):
        self._check_active()
        return coll.allgather(x, self.unified_axes, tiled=tiled)

    def reduce_scatter(self, x):
        self._check_active()
        return coll.reduce_scatter(x, self.unified_axes)

    def alltoall(self, x):
        self._check_active()
        return coll.alltoall(x, self.unified_axes)

    def send_recv(self, x, pairs):
        self._check_active()
        return coll.sendrecv(x, self.unified_axes, pairs)


def threadcomm_init(mesh, process_axes: Sequence[str] = (),
                    thread_axes: Sequence[str] = None,
                    num_threads: Optional[int] = None) -> ThreadComm:
    """MPIX_Threadcomm_init analogue. ``num_threads``, when given, must match
    the thread-axes product (the paper's creation-parameter check)."""
    if thread_axes is None:
        thread_axes = tuple(a for a in mesh.axis_names
                            if a not in tuple(process_axes))
    tc = ThreadComm(mesh, process_axes, thread_axes)
    if num_threads is not None and num_threads != tc.threads_per_process:
        raise ThreadCommError(
            f"num_threads={num_threads} does not match the parallel region "
            f"width {tc.threads_per_process}")
    return tc
