"""MPIX Threadcomm, adapted to JAX — back-compat facade.

The communicator implementation now lives in :mod:`repro.core.comm`, where
the root :class:`~repro.core.comm.ThreadComm` is one instance of the
unified ``Comm`` interface (split/dup sub-communicators, request-based
nonblocking ops, stream-bound contexts). This module keeps the original
import surface::

    from repro.core.threadcomm import ThreadComm, threadcomm_init

Lifecycle (unchanged, paper §2):

    tc = threadcomm_init(mesh, process_axes, thread_axes)   # heavy, collective
    with tc.start():                                        # light, activates
        tc.allreduce(...)  /  tc.run(fn, ...)               # unified-rank comm
    # finish() implicit at context exit — derived objects invalidated
    tc.free()                                               # releases the comm
"""

from repro.core.comm import (AxisComm, Comm, CommError, CommStream,  # noqa: F401
                             Group, GroupComm, Request, ThreadComm,
                             ThreadCommError, threadcomm_init, testall,
                             waitall)
