"""Point-to-point messaging over threadcomm ranks.

JAX programs are statically scheduled SPMD, so p2p is rank-addressed
``ppermute`` (no tag matching / unexpected-message queue — see DESIGN.md §7:
the ordering hazard that makes MPI_THREAD_MULTIPLE slow does not exist under
a static schedule; this IS the TPU-native realization of "the library knows
the thread context").

Protocol selection (eager vs 1-copy) follows the paper's thresholds; on the
wire both lower to collective-permute, but the eager path pads tiny messages
into fixed cells (aggregation-friendly, modeled in protocol.py) while the
1-copy path moves the buffer directly. ``kernels/msgq`` implements the
intra-device staging mechanics as a Pallas kernel.

This is the mechanism layer: user code addresses messages through
``Comm.send_recv`` / ``Comm.isend`` (:mod:`repro.core.comm`), which
translate comm-local ranks and attach the request/stream semantics.
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

from repro.core import protocol
from repro.core.collectives import Axes


def send_recv(x, axes: Axes, pairs: Sequence[Tuple[int, int]], *,
              force_protocol: Optional[str] = None):
    """One message round over unified ranks. Returns (received, proto).

    Small payloads (≤ cell) are padded to the cell size — the eager protocol's
    fixed-cell enqueue; large payloads go through unpadded (1-copy). An
    unknown ``force_protocol`` raises :class:`ValueError` (it must never
    silently fall through to the 1-copy branch).
    """
    nbytes = x.size * x.dtype.itemsize
    proto = (protocol.validate_protocol(force_protocol) if force_protocol
             else protocol.select_protocol(nbytes))
    if proto in ("eager_fast", "eager"):
        cell_elems = max(1, protocol.DEFAULT_CELL_SIZE // x.dtype.itemsize)
        flat = x.reshape(-1)
        pad = (-flat.size) % cell_elems if flat.size else cell_elems
        padded = jnp.pad(flat, (0, pad)) if pad else flat
        recv = lax.ppermute(padded, axes, list(pairs))
        recv = recv[:flat.size].reshape(x.shape)
    else:
        recv = lax.ppermute(x, axes, list(pairs))
    return recv, proto


def shift(x, axes: Axes, n: int, offset: int = 1):
    """Ring shift by ``offset`` over n unified ranks (halo-exchange helper)."""
    perm = [(i, (i + offset) % n) for i in range(n)]
    return lax.ppermute(x, axes, perm)


def halo_exchange_1d(x, axes: Axes, n: int):
    """Exchange boundary slabs with both ring neighbours (the SpMV / stencil
    pattern of the PETSc case study §4.3). x: (local_n, ...) — returns
    (from_left, from_right) slabs of x's boundary rows."""
    left_edge = x[:1]
    right_edge = x[-1:]
    from_left = lax.ppermute(right_edge, axes,
                             [(i, (i + 1) % n) for i in range(n)])
    from_right = lax.ppermute(left_edge, axes,
                              [(i, (i - 1) % n) for i in range(n)])
    return from_left, from_right
