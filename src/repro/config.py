"""Configuration system for the repro framework.

Dataclass-based, explicit, and hashable-where-needed so configs can be closed
over by jit'd functions as static data. One ``ModelConfig`` instance fully
describes an architecture; ``ShapeConfig`` describes a workload cell;
``MeshConfig`` describes the device mesh; ``TrainConfig``/``ServeConfig``
describe the execution knobs.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Tuple

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

# Block families. A model is a stack of identical-structure blocks (so layer
# params can be stacked and scanned) of one of these kinds, plus embeddings.
BLOCK_DENSE = "dense"          # attn + gated MLP
BLOCK_MOE = "moe"              # attn + mixture-of-experts FFN
BLOCK_SSM = "ssm"              # Mamba2 SSD block (attention-free)
BLOCK_HYBRID = "hybrid"        # parallel attn + SSM heads (Hymba), + MLP


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    block: str                       # one of BLOCK_*
    num_layers: int
    d_model: int
    num_heads: int                   # query heads (0 for attention-free)
    num_kv_heads: int
    head_dim: int
    d_ff: int                        # per-expert FFN hidden dim for MoE
    vocab_size: int

    # --- attention details ---
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    # sliding-window attention: window size (0 = full attention everywhere)
    swa_window: int = 0
    # layer indices that use full/global attention even when swa_window > 0
    global_layers: Tuple[int, ...] = ()
    logit_softcap: float = 0.0

    # --- MLP ---
    mlp_act: str = "swiglu"          # swiglu | geglu | gelu (ungated)

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 512        # token group size for GShard-style dispatch
    capacity_factor: float = 1.25

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_d_inner: int = 0
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 128             # SSD chunk length

    # --- norms / embeddings ---
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False        # gemma-style sqrt(d_model) input scaling
    rmsnorm_unit_offset: bool = False  # gemma-style (1 + w) RMSNorm weight

    # --- encoder-decoder (whisper) ---
    is_encoder_decoder: bool = False
    num_encoder_layers: int = 0
    encoder_seq: int = 1500          # stub frontend sequence length

    # --- modality frontend stub (vlm/audio) ---
    frontend: str = "none"           # none | patch_stub | audio_stub
    num_frontend_tokens: int = 0     # e.g. ViT patch tokens prepended

    # --- positional embedding ---
    pos_embed: str = "rope"          # rope | learned | sinusoidal | none

    # ------------------------------------------------------------------
    @property
    def ssm_heads(self) -> int:
        if self.ssm_d_inner == 0:
            return 0
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to a multiple of 128 so the model axis always divides
        it (Megatron convention); logits beyond vocab_size are masked."""
        return pad_to_multiple(self.vocab_size, 128)

    @property
    def uses_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch supports O(seq)-bounded decode state (ring-buffer
        windows and/or SSM state) — gates the long_500k shape."""
        if self.block == BLOCK_SSM:
            return True
        if self.block == BLOCK_HYBRID and self.swa_window > 0:
            return True
        return False

    def param_count(self) -> int:
        """Analytical parameter count (embedding + blocks), for 6ND."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.padded_vocab
        n = 0
        n += V * d                                     # embed
        if not self.tie_embeddings:
            n += V * d                                 # lm head
        per_layer = 0
        if self.uses_attention:
            per_layer += d * self.num_heads * self.head_dim        # wq
            per_layer += 2 * d * self.num_kv_heads * self.head_dim  # wk, wv
            per_layer += self.num_heads * self.head_dim * d        # wo
        if self.block in (BLOCK_DENSE, BLOCK_HYBRID):
            gates = 2 if self.mlp_act in ("swiglu", "geglu") else 1
            per_layer += (gates + 1) * d * f
        if self.block == BLOCK_MOE:
            gates = 2 if self.mlp_act in ("swiglu", "geglu") else 1
            per_layer += self.num_experts * (gates + 1) * d * f
            per_layer += d * self.num_experts                      # router
        if self.block in (BLOCK_SSM, BLOCK_HYBRID):
            di, s, h = self.ssm_d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * di + 2 * s + h)      # in projections (z,x,B,C,dt)
            per_layer += self.ssm_conv * di            # depthwise conv
            per_layer += 3 * h + di                    # A_log, D, dt_bias, gated norm
            per_layer += di * d                        # out_proj
        n += L * per_layer
        if self.is_encoder_decoder:
            # encoder blocks (self-attn + MLP) and decoder cross-attn
            enc = self.num_encoder_layers * (
                4 * d * self.num_heads * self.head_dim + 2 * d * f)
            xattn = L * 4 * d * self.num_heads * self.head_dim
            n += enc + xattn
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top_k experts)."""
        if self.block != BLOCK_MOE:
            return self.param_count()
        d, f, L = self.d_model, self.d_ff, self.num_layers
        gates = 2 if self.mlp_act in ("swiglu", "geglu") else 1
        inactive = L * (self.num_experts - self.top_k) * (gates + 1) * d * f
        return self.param_count() - inactive


# ---------------------------------------------------------------------------
# Workload shapes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether a (model, shape) cell is runnable; returns (ok, reason)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "full-attention arch: long_500k skipped (assignment rule)"
    return True, ""


# ---------------------------------------------------------------------------
# Mesh
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    # which axes carry the batch dim, which carry tensor parallelism, and
    # which are the "process-level" (inter-pod) axes for ThreadComm
    batch_axes: Tuple[str, ...] = ("data",)
    model_axes: Tuple[str, ...] = ("model",)
    process_axes: Tuple[str, ...] = ()

    @property
    def num_devices(self) -> int:
        return math.prod(self.shape)

    def axis_size(self, name: str) -> int:
        return self.shape[self.axis_names.index(name)]

    @property
    def dp(self) -> int:
        return math.prod(self.axis_size(a) for a in self.batch_axes + self.process_axes)

    @property
    def tp(self) -> int:
        return math.prod(self.axis_size(a) for a in self.model_axes)


SINGLE_POD = MeshConfig(shape=(16, 16), axis_names=("data", "model"))
MULTI_POD = MeshConfig(
    shape=(2, 16, 16), axis_names=("pod", "data", "model"),
    process_axes=("pod",))
# small meshes for CPU tests
TEST_MESH_8 = MeshConfig(shape=(2, 4), axis_names=("data", "model"))
TEST_FLAT_8 = MeshConfig(shape=(8,), axis_names=("ranks",), batch_axes=("ranks",),
                         model_axes=())

MESHES = {"single_pod": SINGLE_POD, "multi_pod": MULTI_POD,
          "test8": TEST_MESH_8, "flat8": TEST_FLAT_8}


# ---------------------------------------------------------------------------
# Training / serving knobs
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1_000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    # gradient synchronization: "spmd" (XLA-inserted), "flat" (explicit flat
    # psum = MPI-everywhere analogue), "threadcomm" (explicit two-level
    # hierarchical schedule = the paper's technique)
    grad_sync: str = "spmd"
    remat: bool = True
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # gradient accumulation: split the global batch into k sequential
    # microbatches inside the step (activation memory drops ~k×)
    microbatches: int = 1
    # FSDP-shard MoE expert weights over the data axis (see sharding.py)
    moe_fsdp: bool = True
    # wire dtype for explicit gradient collectives ("bfloat16" halves the
    # reduce-scatter bytes — level-1 gradient compression)
    grad_comm_dtype: str = "float32"
    # FSDP at all (False = replicate params over the data axes; right for
    # small models where weight gathers dominate the collective term)
    fsdp: bool = True
    # cross-entropy computed in seq chunks of this size to bound logits memory
    loss_chunk: int = 512
    # attention switches to chunked online-softmax above this seq length
    attn_chunk_threshold: int = 2_048
    attn_chunk: int = 512
    # kv-block size for the chunked path (0 = same as attn_chunk); the
    # backward saves O(S²/chunk_kv) online-softmax carries per layer, so
    # training wants this LARGE (see §Perf)
    attn_chunk_kv: int = 0
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    attn_chunk_threshold: int = 2_048
    attn_chunk: int = 512
    # ring-buffer KV window for long-context decode (sub-quadratic archs)
    ring_buffer: bool = False


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig
    train: TrainConfig = field(default_factory=TrainConfig)
    serve: ServeConfig = field(default_factory=ServeConfig)

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)
