"""Sharding rules: PartitionSpec trees for params, caches, and batches.

One place owns the mapping from (ModelConfig, MeshConfig) to device layout:

  * tensor parallelism (Megatron-style): attention heads, MLP hidden dim and
    the vocab dim shard over ``mesh_cfg.model_axes``;
  * FSDP / ZeRO: the remaining large dim of each weight shards over
    ``mesh_cfg.batch_axes`` (optimizer state mirrors it — see
    train/trainer.py ``state_pspecs``);
  * MoE expert weights additionally shard the expert dim over the batch
    axes (``moe_fsdp``);
  * batches shard their leading dim over process axes × batch axes in
    process-major order — the same unified-rank order the threadcomm /
    ``Comm`` layer uses (DESIGN.md §2), so explicit-collective trainers and
    SPMD trainers see identical data placement.

Every rule is guarded by divisibility: a dim that the axis product does not
divide is left unsharded rather than producing an invalid NamedSharding.
Rules key off leaf *names* (the init functions in models/ use stable names:
wq/wk/wv/wo, w_gate/w_up/w_down, embed/lm_head, in_proj/out_proj, ...), so
new architectures inherit sensible layouts for free.
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.config import MeshConfig, ModelConfig

# tree keys whose children carry a stacked leading layer dim (vmap'd init)
_STACKED_KEYS = ("blocks", "enc_blocks", "dec_blocks")


def _axis_sizes(mesh_cfg: MeshConfig) -> dict:
    return dict(zip(mesh_cfg.axis_names, mesh_cfg.shape))


def _axes_prod(mesh_cfg: MeshConfig, axes: Tuple[str, ...]) -> int:
    sizes = _axis_sizes(mesh_cfg)
    return math.prod(sizes[a] for a in axes) if axes else 1


def _axes_or_none(axes: Tuple[str, ...]):
    """A PartitionSpec entry: tuple for multi-axis dims, name for one, None
    for zero (an empty tuple in a spec is invalid)."""
    if not axes:
        return None
    return axes[0] if len(axes) == 1 else tuple(axes)


def batch_axes(mesh_cfg: MeshConfig):
    """Mesh axes of the batch dim of activations/batches: process-major over
    (process_axes, batch_axes) — the unified-rank order of DESIGN.md §2."""
    return _axes_or_none(tuple(mesh_cfg.process_axes) + tuple(mesh_cfg.batch_axes))


def batch_pspec(mesh_cfg: MeshConfig) -> P:
    """Spec for data batches: leading dim sharded over the full data-parallel
    domain (slow process axes major, fast batch axes minor)."""
    ax = batch_axes(mesh_cfg)
    return P() if ax is None else P(ax)


def named_sharding(mesh: jax.sharding.Mesh, spec_tree: Any):
    """Map a PartitionSpec tree to a NamedSharding tree on ``mesh``."""
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _path_names(path) -> Tuple[str, ...]:
    out = []
    for entry in path:
        if hasattr(entry, "key"):
            out.append(str(entry.key))
        elif hasattr(entry, "name"):
            out.append(str(entry.name))
        else:
            out.append(str(entry))
    return tuple(out)


# name -> (tp_dim, fsdp_dim) in the UNSTACKED leaf shape; fsdp_dim None means
# the leaf never FSDP-shards (biases, norms, small vectors)
_DENSE_RULES = {
    "wq": (1, 0), "wk": (1, 0), "wv": (1, 0),   # (d, H, hd): heads on TP
    "wo": (0, 2),                                # (H, hd, d)
    "bq": (0, None), "bk": (0, None), "bv": (0, None),   # (H, hd)
    "w_gate": (1, 0), "w_up": (1, 0),            # (d, f): hidden on TP
    "w_down": (0, 1),                            # (f, d)
    "embed": (0, 1),                             # (V, d): vocab-parallel
    "lm_head": (1, 0),                           # (d, V)
    "dec_pos": (None, 1),                        # (maxpos, d)
    "in_proj": (1, 0),                           # (d, 2di+2n+h)
    "out_proj": (0, 1),                          # (di, d)
}
# MoE expert weights carry a leading expert dim: (E, d, f) / (E, f, d)
_MOE_RULES = {
    "w_gate": (2, 1), "w_up": (2, 1),
    "w_down": (1, 2),
}


def param_pspecs(cfg: ModelConfig, mesh_cfg: MeshConfig, params: Any,
                 *, moe_fsdp: bool = True, fsdp: bool = True):
    """PartitionSpec tree matching ``params`` (arrays or ShapeDtypeStructs).

    TP shards over ``model_axes``; FSDP shards a second dim over
    ``batch_axes`` when enabled and divisible; MoE experts shard over the
    batch axes when ``moe_fsdp``. Anything unmatched is replicated.
    """
    tp_axes = tuple(mesh_cfg.model_axes)
    dp_axes = tuple(mesh_cfg.batch_axes)
    tp = _axes_prod(mesh_cfg, tp_axes)
    dp = _axes_prod(mesh_cfg, dp_axes)

    def spec_for(path, leaf) -> P:
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = tuple(leaf.shape)
        stacked = any(k in names[:-1] for k in _STACKED_KEYS)
        off = 1 if stacked else 0

        rules = _MOE_RULES if "moe" in names[:-1] else _DENSE_RULES
        rule = rules.get(name)
        if rule is None:
            return P()
        tp_dim, fsdp_dim = rule
        entries = [None] * len(shape)
        if (tp_dim is not None and tp > 1
                and tp_dim + off < len(shape)
                and shape[tp_dim + off] % tp == 0):
            entries[tp_dim + off] = _axes_or_none(tp_axes)
        if (fsdp and fsdp_dim is not None and dp > 1
                and fsdp_dim + off < len(shape)
                and shape[fsdp_dim + off] % dp == 0):
            entries[fsdp_dim + off] = _axes_or_none(dp_axes)
        # MoE expert dim over the batch axes (expert parallelism as FSDP)
        if ("moe" in names[:-1] and moe_fsdp and dp > 1
                and len(shape) > off and shape[off] % dp == 0
                and entries[off] is None):
            entries[off] = _axes_or_none(dp_axes)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, params)


# ---------------------------------------------------------------------------
# Decode caches
# ---------------------------------------------------------------------------

def cache_pspecs(cfg: ModelConfig, mesh_cfg: MeshConfig, cache: Any):
    """Specs for the stacked (L, B, ...) decode-cache pytree: batch dim over
    the data-parallel domain, kv heads over TP when they divide."""
    tp_axes = tuple(mesh_cfg.model_axes)
    tp = _axes_prod(mesh_cfg, tp_axes)
    dp_all = tuple(mesh_cfg.process_axes) + tuple(mesh_cfg.batch_axes)
    dp = _axes_prod(mesh_cfg, dp_all)
    b_ax = _axes_or_none(dp_all)

    def spec_for(path, leaf) -> P:
        name = _path_names(path)[-1]
        shape = tuple(leaf.shape)
        if name == "pos" or len(shape) < 2:
            return P()
        entries = [None] * len(shape)
        if dp > 1 and shape[1] % dp == 0:
            entries[1] = b_ax
        # kv / state head dims: (L, B, S, G, hd) or (L, B, H, p, n)
        head_dim = {"k": 3, "v": 3, "cross_k": 3, "cross_v": 3, "ssm": 2}.get(name)
        if (head_dim is not None and tp > 1 and head_dim < len(shape)
                and shape[head_dim] % tp == 0):
            entries[head_dim] = _axes_or_none(tp_axes)
        while entries and entries[-1] is None:
            entries.pop()
        return P(*entries)

    return jax.tree_util.tree_map_with_path(spec_for, cache)
