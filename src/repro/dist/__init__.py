"""Distribution layer: sharding rules over the hierarchical device mesh.

``repro.dist.sharding`` turns (ModelConfig, MeshConfig, pytree) into
PartitionSpec trees; ``repro.core.comm`` (the communication layer) consumes
the same mesh axes for explicit collectives. Keeping the two in one `dist`
namespace is the architectural seam the ROADMAP's sharding/async growth
hangs off.
"""

from repro.dist.sharding import (batch_axes, batch_pspec, cache_pspecs,  # noqa: F401
                                 named_sharding, param_pspecs)
