"""Radix-tree prefix cache over the paged KV block pool (DESIGN.md §12).

The paper's threadcomm argument — ranks sharing an address space should
*share*, not re-copy — applied to prefill: identical prompt prefixes
across requests denote identical KV blocks, and :class:`BlockPool` has
carried per-block refcounts for exactly this since the paged layer
landed. This module is the index that turns those refcounts into a
prefix cache:

* **Trie keyed by token content.** Each node owns one pool block and is
  keyed by the full ``block_size``-token chunk it caches, so a path from
  the root spells out a prompt prefix at block granularity. Lookup walks
  full-block matches, then radix-matches the longest common prefix
  against the children of the deepest node — a *partial* hit names a
  copy-on-write source block.
* **The cache is itself a lease holder.** Every indexed block carries
  one reference owned by the cache (``pool.ref(b, owner=cache)`` at
  insert), so the pool invariant "refcount 0 iff on the free list"
  survives: a block whose requests have all finished is *parked* — its
  sole remaining reference is the cache's — not freed. Parked blocks
  form an LRU (`free` → park; `lease` → unpark/touch).
* **Deferred reclamation.** ``BlockPool.alloc`` finding the free list
  short asks the attached cache to ``reclaim``; eviction walks the LRU
  oldest-first and drops whole parked subtrees (a parked node may sit
  above *live* descendants inserted by a later request — those paths
  are pinned and skipped). Evicting drops the cache's reference, the
  refcount hits zero, and the block returns to the free list through
  the ordinary ``free`` path, ledger provenance intact.
* **Copy-on-write.** A partial hit leases the divergent source block
  with a temporary reference, the engine clones it device-side into a
  freshly leased private block (``model.clone_paged_block``), and the
  temporary reference is dropped — a genuine shared ``free`` the
  sanitizer's ledger can attribute.

Pricing of the hit path is ``protocol.prefix_hit_latency`` — a lease
handoff (handshake + per-block table surcharge + one block copy per
CoW clone), not a recompute.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.serve.kv_cache import SlotError


@dataclass
class PrefixHit:
    """Result of one trie lookup: the shareable prefix of a prompt.

    ``blocks`` are full-block hits in prefix order; ``cow_src`` (if any)
    is a cached block whose first ``cow_tokens`` tokens match the
    prompt's next chunk — shareable only by cloning. ``n_parked`` counts
    hit blocks currently parked (they leave the pool's free list alone
    but stop being evictable once leased — admission math needs both).
    """
    blocks: List[int] = field(default_factory=list)
    tokens: int = 0
    cow_src: Optional[int] = None
    cow_tokens: int = 0
    n_parked: int = 0

    @property
    def total_tokens(self) -> int:
        return self.tokens + self.cow_tokens


class _Node:
    """One cached block: keyed by its token chunk, linked into the trie."""

    __slots__ = ("key", "block", "parent", "children")

    def __init__(self, key: Optional[Tuple[int, ...]], block: int,
                 parent: Optional["_Node"]):
        self.key = key
        self.block = block
        self.parent = parent
        self.children: Dict[Tuple[int, ...], _Node] = {}


def _lcp(a: Tuple[int, ...], b: Tuple[int, ...]) -> int:
    n = min(len(a), len(b))
    for i in range(n):
        if a[i] != b[i]:
            return i
    return n


class PrefixCache:
    """Block-granular radix index + LRU reclaimer over a ``BlockPool``.

    Attaching (done in ``__init__``) registers the cache as the pool's
    reclaimer: the pool counts parked-and-evictable blocks as free for
    admission and calls back into :meth:`reclaim` when ``alloc`` finds
    the free list short.
    """

    def __init__(self, pool):
        self.pool = pool
        self.block_size = int(pool.block_size)
        self._root = _Node(None, -1, None)
        self._nodes: Dict[int, _Node] = {}        # block id -> node
        self._parked: "OrderedDict[int, None]" = OrderedDict()  # LRU
        # counters (reset_stats() clears; content survives)
        self.n_lookups = 0
        self.n_hits = 0
        self.n_insertions = 0
        self.n_evictions = 0
        pool.attach_reclaimer(self)

    def __repr__(self) -> str:      # the owner name in pool diagnostics
        return "prefix-cache"

    # -- index accounting --------------------------------------------------
    @property
    def num_cached(self) -> int:
        return len(self._nodes)

    @property
    def num_parked(self) -> int:
        return len(self._parked)

    # -- lookup / lease ----------------------------------------------------
    def lookup(self, tokens, limit: Optional[int] = None) -> PrefixHit:
        """Longest cached prefix of ``tokens[:limit]``.

        Full-block trie walk first, then a radix partial match (longest
        common prefix against the deepest node's children) for the CoW
        tail. Callers clamp ``limit`` below the prompt length so at
        least one token always re-prefills (the final chunk's logits
        seed decode).
        """
        toks = [int(t) for t in tokens]
        limit = len(toks) if limit is None else min(int(limit), len(toks))
        bs = self.block_size
        self.n_lookups += 1
        node, blocks, i = self._root, [], 0
        while i + bs <= limit:
            child = node.children.get(tuple(toks[i:i + bs]))
            if child is None:
                break
            blocks.append(child.block)
            node = child
            i += bs
        cow_src, cow_tokens = None, 0
        rem = tuple(toks[i:limit])
        if rem:
            for key, child in node.children.items():
                n = _lcp(key, rem)
                if n > cow_tokens:
                    cow_tokens, cow_src = n, child.block
        parked = sum(1 for b in blocks if b in self._parked)
        if cow_src is not None and cow_src in self._parked:
            parked += 1
        hit = PrefixHit(blocks=blocks, tokens=len(blocks) * bs,
                        cow_src=cow_src, cow_tokens=cow_tokens,
                        n_parked=parked)
        if hit.total_tokens:
            self.n_hits += 1
        return hit

    def lease(self, hit: PrefixHit, owner: object) -> None:
        """Reference every hit block for ``owner`` (the CoW source gets a
        temporary reference — dropped via :meth:`release_cow` once the
        clone lands). Leased blocks are unparked first, so a reclaim
        triggered by the same admission's fresh-block ``alloc`` can
        never evict them."""
        for b in hit.blocks:
            self.pool.ref(b, owner=owner)
            self._parked.pop(b, None)
        if hit.cow_src is not None:
            self.pool.ref(hit.cow_src, owner=owner)
            self._parked.pop(hit.cow_src, None)

    def release_cow(self, block: int) -> None:
        """Drop the temporary CoW-source reference (the clone is on
        device; the request no longer reads the shared block)."""
        self.pool.free([block])

    # -- insert ------------------------------------------------------------
    def insert(self, tokens, blocks) -> int:
        """Index a finished prefill's full prompt blocks. Walks existing
        nodes (a concurrent duplicate keeps the first copy; the loser's
        private block simply stays unindexed) and references each newly
        indexed block on behalf of the cache. Returns blocks added."""
        toks = [int(t) for t in tokens]
        bs = self.block_size
        n_full = min(len(toks) // bs, len(blocks))
        node, added = self._root, 0
        for j in range(n_full):
            key = tuple(toks[j * bs:(j + 1) * bs])
            child = node.children.get(key)
            if child is None:
                b = int(blocks[j])
                if b in self._nodes:      # already indexed elsewhere
                    break
                child = _Node(key, b, node)
                node.children[key] = child
                self._nodes[b] = child
                self.pool.ref(b, owner=self)
                added += 1
                self.n_insertions += 1
            node = child
        return added

    # -- reclaimer protocol (BlockPool callbacks) --------------------------
    def on_sole_ref(self, block: int) -> None:
        """Pool callback: ``block``'s refcount dropped to 1. If the
        survivor is the cache's own reference (iff the block is
        indexed), the block parks at the LRU's fresh end."""
        if block in self._nodes:
            self._parked[block] = None
            self._parked.move_to_end(block)

    def evictable(self) -> int:
        """Parked blocks reclaim() could actually free right now: a
        parked node pinned by a live descendant (a later request's
        private suffix inserted beneath it) is not evictable — dropping
        it would orphan the live path."""
        return sum(1 for b in self._parked
                   if not self._has_live_descendant(self._nodes[b]))

    def reclaim(self, need: int) -> int:
        """Evict parked subtrees, LRU-oldest first, until ``need`` blocks
        returned to the free list (or nothing evictable remains)."""
        freed = 0
        for b in list(self._parked):
            if freed >= need:
                break
            node = self._nodes.get(b)
            if node is None or b not in self._parked:
                continue              # went down with an earlier subtree
            if self._has_live_descendant(node):
                continue
            freed += self._evict_subtree(node)
        return freed

    def _has_live_descendant(self, node: _Node) -> bool:
        for c in node.children.values():
            if c.block not in self._parked or self._has_live_descendant(c):
                return True
        return False

    def _evict_subtree(self, node: _Node) -> int:
        """Drop ``node`` and everything beneath it (all parked — the
        caller proved no live descendant), children first so the trie
        never holds an edge to a freed block."""
        count = 0
        for c in list(node.children.values()):
            count += self._evict_subtree(c)
        if node.parent is not None:
            node.parent.children.pop(node.key, None)
        self._nodes.pop(node.block, None)
        self._parked.pop(node.block, None)
        self.pool.free([node.block])      # cache ref 1 -> 0: free list
        self.n_evictions += 1
        return count + 1

    def on_pool_reset(self) -> None:
        """Pool callback at ``BlockPool.reset``: every lease (including
        the cache's) was wiped underneath us — drop the index without
        re-freeing anything."""
        self._root = _Node(None, -1, None)
        self._nodes.clear()
        self._parked.clear()

    # -- lifecycle ---------------------------------------------------------
    def clear(self) -> None:
        """Release every cached reference and empty the index (the
        engine's cold ``reset``). Blocks still shared with live requests
        survive at their remaining refcount; cache-only blocks return to
        the free list."""
        blocks = list(self._nodes)
        self._root = _Node(None, -1, None)
        self._nodes.clear()
        self._parked.clear()
        for b in blocks:
            self.pool.free([b])

    def reset_stats(self) -> None:
        self.n_lookups = self.n_hits = 0
        self.n_insertions = self.n_evictions = 0

    def stats(self) -> Dict[str, float]:
        return {
            "prefix_cached_blocks": float(self.num_cached),
            "prefix_parked_blocks": float(self.num_parked),
            "prefix_trie_lookups": float(self.n_lookups),
            "prefix_trie_hits": float(self.n_hits),
            "prefix_insertions": float(self.n_insertions),
            "prefix_evictions": float(self.n_evictions),
        }

    def check(self) -> None:
        """Structural invariants (test hook): every indexed block holds a
        cache reference; every parked block is indexed."""
        for b, node in self._nodes.items():
            if self.pool.refcount(b) < 1:
                raise SlotError(f"cached block {b} has no live lease")
            if node.children is None:
                raise SlotError(f"cached block {b} detached")
        for b in self._parked:
            if b not in self._nodes:
                raise SlotError(f"parked block {b} not indexed")
