"""Multi-rank serving fabric over the threadcomm substrate (DESIGN.md
§10): router rank + N engine ranks, replicated or prefill/decode-
disaggregated placement, request-based KV-block migration."""

from repro.serve.fabric.placement import (DisaggregatedPlacement,  # noqa: F401
                                          Placement,
                                          ReplicatedPlacement,
                                          make_placement)
from repro.serve.fabric.router import ServingFabric  # noqa: F401
from repro.serve.fabric.transport import KVBlockTransport  # noqa: F401
from repro.serve.fabric.worker import EngineWorker  # noqa: F401
