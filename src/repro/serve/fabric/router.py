"""The serving fabric's router rank (DESIGN.md §10).

``ServingFabric`` turns one ``ContinuousEngine`` into a multi-rank
serving fabric over the unified ``Comm`` substrate: a **router** that
classifies, prices and dispatches requests, and **N engine ranks**
(:class:`~repro.serve.fabric.worker.EngineWorker`), each a paged
``ContinuousEngine`` bound to its own derived communication context and
``CommStream`` pair. The rank structure is the paper's: engine ranks
are derived from the root threadcomm by ``split`` (one color class per
engine rank when the comm is wide enough) and each rank's context is a
``dup`` — same group, fresh context — so per-rank communication never
serializes against a peer's, which is exactly the MPIX-stream lesson
the fabric exists to demonstrate at serving scale.

The router reuses the serving substrate's own admission machinery for
the **dispatch hop**: new requests land in the router's
``CellQueueScheduler`` (bounded cells, eager/rendezvous classification,
protocol-model pricing — paper §3.2), and are dealt to engine ranks
join-shortest-queue as ranks have room. Placement policy decides who is
eligible (:mod:`~repro.serve.fabric.placement`):

* **replicated** — every rank a full replica, JSQ over all of them;
* **disaggregated** — prefill ranks deposit prompts, then the router's
  migrate hop streams each finished prefill's KV block-by-block to a
  decode rank through :class:`~repro.serve.fabric.transport.
  KVBlockTransport` (request-based sends, ``waitall`` completion,
  ``protocol.kv_migration_latency`` pricing), handing the BlockPool
  lease off rather than recomputing the prefill.
"""

from __future__ import annotations

import time
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.comm import ThreadComm, threadcomm_init
from repro.core.compat import make_mesh
# telemetry (REPRO_TRACE=1, DESIGN.md §15): dispatch/migrate hop spans
# with modeled-vs-measured residuals — one global read + None check off
from repro.obs import flush_trial as _obs_flush_trial
from repro.obs import metrics as obs_metrics
from repro.obs.trace import active as _tr_active
from repro.serve.engine import ContinuousEngine
from repro.serve.kv_cache import LeaseLeakError, LeaseLeakWarning
from repro.serve.fabric.placement import Placement, make_placement
from repro.serve.fabric.transport import KVBlockTransport
from repro.serve.fabric.worker import EngineWorker
from repro.serve.scheduler import (CellQueueScheduler, ServeRequest,
                                   latency_stats_over)


class ServingFabric:
    """Router + N engine ranks over one communication substrate.

    Drive it like an engine: ``submit(req, now)`` then ``step(now)``
    until ``idle`` — the router dispatches, every rank advances one
    micro-step, and (disaggregated) finished prefills migrate. The
    constructor owns a service-mode root threadcomm over the local
    device mesh unless ``comm`` (already started) is passed in; call
    :meth:`close` to finish/free an owned comm.
    """

    def __init__(self, model, params, *, ranks: int = 2,
                 placement="replicated", cache_len: int,
                 slots_per_rank: int = 4, eos_id: int = -1,
                 prefill_chunk: int = 64, max_prefill_per_step: int = 2,
                 block_size: int = 16,
                 blocks_per_rank: Optional[int] = None,
                 n_prefill_ranks: int = 1,
                 dispatch_window: Optional[int] = None,
                 speculate: int = 0,
                 comm: Optional[ThreadComm] = None):
        self.placement: Placement = (placement if isinstance(placement,
                                                             Placement)
                                     else make_placement(placement,
                                                         n_prefill_ranks))
        roles = self.placement.roles(ranks)
        self.ranks = int(ranks)
        # speculative ranks (DESIGN.md §14 on the fabric): every rank of
        # a replicated placement runs draft–verify rounds. Disaggregated
        # placement is refused up front — the drafter's twin pool never
        # sees the prompt KV a migration ships, so a decode rank could
        # not draft (the engine enforces role == "full" too)
        self.speculate = int(speculate)
        if self.speculate and self.placement.needs_migration:
            raise ValueError(
                "speculative decoding is not supported on disaggregated "
                "placements: the drafter's twin pool cannot receive the "
                "migrated prompt KV (use placement='replicated')")

        # capability gate (DESIGN.md §13): disaggregation migrates KV
        # blocks between ranks, which silently strands any per-request
        # carried state (SSM/hybrid recurrent state, enc-dec cross K/V)
        # at the prefill rank — refuse up front, naming the capability
        caps = getattr(model, "capabilities", None)
        if (self.placement.needs_migration and caps is not None
                and not caps.kv_migration):
            raise ValueError(
                "model lacks capability 'kv_migration' — disaggregated "
                "placement migrates KV blocks between ranks, which would "
                "strand per-request carried state at the prefill rank: "
                + caps.reason)

        # -- substrate: root threadcomm + per-rank derived contexts --
        if comm is None:
            mesh = make_mesh((jax.local_device_count(),), ("serve",))
            comm = threadcomm_init(mesh, process_axes=(),
                                   thread_axes=("serve",))
            comm.start()               # service-mode: finish at close()
            self._owns_comm = True
        else:
            self._owns_comm = False
        self.comm = comm
        subs = self._engine_comms(comm, ranks)

        #: JSQ backpressure: a rank above this load receives no new
        #: dispatches; excess requests wait in the router's cell queue
        #: (the bounded-buffer discipline of paper §3.2, one hop up)
        self.dispatch_window = (int(dispatch_window) if dispatch_window
                                else 2 * slots_per_rank)

        self.workers: List[EngineWorker] = []
        for i, role in enumerate(roles):
            eng = ContinuousEngine(
                model, params, cache_len=cache_len,
                num_slots=slots_per_rank, eos_id=eos_id, comm=subs[i],
                prefill_chunk=prefill_chunk,
                max_prefill_per_step=max_prefill_per_step,
                kv_layout="paged", block_size=block_size,
                num_blocks=blocks_per_rank, role=role,
                speculate=self.speculate if role == "full" else 0)
            self.workers.append(EngineWorker(i, role, eng, comm=subs[i]))

        # -- the dispatch hop's admission queue (router rank) --
        # built after the engines so carried-state families price the
        # per-admission state handoff at this hop too (same surcharge
        # the per-rank engine schedulers apply)
        self.scheduler = CellQueueScheduler(
            num_cells=4 * ranks * slots_per_rank,
            prefill_chunk_bytes=4 * prefill_chunk,
            block_bytes=4 * block_size,
            state_bytes=self.workers[0].engine._carried_state_bytes())

        self.transport = (KVBlockTransport(comm)
                          if self.placement.needs_migration else None)
        self.finished: List[ServeRequest] = []
        self.total_steps = 0
        # ranks are THREADS (the paper's thesis): each engine rank owns
        # disjoint state (its own derived comm context, streams, KV
        # pools, scheduler, jits), so their micro-steps are stepped
        # concurrently — XLA releases the GIL during compiled execution,
        # so rank dispatches overlap on a multi-core host instead of
        # serializing in the driver loop (which would forfeit exactly
        # the independence the per-rank contexts buy)
        self._rank_pool = (ThreadPoolExecutor(
            max_workers=self.ranks, thread_name_prefix="fabric-rank")
            if self.ranks > 1 else None)

    @staticmethod
    def _engine_comms(root: ThreadComm, ranks: int) -> List:
        """One derived communication context per engine rank. With a
        root wide enough, ``split`` assigns each engine rank a
        contiguous color class of unified ranks (its own sub-comm
        family); narrower roots (the 1-device CPU driver) fall back to
        ``dup`` — same group, fresh context per rank. Either way every
        rank's streams serialize only against themselves."""
        S = root.size
        if S >= ranks:
            color = [ur * ranks // S for ur in range(S)]
            sub = root.split(color)
            return [sub.dup() for _ in range(ranks)]
        return [root.dup() for _ in range(ranks)]

    # -- intake (the dispatch hop) -----------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Queue a request at the router: classified and priced by the
        cell-queue admission model, dispatched to an engine rank at the
        next :meth:`step`. The full decode budget is validated against
        the serving ranks here — a request no rank could ever lease
        must fail at submit, not blow up mid-step after the dispatch
        hop already popped it (or livelock the migrate hop)."""
        budget = req.prompt_len + req.max_new_tokens
        decode_role = ("decode" if self.placement.needs_migration
                       else "full")
        cap = max((w.engine.admittable_tokens for w in self.workers
                   if w.role == decode_role), default=0)
        if budget > cap:
            raise ValueError(
                f"request {req.rid}: prompt+max_new = {budget} tokens "
                f"exceeds every {decode_role}-rank capacity {cap}; raise "
                "cache_len/blocks_per_rank or lower max_new_tokens")
        return self.scheduler.submit(req, now)

    def _dispatch(self, now: float) -> None:
        """Deal queued requests join-shortest-queue to eligible ranks,
        stopping at the dispatch window (bounded per-rank backlog)."""
        tr = _tr_active()
        while True:
            w = self.placement.select_submit(self.workers)
            if w is None or w.queue_depth >= self.dispatch_window:
                return
            if tr is None:
                admitted = self.scheduler.admit(now, 1)
                if not admitted:
                    return
                w.submit(admitted[0], now)
            else:
                # the router-dispatch hop's wall-clock twin of the §3.2
                # admission price stamped at this hop's scheduler
                t0 = time.perf_counter()
                admitted = self.scheduler.admit(now, 1)
                if not admitted:
                    return
                w.submit(admitted[0], now)
                tr.hop("router_dispatch", admitted[0].admit_cost_s, t0,
                       time.perf_counter(), rid=admitted[0].rid,
                       rank=w.rank)

    # -- the migrate hop (disaggregated only) ------------------------------
    def _migrate(self, now: float) -> None:
        """Move prefill-complete requests whose decode rank can post
        the receive. Head-of-line within each prefill rank, mirroring
        ``CellQueueScheduler.admit`` one hop down: when the oldest held
        handoff fits no decode rank, migration for that rank defers
        entirely — later (smaller) handoffs must not keep taking the
        blocks the stalled one is waiting for, starving it without
        bound while its prompt blocks stay leased at the prefill rank."""
        for w in self.workers:
            if w.role != "prefill":
                continue
            held = []
            pending = w.engine.take_handoffs()
            for i, h in enumerate(pending):
                budget = h.req.prompt_len + h.req.max_new_tokens
                d = self.placement.select_decode(self.workers, budget)
                if d is None:
                    held.extend(pending[i:])   # FIFO: defer the rest too
                    break
                slot = None
                tr = _tr_active()
                t0 = time.perf_counter() if tr is not None else 0.0
                try:
                    slot, dst_blocks = d.engine.begin_import(h.req)
                    state_row = w.engine.handoff_state(h.slot)
                    cost = self.transport.migrate(
                        w.engine.kv, d.engine.kv, h.blocks,
                        dst_blocks[:len(h.blocks)])
                    d.engine.finish_import(slot, h, state_row, now)
                    if tr is not None:
                        # the migrate hop's wall-clock twin: posted
                        # receive + block messages + waitall + install
                        tr.hop("migration", cost, t0,
                               time.perf_counter(), rid=h.req.rid,
                               src=w.rank, dst=d.rank,
                               blocks=len(h.blocks))
                except BaseException:
                    # an error mid-migration must not lose in-flight
                    # requests: undo the posted receive and put this
                    # handoff (and everything after it, FIFO) back on
                    # hold — the source rows/blocks are still leased
                    # and intact (migration only reads them), so the
                    # whole handoff is retryable
                    if slot is not None:
                        d.engine.kv.free(slot)
                    w.engine.ready_handoffs.extend(pending[i:])
                    raise
                w.engine.release_handoff(h.slot)
                h.req.decode_rank = d.rank
                h.req.kv_migration_s = cost
                h.req.kv_blocks_moved = len(h.blocks)
                w.note_migrated_out(h.req)
                d.note_migrated_in(h.req)
            w.engine.ready_handoffs.extend(held)

    # -- micro-step --------------------------------------------------------
    def step(self, now: float = 0.0) -> List[ServeRequest]:
        """One fabric micro-step: dispatch, advance every rank
        (concurrently — rank threads overlap their compiled dispatches),
        migrate. Returns the requests that finished anywhere this step.
        Dispatch and migration stay on the router thread: they read and
        write cross-rank state (JSQ loads, block leases on two pools),
        while a rank's micro-step touches only its own."""
        tr = _tr_active()
        if tr is not None:
            # router-thread runnable hint: queued requests the router
            # could be dispatching — time it then spends blocked inside
            # a migrate waitall is measured serialization (paper §2)
            tr.set_runnable(self.scheduler.num_waiting)
        self._dispatch(now)
        finished: List[ServeRequest] = []
        if self._rank_pool is not None:
            for done in self._rank_pool.map(
                    lambda w: w.step(now), self.workers):
                finished.extend(done)
        else:
            for w in self.workers:
                finished.extend(w.step(now))
        if self.placement.needs_migration:
            self._migrate(now)
        self.finished.extend(finished)
        self.total_steps += 1
        return finished

    @property
    def idle(self) -> bool:
        return (self.scheduler.num_waiting == 0
                and all(w.idle for w in self.workers))

    # -- reporting ---------------------------------------------------------
    def stats(self) -> Dict:
        """Aggregate fabric measurements: router-level latency/TTFT
        percentiles over every finished request, the dispatch hop's
        admission accounting, per-rank utilization rows, and (disagg)
        the KV-migration rows."""
        out = latency_stats_over(self.finished)
        out.update(
            placement=self.placement.name,
            ranks=float(self.ranks),
            fabric_steps=float(self.total_steps),
        )
        # trial-scoped census + admission accounting of the dispatch
        # hop, and the per-rank rows — both assembled by the canonical
        # schema collectors (repro.obs.metrics, DESIGN.md §15)
        out.update(obs_metrics.scheduler_census(self.scheduler))
        out["per_rank"] = [w.utilization() for w in self.workers]
        if self.transport is not None:
            out.update(self.transport.stats())
            mig = [r.kv_migration_s for r in self.finished
                   if r.kv_blocks_moved > 0]
            if mig:
                out["kv_migration_p50_us"] = 1e6 * float(
                    np.percentile(mig, 50))
                out["kv_migration_p95_us"] = 1e6 * float(
                    np.percentile(mig, 95))
        return out

    # -- lifecycle ---------------------------------------------------------
    def reset(self) -> None:
        """Post-warm-up clean slate across the whole fabric: router
        queue + per-request accounting maps, every rank's engine and
        counters, migration accounting. Compiled programs survive."""
        self.scheduler.reset()
        for w in self.workers:
            w.reset()
        if self.transport is not None:
            self.transport.reset()
        self.finished = []
        self.total_steps = 0

    def close(self, *, strict: bool = False) -> None:
        """Finish/free the root threadcomm if this fabric owns it —
        after a fabric-wide lease census. Requests still in flight
        (dispatch log), KV rows still leased on any rank, or handoffs
        still awaiting migration are leaks at close: each is named via
        ``LeaseLeakWarning``, or ``LeaseLeakError`` when ``strict``
        (finish/free still runs, so an owned comm is never stranded)."""
        leaks: List[str] = []
        in_flight = sorted(r.rid for r in self.scheduler.req_log.values()
                           if r.state != "done")
        if in_flight:
            leaks.append(f"{len(in_flight)} request(s) in flight at the "
                         f"router: {', '.join(map(str, in_flight[:8]))}"
                         + (" ..." if len(in_flight) > 8 else ""))
        for w in self.workers:
            live = w.engine.kv.num_live
            if live:
                owners = [w.engine.kv.owner(s)
                          for s in w.engine.kv.live_slots]
                leaks.append(f"rank {w.rank} ({w.role}) holds {live} "
                             f"live KV lease(s): owners {owners!r}")
            if w.engine.ready_handoffs:
                rids = [h.req.rid for h in w.engine.ready_handoffs]
                leaks.append(f"rank {w.rank} ({w.role}) holds "
                             f"{len(rids)} unmigrated handoff(s): "
                             f"{rids!r}")
        try:
            if leaks:
                msg = ("fabric closed with leaked leases: "
                       + "; ".join(leaks))
                if strict:
                    raise LeaseLeakError(msg)
                warnings.warn(msg, LeaseLeakWarning, stacklevel=2)
        finally:
            if self._rank_pool is not None:
                self._rank_pool.shutdown(wait=True)
                self._rank_pool = None
            if self._owns_comm:
                self.comm.finish()
                self.comm.free()
                self._owns_comm = False
            # per-trial counters are trial-scoped, and a closed fabric
            # ends the trial: drop the router's rid-keyed log/admission
            # accounting and the transport's migration counters (rids
            # restart at 0 next trial — the PR 5 req_log aliasing bug
            # class), and flush the global telemetry (residual ledger +
            # push registry) so nothing recorded here aggregates into a
            # later trial in the same process. Worker/engine counters
            # stay readable until their own reset(): close() must not
            # re-run the engines' lease-leak census the try block above
            # already reported.
            self.scheduler.reset()
            if self.transport is not None:
                self.transport.reset()
            self.finished = []
            self.total_steps = 0
            _obs_flush_trial()
