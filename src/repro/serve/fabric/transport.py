"""KV-block migration transport (DESIGN.md §10): request-based,
block-by-block handoff of a finished prefill's KV between two paged
pools.

This is the fabric's p2p hop, run under the paper's rendezvous
discipline end to end:

* the decode rank leases its destination blocks *first*
  (``ContinuousEngine.begin_import`` — the posted receive), so the
  lease is handed off rather than the prefill recomputed;
* the prompt's KV then crosses **one block per message**: each hop is a
  donated scatter of one source block into one destination block,
  serialized on a dedicated ``CommStream`` (``kv-migrate``) and wrapped
  in a :class:`~repro.core.comm.Request` carrying the protocol model's
  request-object overhead for a message of one block — the exact
  ``isend``/``irecv`` pattern, with ``waitall`` as the completion point
  before the decode rank may touch the migrated state;
* the whole migration is priced by
  :func:`repro.core.protocol.kv_migration_latency` (one rendezvous
  handshake + per-block protocol-selected messages) and the modeled
  cost is stamped on the request for the bench artifact's
  ``kv_migration`` rows.

Bounding every message at one block is what keeps the fabric's decode
ranks responsive: a 2048-token prompt never crosses as one multi-MB
payload that would stall the receiving stream, it crosses as 128
independent block messages the stream interleaves like any other
traffic.
"""

from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp

from repro.analysis.sanitizer import active as _san_active
from repro.core import protocol
from repro.core.comm import Request, waitall
from repro.obs.trace import active as _tr_active


class KVBlockTransport:
    """Block-by-block KV migration between two ``PagedKVCache`` pools."""

    def __init__(self, comm, stream_name: str = "kv-migrate"):
        self.comm = comm
        self.stream = comm.stream(stream_name)
        # one compiled program for every hop: scalar src/dst block ids,
        # destination pool donated so XLA aliases it across the chain
        self._copy = jax.jit(self._copy_impl, donate_argnums=(0,))
        # accounting for the bench artifact's kv_migration rows
        self.n_migrations = 0
        self.n_blocks_moved = 0
        self.bytes_moved = 0
        self.modeled_cost_s = 0.0

    @staticmethod
    def _copy_impl(dst, src, src_block, dst_block):
        """One block message: scatter source block ``src_block`` of every
        (L, P, bs, Gs, hd) leaf into destination block ``dst_block``.
        Also returns a 1-element completion probe read back out of the
        written block — the probe, not the pool, is what joins the
        stream and rides the Request (gating the full pool through an
        eager optimization_barrier would copy the whole un-donated pool
        once per block)."""
        new = jax.tree_util.tree_map(
            lambda d, s: jax.lax.dynamic_update_slice_in_dim(
                d, jax.lax.dynamic_slice_in_dim(
                    s, src_block, 1, axis=1).astype(d.dtype),
                dst_block, axis=1),
            dst, src)
        first = jax.tree_util.tree_leaves(new)[0]
        probe = jnp.ravel(jax.lax.dynamic_index_in_dim(
            first, dst_block, axis=1))[:1]
        return new, probe

    @staticmethod
    def block_nbytes(kv) -> int:
        """Bytes one pool block carries across all layers and both of
        k/v — the per-message payload size protocol selection sees."""
        return int(sum(leaf.nbytes // leaf.shape[1]
                       for leaf in jax.tree_util.tree_leaves(kv.buffers)))

    def migrate(self, src_kv, dst_kv, src_blocks: List[int],
                dst_blocks: List[int]) -> float:
        """Stream ``src_blocks`` of ``src_kv`` into ``dst_blocks`` of
        ``dst_kv`` (1:1, table order), one Request per block, and wait
        them all. Returns the modeled migration latency (seconds); the
        measured side effect is ``dst_kv``'s pool holding the prompt KV.
        """
        if len(src_blocks) != len(dst_blocks):
            raise ValueError(
                f"block lists disagree: {len(src_blocks)} source vs "
                f"{len(dst_blocks)} destination")
        if src_kv.block_size != dst_kv.block_size:
            raise ValueError(
                f"pools disagree on block_size: {src_kv.block_size} vs "
                f"{dst_kv.block_size} (1:1 block migration needs equal "
                "token geometry)")
        nb = self.block_nbytes(src_kv)
        proto = protocol.select_protocol(nb, interthread=True)
        requests: List[Request] = []
        dst = dst_kv.buffers
        san = _san_active()
        if san is not None:
            san.on_migrate_begin(self, len(src_blocks))
        tr = _tr_active()
        t_xfer = time.perf_counter() if tr is not None else 0.0
        # the first hop donates the live destination pool, so from here
        # on dst_kv MUST end up pointing at the freshest chain value
        # whatever happens — on an error mid-chain or at completion the
        # old buffers are already gone, and leaving dst_kv on them would
        # crash every later decode step with a deleted-array error that
        # masks the real failure
        try:
            for sb, db in zip(src_blocks, dst_blocks):
                dst, probe = self._copy(dst, src_kv.buffers,
                                        jnp.int32(sb), jnp.int32(db))
                # the probe (read out of the freshly written block,
                # inside the same jit) joins the migrate stream's
                # program order — MPIX-stream serialization of the
                # per-block sends — and rides the Request whose wait()
                # is the block's completion point; the pool itself is
                # serialized by the donation chain and must not be
                # pinned by a request (the next hop deletes it)
                probe = self.stream.ordered(probe)
                requests.append(Request(
                    self.comm, f"kv_block[{proto}]", probe,
                    stream=self.stream,
                    model_overhead_s=protocol.request_overhead(nb, proto)))
        finally:
            # request-leak: completion must sit on the exception path
            # too — an error mid-chain used to abandon every block
            # message already in flight (their Requests died unwaited at
            # the next finish()); the issued prefix is always valid, so
            # complete it before the pool install either way
            try:
                waitall(requests)          # completion before install
                if san is not None and len(requests) == len(src_blocks):
                    san.on_migrate_end(self)
            finally:
                dst_kv.swap_buffers(dst)
        moved = len(src_blocks)
        # the model already charges each block's request object inside
        # its per-block message price — the Request.model_overhead_s
        # fields are the per-message view of the same cost, not an add-on
        cost = protocol.kv_migration_latency(moved * nb, nb)
        if tr is not None:
            # the pure block-transfer span; it nests (by timestamp
            # containment) inside the router's hop:migration event,
            # which also covers the lease import bookkeeping
            tr.complete("kv_transfer", t_xfer, time.perf_counter(),
                        cat="fabric", blocks=moved)
        self.n_migrations += 1
        self.n_blocks_moved += moved
        self.bytes_moved += moved * nb
        self.modeled_cost_s += cost
        return cost

    def stats(self) -> dict:
        """Aggregate migration accounting for the bench artifact."""
        return {
            "n_migrations": float(self.n_migrations),
            "blocks_moved": float(self.n_blocks_moved),
            "bytes_moved": float(self.bytes_moved),
            "kv_migration_modeled_s": self.modeled_cost_s,
            "kv_migration_us_per_block":
                (1e6 * self.modeled_cost_s / self.n_blocks_moved
                 if self.n_blocks_moved else 0.0),
        }

    def reset(self) -> None:
        self.n_migrations = 0
        self.n_blocks_moved = 0
        self.bytes_moved = 0
        self.modeled_cost_s = 0.0
