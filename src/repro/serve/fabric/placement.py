"""Placement policies for the serving fabric (DESIGN.md §10).

A policy answers three questions the router rank asks:

* what **role** each engine rank plays (``roles``) — every rank a full
  prefill+decode replica, or dedicated prefill ranks feeding dedicated
  decode ranks;
* which rank receives a **new request** (``select_submit``) — always
  least-loaded / join-shortest-queue over the eligible ranks, the
  serving analogue of dealing messages to the emptiest cell queue;
* which rank receives a **migrating prefill** (``select_decode``) —
  disaggregated only: least-loaded decode rank *that can lease the
  request's full token budget right now* (the posted-receive gate of
  the rendezvous handoff; with no eligible rank the handoff stays held
  at its prefill rank, blocks still leased, and retries next step).

Load is ``queued + live`` requests on the rank, so join-shortest-queue
self-balances even when a burst arrives in one router step: each
dispatch bumps the target's load before the next candidate is placed.
"""

from __future__ import annotations

from typing import List, Optional, Sequence


class Placement:
    """Policy interface; see module docstring for the contract."""

    name = "?"
    #: True when the policy routes prefill-complete requests through the
    #: KV-block migration transport (the router then runs the migrate
    #: hop each step)
    needs_migration = False

    def roles(self, n_ranks: int) -> List[str]:
        raise NotImplementedError

    def select_submit(self, workers: Sequence) -> Optional[object]:
        """Least-loaded rank eligible for new requests, or None."""
        raise NotImplementedError

    def select_decode(self, workers: Sequence,
                      token_budget: int) -> Optional[object]:
        """Least-loaded decode rank able to lease ``token_budget`` tokens
        now, or None (the handoff waits at its prefill rank)."""
        return None

    @staticmethod
    def _least_loaded(cands) -> Optional[object]:
        cands = list(cands)
        if not cands:
            return None
        return min(cands, key=lambda w: (w.load, w.rank))


class ReplicatedPlacement(Placement):
    """Data parallelism: every rank is a full prefill+decode replica and
    new requests join the shortest queue. The static analogue is
    ``shard_trace`` fan-out; the router's JSQ is the dynamic version
    (it sees actual queue depths, not just arrival indices)."""

    name = "replicated"
    needs_migration = False

    def roles(self, n_ranks: int) -> List[str]:
        if n_ranks < 1:
            raise ValueError("need at least one engine rank")
        return ["full"] * n_ranks

    def select_submit(self, workers):
        return self._least_loaded(workers)


class DisaggregatedPlacement(Placement):
    """Prefill/decode disaggregation: ``n_prefill`` ranks run
    prompt-deposit only (``role="prefill"`` engines, prompt-sized block
    leases) and stream finished KV block-by-block to the decode ranks,
    which never prefill. Separating the phases keeps the long-running
    decode pool free of prefill head-of-line stalls entirely — the
    decode ranks' micro-steps never share a dispatch with chunk work."""

    name = "disagg"
    needs_migration = True

    def __init__(self, n_prefill: int = 1):
        if n_prefill < 1:
            raise ValueError("need at least one prefill rank")
        self.n_prefill = int(n_prefill)

    def roles(self, n_ranks: int) -> List[str]:
        if n_ranks < 2:
            raise ValueError("disaggregation needs >= 2 engine ranks "
                             "(prefill + decode)")
        if self.n_prefill >= n_ranks:
            raise ValueError(
                f"n_prefill={self.n_prefill} leaves no decode rank of "
                f"{n_ranks}")
        return (["prefill"] * self.n_prefill
                + ["decode"] * (n_ranks - self.n_prefill))

    def select_submit(self, workers):
        return self._least_loaded(w for w in workers
                                  if w.role == "prefill")

    def select_decode(self, workers, token_budget: int):
        return self._least_loaded(
            w for w in workers
            if w.role == "decode" and w.engine.kv.can_admit(token_budget))


def make_placement(name: str, n_prefill: int = 1) -> Placement:
    """Policy by CLI name (``--fabric replicated|disagg``)."""
    if name == "replicated":
        return ReplicatedPlacement()
    if name == "disagg":
        return DisaggregatedPlacement(n_prefill)
    raise ValueError(f"unknown placement {name!r} "
                     "(expected 'replicated' or 'disagg')")
