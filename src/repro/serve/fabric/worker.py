"""Engine rank of the serving fabric (DESIGN.md §10): one paged
``ContinuousEngine`` bound to its own derived communication context and
``CommStream`` pair, plus the per-rank accounting the router aggregates
(load for join-shortest-queue, utilization for the bench artifact).

The worker is deliberately thin — the engine already is the serving
loop; the worker is the *rank* wrapper: identity, role, dispatch
counters, and the load metric the placement policies compare. This is
the paper's thread-rank shape: each worker is an independent rank of
the serving threadcomm with its own stream-bound channel, so nothing a
worker does serializes against its peers.
"""

from __future__ import annotations

from typing import List

from repro.serve.engine import ContinuousEngine
from repro.serve.scheduler import ServeRequest


class EngineWorker:
    """One engine rank: a ``ContinuousEngine`` plus rank accounting."""

    def __init__(self, rank: int, role: str, engine: ContinuousEngine,
                 comm=None):
        self.rank = int(rank)
        self.role = role
        self.engine = engine
        self.comm = comm
        # -- per-rank accounting (the router's utilization rows) --
        self.total_steps = 0
        self.busy_steps = 0
        self.n_dispatched = 0      # requests routed here by the router
        self.n_migrated_out = 0    # prefill rank: handoffs shipped
        self.n_migrated_in = 0     # decode rank: handoffs received
        self.n_finished = 0
        self.tokens_out = 0        # generated tokens of requests finished here

    # -- intake ------------------------------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Accept a router dispatch into this rank's engine scheduler."""
        req.rank = self.rank
        self.n_dispatched += 1
        return self.engine.submit(req, now)

    # -- load metric (join-shortest-queue input) ---------------------------
    @property
    def load(self) -> int:
        """Requests this rank is responsible for right now: queued in
        its engine scheduler plus live in its KV pool (held handoffs
        keep their rows leased, so they count as live until migrated —
        exactly the backpressure the prefill JSQ should see)."""
        e = self.engine
        return e.scheduler.num_waiting + e.kv.num_live

    @property
    def idle(self) -> bool:
        return self.engine.idle and not self.engine.ready_handoffs

    # -- micro-step --------------------------------------------------------
    def step(self, now: float = 0.0) -> List[ServeRequest]:
        busy = not self.idle
        finished = self.engine.step(now)
        self.total_steps += 1
        self.busy_steps += int(busy)
        self.n_finished += len(finished)
        self.tokens_out += sum(r.generated for r in finished)
        return finished

    # -- reporting ---------------------------------------------------------
    def utilization(self) -> dict:
        """One per-rank row of the fabric bench artifact."""
        return {
            "rank": self.rank,
            "role": self.role,
            "steps": float(self.total_steps),
            "busy_steps": float(self.busy_steps),
            "utilization": (self.busy_steps / self.total_steps
                            if self.total_steps else 0.0),
            "dispatched": float(self.n_dispatched),
            "migrated_in": float(self.n_migrated_in),
            "migrated_out": float(self.n_migrated_out),
            "finished": float(self.n_finished),
            "tokens": float(self.tokens_out),
        }

    def reset(self) -> None:
        """Post-warm-up clean slate: engine state AND rank accounting
        (a warm trial's busy steps must not pollute the measured
        utilization rows)."""
        self.engine.reset()
        self.total_steps = 0
        self.busy_steps = 0
        self.n_dispatched = 0
        self.n_migrated_out = 0
        self.n_migrated_in = 0
        self.n_finished = 0
        self.tokens_out = 0
