"""Engine rank of the serving fabric (DESIGN.md §10): one paged
``ContinuousEngine`` bound to its own derived communication context and
``CommStream`` pair, plus the per-rank accounting the router aggregates
(load for join-shortest-queue, utilization for the bench artifact).

The worker is deliberately thin — the engine already is the serving
loop; the worker is the *rank* wrapper: identity, role, dispatch
counters, and the load metric the placement policies compare. This is
the paper's thread-rank shape: each worker is an independent rank of
the serving threadcomm with its own stream-bound channel, so nothing a
worker does serializes against its peers.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import protocol
# telemetry (REPRO_TRACE=1, DESIGN.md §15): each rank step runs inside a
# rank scope so every span a pool thread emits lands on the right
# Perfetto lane (threads are re-assigned to ranks arbitrarily per step)
from repro.obs import metrics as obs_metrics
from repro.obs.trace import active as _tr_active
from repro.serve.engine import ContinuousEngine
from repro.serve.scheduler import ServeRequest


class EngineWorker:
    """One engine rank: a ``ContinuousEngine`` plus rank accounting."""

    def __init__(self, rank: int, role: str, engine: ContinuousEngine,
                 comm=None):
        self.rank = int(rank)
        self.role = role
        self.engine = engine
        self.comm = comm
        # -- per-rank accounting (the router's utilization rows) --
        self.total_steps = 0
        self.busy_steps = 0
        self.n_dispatched = 0      # requests routed here by the router
        self.n_migrated_out = 0    # prefill rank: handoffs shipped
        self.n_migrated_in = 0     # decode rank: handoffs received
        self.n_finished = 0
        self.tokens_out = 0        # generated tokens of requests finished here
        # -- predicted-cost load (join-shortest-queue input) --
        # rid -> modeled seconds of work this rank still owes the
        # request; summed into _load_s so `load` is O(1)
        self._cost_s: Dict[int, float] = {}
        self._load_s = 0.0

    # -- intake ------------------------------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Accept a router dispatch into this rank's engine scheduler."""
        req.rank = self.rank
        self.n_dispatched += 1
        out = self.engine.submit(req, now)
        self._track(req, self.predicted_cost_s(req))
        return out

    # -- load metric (join-shortest-queue input) ---------------------------
    def predicted_cost_s(self, req: ServeRequest,
                         decode_only: bool = False) -> float:
        """Modeled seconds of work this request brings to a rank (paper
        §3.2 protocol model): the prompt deposit priced exactly as the
        engine scheduler will price it (chunked/paged when configured),
        plus one interthread token-handoff per decode step. A
        count-based JSQ rates a 16-token and a 256-token prompt the
        same; this is the unit fix — ranks equalize modeled *work*, not
        request count. ``decode_only`` is the migrated-in share: the
        decode rank never re-pays the prompt deposit.

        Decode is priced per *dispatch*, not per token: a speculative
        engine emits ``decode_tokens_per_dispatch`` tokens per round
        (observed acceptance, or its prior before data), so its dispatch
        count for the same ``max_new_tokens`` is proportionally lower —
        the old hardcoded one-token-per-dispatch assumption overpriced
        speculative ranks by exactly that factor and would steer a
        mixed-fleet JSQ away from its fastest ranks."""
        s = self.engine.scheduler
        m = s.host_model
        per_dispatch = self.engine.decode_tokens_per_dispatch
        dispatches = -(-req.max_new_tokens // max(1.0, per_dispatch))
        spec_k = getattr(self.engine, "speculate", 0)
        if spec_k:
            cost = dispatches * protocol.speculative_verify_latency(
                spec_k, s.itemsize, m)
        else:
            cost = dispatches * protocol.interthread_latency(
                s.itemsize, m)
        if not decode_only:
            nbytes = req.prompt_len * s.itemsize
            proto = protocol.select_protocol(nbytes, interthread=True,
                                             cell=s.cell_size)
            cost += s._price(nbytes, proto)
        return cost

    def _track(self, req: ServeRequest, cost: float) -> None:
        self._cost_s[req.rid] = cost
        self._load_s += cost

    def _untrack(self, req: ServeRequest) -> None:
        self._load_s -= self._cost_s.pop(req.rid, 0.0)

    @property
    def load(self) -> float:
        """Predicted seconds of work this rank is responsible for right
        now: the summed protocol-model cost of every request queued,
        prefilling, decoding, or held as an unmigrated handoff here
        (held handoffs keep their rows leased, so their cost stays on
        the prefill rank until migrated — exactly the backpressure the
        prefill JSQ should see)."""
        return self._load_s

    @property
    def queue_depth(self) -> int:
        """Requests this rank is responsible for right now — the
        dispatch-window backpressure gate (a *count* bound on per-rank
        backlog; `load` is the JSQ placement key)."""
        e = self.engine
        return e.scheduler.num_waiting + e.kv.num_live

    # -- migration accounting (disaggregated placement) --------------------
    def note_migrated_out(self, req: ServeRequest) -> None:
        """A handoff shipped from this prefill rank: its remaining work
        (the decode share) now belongs to the decode rank."""
        self.n_migrated_out += 1
        self._untrack(req)

    def note_migrated_in(self, req: ServeRequest) -> None:
        """A handoff landed on this decode rank: it owes the decode
        share only (the prompt deposit already happened upstream)."""
        self.n_migrated_in += 1
        self._track(req, self.predicted_cost_s(req, decode_only=True))

    @property
    def idle(self) -> bool:
        return self.engine.idle and not self.engine.ready_handoffs

    # -- micro-step --------------------------------------------------------
    def step(self, now: float = 0.0) -> List[ServeRequest]:
        busy = not self.idle
        tr = _tr_active()
        if tr is None:
            finished = self.engine.step(now)
        else:
            with tr.rank_scope(self.rank), \
                    tr.span("rank_step", cat="fabric", rank=self.rank,
                            role=self.role, busy=busy):
                finished = self.engine.step(now)
        self.total_steps += 1
        self.busy_steps += int(busy)
        self.n_finished += len(finished)
        self.tokens_out += sum(r.generated for r in finished)
        for r in finished:
            self._untrack(r)
        return finished

    # -- reporting ---------------------------------------------------------
    def utilization(self) -> dict:
        """Thin alias — the canonical per-rank row schema lives in
        :func:`repro.obs.metrics.worker_utilization` (DESIGN.md §15)."""
        return obs_metrics.worker_utilization(self)

    def reset(self) -> None:
        """Post-warm-up clean slate: engine state AND rank accounting
        (a warm trial's busy steps must not pollute the measured
        utilization rows)."""
        self.engine.reset()
        self.total_steps = 0
        self.busy_steps = 0
        self.n_dispatched = 0
        self.n_migrated_out = 0
        self.n_migrated_in = 0
        self.n_finished = 0
        self.tokens_out = 0
        self._cost_s.clear()
        self._load_s = 0.0
