from repro.serve.engine import Engine  # noqa: F401
