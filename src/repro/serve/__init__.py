from repro.serve.block_pool import BlockPool, PagedKVCache  # noqa: F401
from repro.serve.engine import ContinuousEngine, Engine, StaticEngine  # noqa: F401
from repro.serve.kv_cache import SlotError, SlotKVCache  # noqa: F401
from repro.serve.scheduler import (CellQueueScheduler, ServeRequest,  # noqa: F401
                                   TraceEntry, make_trace, shard_trace)
