from repro.serve.block_pool import BlockPool, PagedKVCache  # noqa: F401
from repro.serve.engine import (ContinuousEngine, Engine, KVHandoff,  # noqa: F401
                                StaticEngine)
from repro.serve.fabric import (DisaggregatedPlacement, EngineWorker,  # noqa: F401
                                KVBlockTransport, ReplicatedPlacement,
                                ServingFabric)
from repro.serve.kv_cache import (LeaseLeakError, LeaseLeakWarning,  # noqa: F401
                                  SlotError, SlotKVCache)
from repro.serve.prefix_cache import PrefixCache, PrefixHit  # noqa: F401
from repro.serve.scheduler import (CellQueueScheduler, ServeRequest,  # noqa: F401
                                   TraceEntry, latency_stats_over,
                                   make_trace, shard_trace)
