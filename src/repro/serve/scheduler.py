"""Continuous-batching request scheduler: bounded cell-queue admission
(paper §3.2 recast as serving admission control; DESIGN.md §8).

The paper's interthread message queue is a *bounded pool of fixed-size
cells*: small (eager) messages are buffered into cells immediately —
sender proceeds without waiting for the receiver — while large messages
follow the rendezvous discipline, handing the payload over only once the
receiver has posted. We reuse that structure, and the protocol model's
actual thresholds, as the serving admission queue:

* a request's **prompt is its message** — ``nbytes = prompt tokens ×
  itemsize``, classified by :func:`repro.core.protocol.select_protocol`;
* **eager-class** prompts (≤ the interthread eager threshold) are admitted
  into the bounded cell queue on submit, occupying ``ceil(nbytes/cell)``
  cells — the request is "buffered" and its submitter unblocked;
* **rendezvous-class** prompts (1-copy sized) are never buffered: they
  wait in a deferral queue until a decode slot (the posted receive) is
  free and every buffered request ahead of them has drained;
* eager submissions that find the cell pool full overflow into the same
  deferral discipline (bounded buffer — the queue cannot grow without
  limit), and are promoted back into cells as cells free up.

Admission priority is cells → overflow promotions → rendezvous, FIFO
within each class; the cost model (`interthread_latency`) prices each
admission for the accounting rows the traffic driver reports.

Per-request arrival/admit/first-token/finish times are stamped on the
:class:`ServeRequest` itself, so latency percentiles need no side tables.
"""

from __future__ import annotations

import math
from collections import deque
from dataclasses import dataclass
from typing import Deque, Dict, List, Optional

import numpy as np

from repro.core import protocol
# telemetry (REPRO_TRACE=1, DESIGN.md §15): admit/defer instants and the
# admission counters — one global read + None check when off
from repro.obs.metrics import active as _reg_active
from repro.obs.trace import active as _tr_active

#: scheduler classes mapped from the protocol model
EAGER_CLASS = ("eager_fast", "eager")


@dataclass
class ServeRequest:
    """One generation request plus its lifecycle accounting."""
    rid: int
    batch: Dict[str, np.ndarray]          # model inputs, leading dim 1
    max_new_tokens: int
    temperature: float = 0.0
    seed: int = 0
    arrival: float = 0.0                  # trace arrival time (seconds)

    # -- stamped by the scheduler / engine --
    protocol: str = ""
    nbytes: int = 0
    cells: int = 0
    admit_cost_s: float = 0.0             # protocol-model admission price
    # lifecycle: queued -> prefilling (chunked deposit in progress) ->
    # decoding -> done; monolithic admission skips straight to decoding
    state: str = "queued"
    prefill_chunks: int = 0               # chunk dispatches this rode in
    prefix_hit_tokens: int = 0            # prompt tokens served from the
                                          # radix prefix cache (no prefill)
    # -- stamped by the serving fabric (DESIGN.md §10) --
    rank: int = -1                        # engine rank that served/prefilled
    decode_rank: int = -1                 # disagg: rank that decoded
    kv_migration_s: float = 0.0           # modeled KV-handoff latency
    kv_blocks_moved: int = 0              # blocks migrated for this request
    submit_time: Optional[float] = None
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    output: Optional[np.ndarray] = None   # (max_new_tokens,) int32
    generated: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.batch["tokens"].shape[1])

    @property
    def latency(self) -> float:
        if self.finish_time is None:
            raise ValueError(f"request {self.rid} not finished")
        return self.finish_time - self.arrival

    @property
    def queue_delay(self) -> float:
        if self.admit_time is None:
            raise ValueError(f"request {self.rid} not admitted")
        return self.admit_time - (self.submit_time
                                  if self.submit_time is not None
                                  else self.arrival)

    @property
    def ttft(self) -> float:
        """Time to first token, from trace arrival."""
        if self.first_token_time is None:
            raise ValueError(f"request {self.rid} has no first token yet")
        return self.first_token_time - self.arrival


class CellQueueScheduler:
    """Bounded cell-pool admission queue with rendezvous deferral."""

    def __init__(self, num_cells: int = 16,
                 cell_size: int = protocol.DEFAULT_CELL_SIZE,
                 itemsize: int = 4, prefill_chunk_bytes: int = 0,
                 block_bytes: int = 0, state_bytes: int = 0):
        if num_cells < 1:
            raise ValueError("need at least one cell")
        self.num_cells = int(num_cells)
        self.cell_size = int(cell_size)
        self.itemsize = int(itemsize)
        # the SAME HostModel (same cell) classifies and prices — a
        # non-default cell must not be classified against one cell size
        # but priced against the default one
        self.host_model = protocol.HostModel(cell=int(cell_size))
        # >0: rendezvous-class prompts stream chunk-by-chunk into their
        # slot (chunked prefill) and are priced as chunked handoffs
        self.prefill_chunk_bytes = int(prefill_chunk_bytes)
        # >0: the deposit target is a paged pool — chunked prompts pay the
        # per-block table surcharge on top of the chunked handoff
        self.block_bytes = int(block_bytes)
        # >0: the model carries per-request non-KV state (SSM/hybrid
        # recurrent state, enc-dec cross K/V — capabilities.carried_state)
        # of this many bytes per slot; each admission pays one extra
        # interthread handoff for installing/zeroing it. Priced once per
        # admission in _classify, NOT in _price, so reprice_prefix's
        # miss-suffix repricing can never double-count it.
        self.state_bytes = int(state_bytes)
        self._state_cost_s = (
            protocol.interthread_latency(self.state_bytes, self.host_model)
            if self.state_bytes > 0 else 0.0)
        self.cells_free = int(num_cells)
        self._cellq: Deque[ServeRequest] = deque()      # buffered (eager)
        self._overflow: Deque[ServeRequest] = deque()   # eager, pool full
        self._rendezvous: Deque[ServeRequest] = deque() # 1-copy sized
        self.finished: List[ServeRequest] = []
        # per-request accounting map, keyed by rid: every request
        # submitted this trial (arrival and all lifecycle stamps ride
        # on the record itself). The fabric router reads it from its
        # dispatch-hop scheduler for trial-scoped bookkeeping
        # (in-flight census, arrival span — ServingFabric.stats()); it
        # lives exactly one trial, like `finished`. rids restart at 0
        # every trial, so reset() MUST clear it — a leftover warm-up
        # entry would alias the real request with the same rid and leak
        # its arrival/accounting into the next trial's stats.
        self.req_log: Dict[int, ServeRequest] = {}
        # counters for the driver's accounting rows
        self.n_submitted = 0
        self.n_eager_admits = 0       # buffered straight into cells
        self.n_deferred = 0           # overflow + rendezvous submissions
        self.n_block_deferrals = 0    # admissions stalled on free blocks
        self.modeled_admit_cost_s = 0.0
        # prefix-cache repricing (DESIGN.md §12): hits replace the full
        # admission price with the cheap table-lease walk
        self.n_prefix_hits = 0
        self.prefix_tokens_saved = 0
        self.modeled_prefix_hit_cost_s = 0.0
        # speculative decoding accounting (DESIGN.md §14): one "dispatch"
        # per live row per verify round; accepted counts the tokens each
        # dispatch emitted (drafted prefix + the target's own token)
        self.n_spec_dispatches = 0
        self.spec_accepted_tokens = 0
        self.spec_drafted_tokens = 0
        self.spec_matched_tokens = 0
        self.spec_modeled_cost_s = 0.0

    def reset(self) -> None:
        """Drop all queued/finished requests and zero the accounting —
        the post-warm-up clean slate (queue *configuration* is kept)."""
        self.cells_free = self.num_cells
        self._cellq.clear()
        self._overflow.clear()
        self._rendezvous.clear()
        self.finished = []
        self.req_log.clear()    # rid-keyed: would alias the next
                                # trial's requests (rids restart at 0)
        self.n_submitted = 0
        self.n_eager_admits = 0
        self.n_deferred = 0
        self.n_block_deferrals = 0
        self.modeled_admit_cost_s = 0.0
        self.n_prefix_hits = 0
        self.prefix_tokens_saved = 0
        self.modeled_prefix_hit_cost_s = 0.0
        self.n_spec_dispatches = 0
        self.spec_accepted_tokens = 0
        self.spec_drafted_tokens = 0
        self.spec_matched_tokens = 0
        self.spec_modeled_cost_s = 0.0

    # -- classification ----------------------------------------------------
    def _price(self, nbytes: int, proto: str) -> float:
        """Protocol-model admission price, matching what the engine
        actually does with the prompt: in chunked-prefill mode every
        prompt larger than one chunk streams into its slot incrementally
        and pays the chunked handoff (handshake + per-chunk envelopes) —
        eager-class or not; prompts that fit a single chunk deposit whole
        and keep their eager/1-copy price."""
        if 0 < self.prefill_chunk_bytes < nbytes:
            if self.block_bytes > 0:
                return protocol.paged_admission_latency(
                    nbytes, self.prefill_chunk_bytes, self.block_bytes,
                    self.host_model)
            return protocol.chunked_handoff_latency(
                nbytes, self.prefill_chunk_bytes, self.host_model)
        return protocol.interthread_latency(nbytes, self.host_model,
                                            proto=proto)

    def _classify(self, req: ServeRequest, now: float) -> str:
        req.submit_time = now
        req.nbytes = int(req.batch["tokens"].size) * self.itemsize
        req.protocol = protocol.select_protocol(
            req.nbytes, interthread=True, cell=self.cell_size)
        req.admit_cost_s = self._price(req.nbytes, req.protocol)
        # carried-state handoff surcharge: one per admission, flat in the
        # prompt length (the state pytree has fixed per-slot shape)
        req.admit_cost_s += self._state_cost_s
        req.cells = (max(1, math.ceil(req.nbytes / self.cell_size))
                     if req.protocol in EAGER_CLASS else 0)
        self.modeled_admit_cost_s += req.admit_cost_s
        return req.protocol

    def reprice_prefix(self, req: ServeRequest, hit_tokens: int,
                       cow_blocks: int = 0) -> float:
        """Re-price an admission whose prompt prefix was served from the
        radix cache: the hit tokens never stream through the queue — they
        cost a trie walk plus per-block table-lease envelopes (and a
        one-block copy per CoW clone), modeled by
        :func:`repro.core.protocol.prefix_hit_latency`. Only the miss
        suffix still pays the ordinary chunked/paged deposit price.

        Called by the engine at admission (it is the one that knows the
        hit length); replaces ``req.admit_cost_s`` and patches
        ``modeled_admit_cost_s`` (the full price was already accumulated
        by ``_classify`` at submit). Returns the new price."""
        hit_bytes = int(hit_tokens) * self.itemsize
        miss_bytes = max(0, req.nbytes - hit_bytes)
        bb = self.block_bytes if self.block_bytes > 0 else self.cell_size
        new_cost = protocol.prefix_hit_latency(
            hit_bytes, bb, self.host_model, cow_blocks=cow_blocks)
        if miss_bytes > 0:
            new_cost += self._price(miss_bytes, req.protocol)
        # carried state is installed regardless of how much prompt the
        # prefix cache served (unreachable today — carried-state families
        # have prefix_cache=False — but the invariant is cheap to keep)
        new_cost += self._state_cost_s
        self.modeled_admit_cost_s += new_cost - req.admit_cost_s
        self.modeled_prefix_hit_cost_s += new_cost
        self.n_prefix_hits += 1
        self.prefix_tokens_saved += int(hit_tokens)
        req.admit_cost_s = new_cost
        req.prefix_hit_tokens = int(hit_tokens)
        return new_cost

    # -- submission --------------------------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Queue a request; returns the queue it landed in
        (``"cells" | "overflow" | "rendezvous"``)."""
        proto = self._classify(req, now)
        self.n_submitted += 1
        self.req_log[req.rid] = req
        req.state = "queued"
        if proto in EAGER_CLASS and req.cells <= self.num_cells:
            if req.cells <= self.cells_free:
                self.cells_free -= req.cells
                self._cellq.append(req)
                self.n_eager_admits += 1
                return "cells"
            self._overflow.append(req)
            self.n_deferred += 1
            return "overflow"
        if proto in EAGER_CLASS:
            # eager prompts that could NEVER fit the cell pool even when
            # empty re-route to the rendezvous discipline (they must not
            # wait in overflow for a promotion that cannot happen) — and
            # their accounting must say so: reclassify protocol + price
            # instead of reporting an eager-priced row that rendezvoused
            self.modeled_admit_cost_s -= req.admit_cost_s
            req.protocol = "one_copy"
            req.admit_cost_s = (self._price(req.nbytes, "one_copy")
                                + self._state_cost_s)
            self.modeled_admit_cost_s += req.admit_cost_s
        req.cells = 0
        self._rendezvous.append(req)
        self.n_deferred += 1
        return "rendezvous"

    def _promote(self) -> None:
        """Refill freed cells from the overflow queue (FIFO)."""
        while self._overflow and self._overflow[0].cells <= self.cells_free:
            req = self._overflow.popleft()
            self.cells_free -= req.cells
            self._cellq.append(req)

    # -- admission ---------------------------------------------------------
    def admit(self, now: float, free_slots: int,
              can_admit=None) -> List[ServeRequest]:
        """Hand over up to ``free_slots`` requests for prefill, priority
        cells → promoted overflow → rendezvous.

        ``can_admit(req)`` is the engine's second admission gate — with a
        paged KV pool it checks free *blocks* for the request's tokens.
        Admission is head-of-line within the priority order: when the
        next request doesn't fit the pool, admission defers entirely
        (FIFO is preserved; small latecomers must not starve a large
        prompt that is already at the head)."""
        out: List[ServeRequest] = []
        tr = _tr_active()
        while free_slots > 0:
            if self._cellq:
                queue = self._cellq
            elif self._rendezvous:
                queue = self._rendezvous
            else:
                break
            req = queue[0]
            if can_admit is not None and not can_admit(req):
                self.n_block_deferrals += 1
                if tr is not None:
                    tr.instant("defer", cat="sched", rid=req.rid,
                               reason="blocks")
                break
            queue.popleft()
            if queue is self._cellq:
                self.cells_free += req.cells
                self._promote()
            req.admit_time = now
            out.append(req)
            if tr is not None:
                tr.instant("admit", cat="sched", rid=req.rid,
                           protocol=req.protocol)
            free_slots -= 1
        reg = _reg_active()
        if reg is not None:
            if out:
                reg.counter("sched.admitted").inc(len(out))
            reg.gauge("sched.queue_depth").set(self.num_waiting)
        return out

    def record_spec_dispatch(self, accepted: int, drafted: int,
                             matched: int, cost_s: float) -> None:
        """Account one row's draft–verify round (DESIGN.md §14):
        ``accepted`` tokens emitted by the fused verify dispatch (matched
        draft prefix + the target's own next token), ``drafted`` tokens
        the drafter proposed, ``matched`` of them accepted, and the
        round's §3.2 protocol price
        (:func:`repro.core.protocol.speculative_verify_latency`)."""
        self.n_spec_dispatches += 1
        self.spec_accepted_tokens += int(accepted)
        self.spec_drafted_tokens += int(drafted)
        self.spec_matched_tokens += int(matched)
        self.spec_modeled_cost_s += float(cost_s)

    def spec_stats(self) -> Dict[str, float]:
        """Speculative accounting rows; zeros when speculation is off."""
        d = max(1, self.n_spec_dispatches)
        return {
            "spec_dispatches": float(self.n_spec_dispatches),
            "spec_accepted_tokens": float(self.spec_accepted_tokens),
            "spec_drafted_tokens": float(self.spec_drafted_tokens),
            "accepted_per_dispatch": self.spec_accepted_tokens / d,
            "acceptance_rate": (
                self.spec_matched_tokens / self.spec_drafted_tokens
                if self.spec_drafted_tokens else 0.0),
            "spec_modeled_cost_us": 1e6 * self.spec_modeled_cost_s,
        }

    # -- completion / stats ------------------------------------------------
    def record_finish(self, req: ServeRequest, now: float) -> None:
        req.finish_time = now
        req.state = "done"
        self.finished.append(req)
        reg = _reg_active()
        if reg is not None:
            reg.counter("tokens_out").inc(req.generated)
            reg.histogram("latency_s").observe(req.latency)
            if req.first_token_time is not None:
                reg.histogram("ttft_s").observe(req.ttft)

    @property
    def num_waiting(self) -> int:
        return len(self._cellq) + len(self._overflow) + len(self._rendezvous)

    def queue_depths(self) -> Dict[str, int]:
        return {"cells": len(self._cellq), "overflow": len(self._overflow),
                "rendezvous": len(self._rendezvous),
                "cells_free": self.cells_free}

    def latency_stats(self) -> Dict[str, float]:
        """Percentiles over finished requests (seconds)."""
        return latency_stats_over(self.finished)


def latency_stats_over(finished: List[ServeRequest]) -> Dict[str, float]:
    """Latency/TTFT percentiles over any finished-request collection —
    one scheduler's ``finished`` list, or the union a fabric router
    gathers across its engine ranks (every rank stamps the same
    per-request fields, so aggregation is just a bigger list)."""
    if not finished:
        return {}
    lat = np.array([r.latency for r in finished])
    qd = np.array([r.queue_delay for r in finished])
    toks = int(sum(r.generated for r in finished))
    out = {
        "n": float(len(lat)),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p95_s": float(np.percentile(lat, 95)),
        "latency_mean_s": float(lat.mean()),
        "queue_delay_p50_s": float(np.percentile(qd, 50)),
        "queue_delay_p95_s": float(np.percentile(qd, 95)),
        "tokens": float(toks),
    }
    ttft = np.array([r.ttft for r in finished
                     if r.first_token_time is not None])
    if ttft.size:
        out["ttft_p50_s"] = float(np.percentile(ttft, 50))
        out["ttft_p95_s"] = float(np.percentile(ttft, 95))
        out["ttft_mean_s"] = float(ttft.mean())
    return out


# ---------------------------------------------------------------------------
# Traffic traces + replica fan-out
# ---------------------------------------------------------------------------

@dataclass
class TraceEntry:
    arrival: float
    max_new: int
    temperature: float = 0.0
    prompt_len: int = 0
    # shared-prefix workloads: requests in the same group open with the
    # same ``prefix_len`` template tokens (few-shot preamble / system
    # prompt); -1 = independent prompt
    prefix_group: int = -1
    prefix_len: int = 0


def make_trace(n_requests: int, *, prompt_len, max_new,
               arrival: str = "poisson", rate: float = 100.0,
               burst: int = 4, temperature: float = 0.0,
               shared_prefix_len: int = 0, share_ratio: float = 1.0,
               prefix_groups: int = 1,
               seed: int = 0) -> List[TraceEntry]:
    """Arrival trace: ``arrival`` is ``"poisson"`` (exponential gaps at
    ``rate`` req/s), ``"burst"`` (groups of ``burst`` at 1/rate spacing)
    or ``"all"`` (everything at t=0). ``max_new`` is an int or an
    inclusive ``(lo, hi)`` range sampled per request. ``prompt_len`` is an
    int or a sequence cycled across requests — e.g. ``(16, 256)`` yields
    the short/long interleave that exposes prefill head-of-line
    blocking.

    ``shared_prefix_len > 0`` turns on the shared-prefix workload shape
    (system prompt / few-shot template): each request joins one of
    ``prefix_groups`` template families with probability ``share_ratio``
    and opens with that family's first ``min(shared_prefix_len,
    prompt_len)`` tokens; the suffix stays per-request random. The
    prompt *tokens* are materialized downstream
    (``launch.serve.requests_from_trace``) — the trace only records the
    group and overlap length."""
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        gaps = rng.exponential(1.0 / rate, size=n_requests)
        times = np.cumsum(gaps) - gaps[0]
    elif arrival == "burst":
        times = np.array([(i // burst) * (1.0 / rate)
                          for i in range(n_requests)])
    elif arrival == "all":
        times = np.zeros(n_requests)
    else:
        raise ValueError(f"unknown arrival kind {arrival!r}")
    if isinstance(max_new, int):
        news = np.full(n_requests, max_new)
    else:
        lo, hi = max_new
        news = rng.integers(lo, hi + 1, size=n_requests)
    plens = ([int(prompt_len)] if isinstance(prompt_len, (int, np.integer))
             else [int(p) for p in prompt_len])
    out = [TraceEntry(arrival=float(times[i]), max_new=int(news[i]),
                      temperature=temperature,
                      prompt_len=plens[i % len(plens)])
           for i in range(n_requests)]
    if shared_prefix_len > 0:
        if not 0.0 <= share_ratio <= 1.0:
            raise ValueError(f"share_ratio {share_ratio} not in [0, 1]")
        if prefix_groups < 1:
            raise ValueError("need at least one prefix group")
        for e in out:
            # a 1-token "shared prefix" is pointless (the engine always
            # re-prefills the final prompt token to seed decode)
            if e.prompt_len > 1 and rng.random() < share_ratio:
                e.prefix_group = int(rng.integers(prefix_groups))
                e.prefix_len = min(int(shared_prefix_len), e.prompt_len)
    return out


def shard_trace(trace: List[TraceEntry], replica: int,
                n_replicas: int, seed: Optional[int] = None
                ) -> List[TraceEntry]:
    """Data-parallel fan-out: the slice of the trace replica ``replica``
    of ``n_replicas`` serves (each replica is a ``Comm.split`` family of
    the serving threadcomm — DESIGN.md §8).

    ``seed=None`` is the deterministic round-robin deal (entry ``i`` to
    replica ``i % n_replicas``). With a seed, entries are dealt through a
    seeded permutation instead — still an exact partition (every replica
    computes the same permutation from the same seed, so the shards stay
    disjoint and exhaustive with no coordination), but decorrelated from
    any periodic structure in the trace (e.g. the 16/256 prompt-length
    interleave, which round-robin would hand entirely to one replica
    when ``n_replicas`` divides the cycle length). Arrival order within a
    shard is preserved."""
    if not 0 <= replica < n_replicas:
        raise ValueError(f"replica {replica} out of range({n_replicas})")
    if seed is None:
        return [e for i, e in enumerate(trace) if i % n_replicas == replica]
    perm = np.random.default_rng(seed).permutation(len(trace))
    mine = sorted(int(perm[j]) for j in range(replica, len(trace),
                                              n_replicas))
    return [trace[i] for i in mine]
