"""Paged KV substrate: a global pool of fixed-size KV blocks leased
through per-request block tables (DESIGN.md §9).

This generalizes the paper's cell pool one step further than
``SlotKVCache``: there, one cell = one whole request (a slot reserves
``cache_len`` tokens of HBM whether the request is 16 tokens or 2048);
here, one cell = one KV *block* of ``block_size`` tokens, and a request
leases exactly the blocks its tokens occupy — the MPIX-stream
progression from coarse process-level to fine stream-level resources
applied to serving memory. Pool capacity is then measured in bytes, not
request count: a 16-token request holds 1–2 blocks while a 2048-token
request holds 128, and admission gates on *free blocks* instead of free
slots.

Two layers:

* :class:`BlockPool` — the host-side allocator: O(1) free-list
  alloc/free, per-block reference counts (a block can back several
  requests sharing a prefix — the refcount is the mechanism; prefix
  sharing itself is a later consumer), owners recorded for error
  reporting. Misuse raises :class:`~repro.serve.kv_cache.SlotError`
  naming the owner, exactly like the slot pool.
* :class:`PagedKVCache` — the engine-facing cache: the device-side block
  pool pytree (``model.init_paged_cache``), a fixed set of *request
  rows* (the decode batch width), and one block table per row. Mirrors
  the ``SlotKVCache`` surface (alloc/free/advance/lengths/buffers/
  swap_buffers/reset) so the continuous engine can drive either layout.

Host-side length/refcount bookkeeping is uniformly ``np.int32`` — the
same dtype as device positions, so host→device table/length transfers
never silently widen (the slot pool's ``np.int64`` lengths were the odd
one out; both pools now agree).
"""

from __future__ import annotations

import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.sanitizer import active as _san_active
from repro.obs.metrics import active as _reg_active
from repro.obs.trace import active as _tr_active
from repro.serve.kv_cache import LeaseLeakError, LeaseLeakWarning, SlotError


class BlockPool:
    """O(1) free-list allocator over a fixed population of KV blocks."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise SlotError("need at least one block")
        if block_size < 1:
            raise SlotError("block_size must be >= 1")
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self._free: List[int] = list(range(num_blocks - 1, -1, -1))
        self._ref = np.zeros((num_blocks,), np.int32)
        self._owner: List[Optional[object]] = [None] * num_blocks
        self._last_owner: List[Optional[object]] = [None] * num_blocks
        self._reclaimer = None        # e.g. a PrefixCache (DESIGN.md §12)

    def attach_reclaimer(self, reclaimer) -> None:
        """Register a deferred reclaimer (the prefix cache): blocks it
        parks count as free for admission (``num_free``), ``alloc``
        asks it to ``reclaim`` when the free list runs short, and
        ``free`` notifies it when a block's sole surviving reference
        could be its own (``on_sole_ref``)."""
        if self._reclaimer is not None and self._reclaimer is not reclaimer:
            raise SlotError("pool already has a reclaimer attached")
        self._reclaimer = reclaimer

    @property
    def num_free(self) -> int:
        free = len(self._free)
        if self._reclaimer is not None:
            free += self._reclaimer.evictable()
        return free

    @property
    def num_live(self) -> int:
        return self.num_blocks - len(self._free)

    def refcount(self, block: int) -> int:
        return int(self._ref[block])

    def owner(self, block: int):
        return self._owner[block]

    def blocks_needed(self, ntokens: int) -> int:
        """Table entries a request of ``ntokens`` tokens occupies."""
        if ntokens < 0:
            raise SlotError(f"negative token count {ntokens}")
        return -(-int(ntokens) // self.block_size)

    def alloc(self, n: int, owner: object) -> List[int]:
        """Lease ``n`` blocks for ``owner`` (refcount 1 each). Raises on
        exhaustion — admission control must gate on ``num_free``."""
        if owner is None:
            raise SlotError("block owner must be non-None")
        if n > len(self._free) and self._reclaimer is not None:
            # deferred reclamation: evict parked prefix-cache blocks
            # (LRU order) until the free list covers the request
            self._reclaimer.reclaim(n - len(self._free))
        if n > len(self._free):
            raise SlotError(
                f"block pool exhausted: need {n}, have {len(self._free)} "
                "(admission must gate on num_free)")
        blocks = [self._free.pop() for _ in range(n)]
        for b in blocks:
            self._ref[b] = 1
            self._owner[b] = owner
            self._last_owner[b] = owner
        san = _san_active()
        if san is not None:       # lease ledger records the alloc site
            san.on_lease_alloc(self, blocks, owner)
        self._observe_occupancy()
        return blocks

    def ref(self, block: int, owner: object = None) -> None:
        """Add a reference to a live block (shared-prefix lease);
        ``owner`` feeds the ledger's shared-ref provenance."""
        if self._ref[block] < 1:
            raise SlotError(f"ref of free block {block}")
        self._ref[block] += 1
        san = _san_active()
        if san is not None:
            san.on_lease_ref(self, block, owner)

    def free(self, blocks) -> None:
        """Drop one reference per block; blocks reaching zero return to
        the free list. Double-free names the last owner."""
        san = _san_active()
        for b in blocks:
            if self._ref[b] < 1:
                msg = (f"double free of block {b} "
                       f"(last owner {self._last_owner[b]!r})")
                if san is not None:
                    # the ledger remembers where the block was first
                    # allocated and first freed — the half of the story
                    # the refcount alone can't tell
                    msg += "; " + san.on_double_free(
                        self, b, self._last_owner[b])
                raise SlotError(msg)
            self._ref[b] -= 1
            if self._ref[b] == 0:
                self._owner[b] = None
                self._free.append(b)
            elif self._ref[b] == 1 and self._reclaimer is not None:
                # the survivor may be the reclaimer's own reference —
                # it parks the block (LRU) if so, ignores otherwise
                self._reclaimer.on_sole_ref(b)
            if san is not None:
                san.on_lease_release(self, b)
        self._observe_occupancy()

    def _observe_occupancy(self) -> None:
        """Telemetry (DESIGN.md §15): block-pool occupancy as a Perfetto
        counter track + a registry gauge, sampled at lease transitions
        (per request admission/finish, not per token — alloc/free are
        the only places occupancy moves)."""
        tr = _tr_active()
        if tr is not None:
            free = len(self._free)
            tr.counter("block_pool", free=free,
                       live=self.num_blocks - free)
        reg = _reg_active()
        if reg is not None:
            reg.gauge("block_pool.free_blocks").set(len(self._free))
            reg.gauge("block_pool.live_blocks").set(
                self.num_blocks - len(self._free))

    def reset(self, *, strict: bool = False) -> None:
        """Wipe every lease. Blocks still live are leaks — requests that
        never reached ``free`` — and are named: warn
        (:class:`~repro.serve.kv_cache.LeaseLeakWarning`) by default,
        raise (:class:`~repro.serve.kv_cache.LeaseLeakError`) under
        ``strict=True``."""
        leaked = [(b, self._owner[b]) for b in range(self.num_blocks)
                  if self._ref[b] > 0]
        san = _san_active()
        if san is not None:       # ledger adds allocation provenance
            san.on_pool_reset(self)
        if leaked:
            msg = (f"reset with {len(leaked)} live block lease(s): "
                   + ", ".join(f"block {b} (owner {o!r})"
                               for b, o in leaked[:8])
                   + (f", ... {len(leaked) - 8} more" if len(leaked) > 8
                      else ""))
            if strict:
                raise LeaseLeakError(msg)
            warnings.warn(msg, LeaseLeakWarning, stacklevel=2)
        self._free = list(range(self.num_blocks - 1, -1, -1))
        self._ref[:] = 0
        self._owner = [None] * self.num_blocks
        if self._reclaimer is not None:
            # every lease (the reclaimer's included) was just wiped; the
            # reclaimer drops its index without re-freeing anything
            self._reclaimer.on_pool_reset()


class PagedKVCache:
    """Paged decode-state cache: fixed request rows + leased KV blocks.

    ``num_slots`` is the decode batch width (request rows) — cheap host
    state only; the expensive resource is the block pool, sized
    independently by ``num_blocks``. A request's admission cost is
    ``blocks_for(prompt + max_new)`` blocks (reserved up front, so a
    live request can never hit mid-decode exhaustion) plus one row.
    """

    def __init__(self, model, *, num_blocks: int, block_size: int,
                 num_slots: int, max_blocks_per_req: int):
        if num_slots < 1:
            raise SlotError("need at least one request row")
        if max_blocks_per_req < 1:
            raise SlotError("max_blocks_per_req must be >= 1")
        self.model = model
        self.num_slots = int(num_slots)
        self.block_size = int(block_size)
        self.max_blocks_per_req = int(max_blocks_per_req)
        self.pool = BlockPool(num_blocks, block_size)
        # num_rows sizes the row-aligned carried-state leaves (SSM
        # conv/ssm, enc-dec cross K/V) that ride in the same pytree as
        # the block-addressed k/v pool (DESIGN.md §13)
        self._buf = model.init_paged_cache(num_blocks, block_size,
                                           num_rows=num_slots)
        self._tables = np.full((num_slots, max_blocks_per_req), -1, np.int32)
        self._tables_dev = None       # host->device copy, built on demand
        self._free_rows: List[int] = list(range(num_slots - 1, -1, -1))
        self._owner: List[Optional[object]] = [None] * num_slots
        self._last_owner: List[Optional[object]] = [None] * num_slots
        self._nblocks = np.zeros((num_slots,), np.int32)
        # tokens resident per row — np.int32, same dtype as device positions
        self._len = np.zeros((num_slots,), np.int32)

    # -- pool / row accounting ---------------------------------------------
    @property
    def num_free(self) -> int:
        """Free request rows (the admission gate shared with the slot
        layout; block availability is the second, paged-only gate)."""
        return len(self._free_rows)

    @property
    def num_live(self) -> int:
        return self.num_slots - len(self._free_rows)

    @property
    def num_free_blocks(self) -> int:
        return self.pool.num_free

    @property
    def live_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if self._owner[s] is not None]

    def owner(self, slot: int):
        return self._owner[slot]

    def length(self, slot: int) -> int:
        return int(self._len[slot])

    @property
    def lengths(self) -> np.ndarray:
        return self._len.copy()

    def blocks_for(self, ntokens: int) -> int:
        return self.pool.blocks_needed(ntokens)

    def blocks_of(self, slot: int) -> List[int]:
        """The block ids leased to ``slot``, in table order (the
        migration transport copies these 1:1 into the destination
        lease)."""
        if self._owner[slot] is None:
            raise SlotError(f"blocks_of free row {slot}")
        return self._tables[slot, :int(self._nblocks[slot])].tolist()

    def can_admit(self, ntokens: int, hit=None) -> bool:
        """One free row + enough free blocks for ``ntokens`` tokens.

        With a :class:`~repro.serve.prefix_cache.PrefixHit`, only the
        *miss* tail needs fresh blocks — but the hit's parked blocks,
        while costing nothing from the free list, stop being evictable
        the moment they are leased, so they are subtracted from the
        pool's (free + evictable) headroom."""
        nb = self.blocks_for(ntokens)
        if nb > self.max_blocks_per_req:
            raise SlotError(
                f"request of {ntokens} tokens needs {nb} blocks > "
                f"max_blocks_per_req={self.max_blocks_per_req}")
        if not self._free_rows:
            return False
        if hit is None:
            return nb <= self.pool.num_free
        fresh = nb - len(hit.blocks)
        return fresh <= self.pool.num_free - hit.n_parked

    # -- lease lifecycle ---------------------------------------------------
    def alloc(self, owner: object, ntokens: int) -> int:
        """Claim a request row and lease the blocks ``ntokens`` tokens
        will occupy. Raises on row/block exhaustion."""
        if owner is None:
            raise SlotError("row owner must be non-None")
        if not self._free_rows:
            raise SlotError("request rows exhausted (admission must gate "
                            "on num_free)")
        nb = self.blocks_for(ntokens)
        if nb > self.max_blocks_per_req:
            raise SlotError(
                f"request of {ntokens} tokens needs {nb} blocks > "
                f"max_blocks_per_req={self.max_blocks_per_req}")
        blocks = self.pool.alloc(nb, owner)   # raises before row is taken
        slot = self._free_rows.pop()
        self._owner[slot] = owner
        self._last_owner[slot] = owner
        self._tables[slot, :] = -1
        self._tables[slot, :nb] = np.asarray(blocks, np.int32)
        self._tables_dev = None
        self._nblocks[slot] = nb
        self._len[slot] = 0
        return slot

    def alloc_prefix(self, owner: object, ntokens: int, hit,
                     cache) -> int:
        """Claim a row backed partly by cached prefix blocks: the hit's
        blocks are leased at refcount+1 through ``cache.lease`` (CoW
        source included, as a temporary reference) and only the miss
        tail is freshly allocated. Lease-before-alloc ordering matters:
        a reclaim triggered by the fresh allocation can never evict a
        block this request just hit."""
        if owner is None:
            raise SlotError("row owner must be non-None")
        if not self._free_rows:
            raise SlotError("request rows exhausted (admission must gate "
                            "on num_free)")
        nb = self.blocks_for(ntokens)
        if nb > self.max_blocks_per_req:
            raise SlotError(
                f"request of {ntokens} tokens needs {nb} blocks > "
                f"max_blocks_per_req={self.max_blocks_per_req}")
        shared = list(hit.blocks)
        cache.lease(hit, owner)
        try:
            fresh = self.pool.alloc(nb - len(shared), owner)
        except SlotError:
            # unwind the shared leases; admission should have gated
            if hit.cow_src is not None:
                self.pool.free([hit.cow_src])
            self.pool.free(shared)
            raise
        slot = self._free_rows.pop()
        self._owner[slot] = owner
        self._last_owner[slot] = owner
        self._tables[slot, :] = -1
        self._tables[slot, :nb] = np.asarray(shared + fresh, np.int32)
        self._tables_dev = None
        self._nblocks[slot] = nb
        self._len[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        if self._owner[slot] is None:
            raise SlotError(
                f"double free of request row {slot} "
                f"(last owner {self._last_owner[slot]!r})")
        nb = int(self._nblocks[slot])
        self.pool.free(self._tables[slot, :nb].tolist())
        self._tables[slot, :] = -1
        self._tables_dev = None
        self._nblocks[slot] = 0
        self._owner[slot] = None
        self._len[slot] = 0
        self._free_rows.append(slot)

    def advance(self, slot: int, n: int = 1) -> None:
        """Account ``n`` more resident tokens in ``slot``. The lease
        already covers them (blocks are reserved at admission), so this
        is bookkeeping only — but overrunning the lease is a bug."""
        if self._owner[slot] is None:
            raise SlotError(f"advance on free row {slot}")
        new = int(self._len[slot]) + int(n)
        if new > int(self._nblocks[slot]) * self.block_size:
            raise SlotError(
                f"row {slot} (owner {self._owner[slot]!r}) overran its "
                f"lease: {new} tokens > {int(self._nblocks[slot])} blocks "
                f"x {self.block_size}")
        self._len[slot] = new

    # -- tables / buffers --------------------------------------------------
    def table_rows(self, slots) -> np.ndarray:
        """(len(slots), max_blocks_per_req) int32 view copies for a chunk
        dispatch; out-of-range row indices yield all ``-1`` (drop) rows."""
        out = np.full((len(slots), self.max_blocks_per_req), -1, np.int32)
        for i, s in enumerate(slots):
            if 0 <= s < self.num_slots:
                out[i] = self._tables[s]
        return out

    def tables_device(self):
        """The full (num_slots, max_blocks_per_req) table as a device
        array — the decode dispatch's indirection input. Cached: tables
        mutate only at alloc/free/reset, so the common decode micro-step
        (no admission, no finish) pays no host→device transfer."""
        if self._tables_dev is None:
            self._tables_dev = jnp.asarray(self._tables)
        return self._tables_dev

    @property
    def buffers(self):
        """The pooled cache pytree (k/v: (L, P, bs, Gs, hd))."""
        return self._buf

    def swap_buffers(self, new_buf) -> None:
        """Install the donated-output pool after a dispatch."""
        self._buf = new_buf

    # -- accounting --------------------------------------------------------
    @property
    def capacity_tokens(self) -> int:
        return self.pool.num_blocks * self.block_size

    @property
    def resident_capacity_tokens(self) -> int:
        """Token capacity currently leased (the HBM actually pinned by
        live requests, in token units)."""
        return int(self._nblocks.sum()) * self.block_size

    @property
    def kv_bytes(self) -> int:
        return int(sum(x.nbytes
                       for x in jax.tree_util.tree_leaves(self._buf)))

    def reset(self, *, strict: bool = False) -> None:
        """Return every row and block to the free pools. Rows still
        occupied are lease leaks and are named (warn, or raise under
        ``strict=True``); the block pool runs the same check."""
        leaked = [(s, self._owner[s]) for s in range(self.num_slots)
                  if self._owner[s] is not None]
        if leaked:
            msg = (f"reset with {len(leaked)} live request row(s): "
                   + ", ".join(f"row {s} (owner {o!r})" for s, o in leaked))
            if strict:
                raise LeaseLeakError(msg)
            warnings.warn(msg, LeaseLeakWarning, stacklevel=2)
        if leaked:
            # the row check already named this reset's leak; the pool's
            # own check would re-name the same leases block-by-block
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", LeaseLeakWarning)
                self.pool.reset()
        else:
            # rows clean, but prefix-shared refs can outlive their rows
            self.pool.reset(strict=strict)
        self._tables[:] = -1
        self._tables_dev = None
        self._free_rows = list(range(self.num_slots - 1, -1, -1))
        self._owner = [None] * self.num_slots
        self._nblocks[:] = 0
        self._len[:] = 0

    def reset_rows(self, *, strict: bool = False) -> None:
        """Free every request *row* (and its block lease) while leaving
        the rest of the pool — the prefix cache's parked index and the
        device buffers — intact. This is the warm-cache reset: a new
        trace starts with empty rows but a populated cache. Occupied
        rows are still leaks and are named exactly like :meth:`reset`;
        they are then freed through the ordinary path, so shared blocks
        fall back to the cache (parked) rather than vanishing."""
        leaked = [(s, self._owner[s]) for s in range(self.num_slots)
                  if self._owner[s] is not None]
        if leaked:
            msg = (f"reset with {len(leaked)} live request row(s): "
                   + ", ".join(f"row {s} (owner {o!r})" for s, o in leaked))
            if strict:
                raise LeaseLeakError(msg)
            warnings.warn(msg, LeaseLeakWarning, stacklevel=2)
            for s, _ in leaked:
                self.free(s)
        self._tables_dev = None
