"""Slot-pool paged KV cache for continuous batching (DESIGN.md §8).

The decode state of every in-flight request lives in one stacked pytree of
fixed-capacity *slots* — leading dim ``num_slots``, one per-request cache
(batch=1, the model's own ``init_cache`` structure) per slot. Requests are
admitted by allocating a slot and depositing their prefilled cache into it
with a donation-safe in-place update; they retire by freeing the slot,
whose buffers are simply overwritten by the next occupant.

Design points (mirrors the paper's cell pool + *Lessons Learned on
MPI+Threads*' independent-state rule):

* **Fixed pool, O(1) alloc/free.** Slots are the bounded resource the
  scheduler's cell queue admits against; there is no dynamic allocation on
  the serving hot path.
* **Per-slot independent state.** Each slot carries its own KV rows, SSM
  state and position counter, so in-flight requests never serialize on
  shared mutable state — decode over the pool is an embarrassingly
  batched ``vmap`` over slots.
* **Paged/ring recycling.** ``cache_len`` bounds the pages a slot holds;
  for sub-quadratic archs the model layer recycles pages in place
  (``pos % cache_len`` ring addressing), so a slot serves arbitrarily long
  decodes at fixed footprint.
* **Donation-safe updates.** Both the insert (``dynamic_update_slice`` at
  the slot index) and the decode step donate the stacked buffers, so XLA
  aliases them end-to-end — no full-pool copies per token.
"""

from __future__ import annotations

import warnings
from typing import Any, List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


class SlotError(RuntimeError):
    """Slot-pool misuse (double free, insert into a free slot, exhaustion)."""


class LeaseLeakError(SlotError):
    """Live leases found where a clean pool was required (``strict=True``
    reset/close). The message names every leaked owner."""


class LeaseLeakWarning(UserWarning):
    """Live leases found at reset/close (non-strict): the pool is wiped
    anyway, but the leak — requests that never reached ``free`` — is
    named so it can't pass silently."""


class SlotKVCache:
    """Fixed pool of per-request decode-state slots over a stacked pytree."""

    def __init__(self, model, cache_len: int, num_slots: int):
        if num_slots < 1:
            raise SlotError("need at least one slot")
        self.model = model
        self.cache_len = int(cache_len)
        self.num_slots = int(num_slots)
        proto = model.init_cache(1, cache_len)   # per-request (batch=1) cache
        self._buf = jax.tree_util.tree_map(
            lambda x: jnp.zeros((num_slots,) + x.shape, x.dtype), proto)
        self._free: List[int] = list(range(num_slots - 1, -1, -1))
        self._owner: List[Optional[object]] = [None] * num_slots
        self._last_owner: List[Optional[object]] = [None] * num_slots
        # tokens resident per slot (prompt + generated); capped by cache_len
        # only in the ring sense — the model recycles pages past capacity.
        # np.int32: one dtype for ALL host-side length bookkeeping, matching
        # the int32 device positions (and the paged pool's tables/lengths)
        self._len = np.zeros((num_slots,), np.int32)
        self._insert = jax.jit(self._insert_impl, donate_argnums=(0,))
        self._reset_one = jax.jit(self._reset_slot_impl, donate_argnums=(0,))
        self._gather = jax.jit(self.rows_at)
        self._scatter = jax.jit(self.rows_into, donate_argnums=(0,))

    @staticmethod
    def _insert_impl(buf, one, slot):
        return jax.tree_util.tree_map(
            lambda b, o: lax.dynamic_update_slice_in_dim(
                b, o[None].astype(b.dtype), slot, axis=0), buf, one)

    @staticmethod
    def _reset_slot_impl(buf, slot):
        """Blank one slot's rows: positions to -1 (no valid pages), every
        other leaf to zeros — the clean-slate a chunked prefill streams
        into (a monolithic insert overwrites the whole slot instead)."""
        def leaf(path, b):
            fill = -1 if any(getattr(k, "key", None) == "pos"
                             for k in path) else 0
            return lax.dynamic_update_slice_in_dim(
                b, jnp.full((1,) + b.shape[1:], fill, b.dtype), slot, axis=0)
        return jax.tree_util.tree_map_with_path(leaf, buf)

    # -- fixed-shape row views (chunked prefill) ---------------------------
    @staticmethod
    def rows_at(buf, slots):
        """Gather per-slot cache rows: (num_slots, ...) -> (P, ...).
        Out-of-range indices clamp (callers pad row batches with
        ``num_slots`` and mask — the garbage gather is never written
        back). Pure; composable inside a caller's fused jit."""
        return jax.tree_util.tree_map(
            lambda b: jnp.take(b, slots, axis=0, mode="clip"), buf)

    @staticmethod
    def rows_into(buf, rows, slots):
        """Scatter updated rows back at ``slots`` (drop-mode: out-of-range
        padding rows write nothing). The inverse of :meth:`rows_at`; pure,
        composable inside a caller's fused jit."""
        return jax.tree_util.tree_map(
            lambda b, r: b.at[slots].set(r.astype(b.dtype), mode="drop"),
            buf, rows)

    # -- pool management ---------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_live(self) -> int:
        return self.num_slots - len(self._free)

    @property
    def live_slots(self) -> List[int]:
        return [s for s in range(self.num_slots) if self._owner[s] is not None]

    def owner(self, slot: int):
        return self._owner[slot]

    def length(self, slot: int) -> int:
        return int(self._len[slot])

    @property
    def lengths(self) -> np.ndarray:
        return self._len.copy()

    def alloc(self, owner: object) -> int:
        """Claim a free slot for ``owner``. Raises on exhaustion — admission
        control (the scheduler's cell queue) must gate on ``num_free``."""
        if owner is None:
            raise SlotError("slot owner must be non-None")
        if not self._free:
            raise SlotError("slot pool exhausted (admission must gate on "
                            "num_free)")
        slot = self._free.pop()
        self._owner[slot] = owner
        self._last_owner[slot] = owner
        self._len[slot] = 0
        return slot

    def free(self, slot: int) -> None:
        if self._owner[slot] is None:
            raise SlotError(f"double free of slot {slot} "
                            f"(last owner {self._last_owner[slot]!r})")
        self._owner[slot] = None
        self._len[slot] = 0
        self._free.append(slot)

    # -- buffer access -----------------------------------------------------
    @property
    def buffers(self):
        """The stacked cache pytree (leading dim = num_slots)."""
        return self._buf

    def swap_buffers(self, new_buf) -> None:
        """Install the donated-output buffers after a decode step; the old
        reference is dead (its storage was donated to the step)."""
        self._buf = new_buf

    def insert(self, slot: int, request_cache: Any, length: int) -> None:
        """Deposit a prefilled per-request cache (batch=1 pytree) into
        ``slot``. In-place on device (dynamic_update_slice over donated
        buffers)."""
        if self._owner[slot] is None:
            raise SlotError(f"insert into free slot {slot}")
        self._buf = self._insert(self._buf, request_cache, jnp.int32(slot))
        self._len[slot] = int(length)

    def advance(self, slot: int, n: int = 1) -> None:
        """Account ``n`` more resident tokens in ``slot`` (one decode
        micro-step appends one page entry, ring-recycled past capacity)."""
        if self._owner[slot] is None:
            raise SlotError(f"advance on free slot {slot}")
        self._len[slot] += n

    # -- chunked prefill (incremental deposit) -----------------------------
    def reset_slot(self, slot: int) -> None:
        """Blank a live slot before streaming a prompt into it chunk by
        chunk: position pages to -1, state to zeros. Required because a
        chunked deposit *appends* pages instead of overwriting the whole
        slot — stale pages from the previous occupant must not alias as
        valid history."""
        if self._owner[slot] is None:
            raise SlotError(f"reset of free slot {slot}")
        self._buf = self._reset_one(self._buf, jnp.int32(slot))
        self._len[slot] = 0

    def take_rows(self, slots) -> Any:
        """Gathered per-slot cache rows for ``slots`` (host-level wrapper
        over :meth:`rows_at`)."""
        return self._gather(self._buf, jnp.asarray(slots, jnp.int32))

    def insert_at(self, slots, rows, lengths=None) -> None:
        """Deposit updated cache rows back into their ``slots`` — the
        append-pages half of a chunked handoff. ``lengths`` (optional,
        same order as ``slots``) sets the resident-token count per slot;
        chunk streaming instead accounts pages via :meth:`advance` as each
        chunk lands."""
        slots = np.asarray(slots)
        self._buf = self._scatter(self._buf, rows,
                                  jnp.asarray(slots, jnp.int32))
        if lengths is not None:
            for s, n in zip(slots.tolist(), np.asarray(lengths).tolist()):
                if 0 <= s < self.num_slots:
                    if self._owner[s] is None:
                        raise SlotError(f"insert_at into free slot {s}")
                    self._len[s] = int(n)

    def reset(self, *, strict: bool = False) -> None:
        """Return every slot to the free pool and zero the page accounting
        (buffer contents are lazily reclaimed: the next occupant either
        overwrites its slot wholesale or ``reset_slot``s it first).

        A reset over live slots is a lease leak — those requests never
        reached ``free`` — so the leaked owners are named: warn
        (:class:`LeaseLeakWarning`) by default, raise
        (:class:`LeaseLeakError`) under ``strict=True``."""
        leaked = [(s, self._owner[s]) for s in range(self.num_slots)
                  if self._owner[s] is not None]
        if leaked:
            msg = (f"reset with {len(leaked)} live slot lease(s): "
                   + ", ".join(f"slot {s} (owner {o!r})" for s, o in leaked))
            if strict:
                raise LeaseLeakError(msg)
            warnings.warn(msg, LeaseLeakWarning, stacklevel=2)
        self._free = list(range(self.num_slots - 1, -1, -1))
        self._owner = [None] * self.num_slots
        self._len[:] = 0
