"""Serving engines: static batch (parity baseline) and continuous batching
over the threadcomm substrate (DESIGN.md §8).

``StaticEngine`` is the original fixed-batch path: prefill a whole batch,
decode every row in lockstep until all are done. It stays as the parity
and throughput baseline.

``ContinuousEngine`` interleaves prefill and decode *micro-steps* over a
fixed pool of KV slots (:mod:`repro.serve.kv_cache`): each host step
admits up to ``max_prefill_per_step`` requests from the cell-queue
scheduler (:mod:`repro.serve.scheduler`), prefills them one at a time
into freed slots, then advances every live slot by one token. Decode over
the pool is a single jit'd ``vmap`` of the model's ``decode_step`` with
*per-slot* positions and donated buffers — each slot's state is fully
independent (no shared mutable state across in-flight requests), which is
the serving-side reading of the MPI+Threads lesson that accidental
serialization, not concurrency itself, is what kills throughput.

Threadcomm integration:

* ``comm=`` binds the engine to a (sub-)communicator; prefill inserts and
  decode steps are then threaded through two distinct ``CommStream``s
  ("prefill" / "decode"), giving each domain explicit program order while
  leaving the two free to overlap — the MPIX-stream discipline applied to
  serving.
* Data-parallel replica fan-out is ``Comm.split`` + ``shard_trace``: each
  replica family runs its own engine over its slice of the traffic (see
  ``tests/mp_cases.py::case_serve_replica_fanout``).
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.kv_cache import SlotKVCache
from repro.serve.scheduler import CellQueueScheduler, ServeRequest


def _sample_rows(logits, keys, temps):
    """Per-row sampling: greedy when temp <= 0, else temperature
    categorical with that row's own PRNG key. logits (B, Vp)."""
    greedy = jnp.argmax(logits, -1)
    drawn = jax.vmap(
        lambda l, k, t: jax.random.categorical(
            k, l / jnp.maximum(t, 1e-6), -1))(logits, keys, temps)
    return jnp.where(temps > 0.0, drawn, greedy).astype(jnp.int32)


class _NullStream:
    """Stand-in when no communicator is bound: no ordering constraints."""

    def ordered(self, value):
        return value


# ---------------------------------------------------------------------------
# Static batch (the original Engine; parity + throughput baseline)
# ---------------------------------------------------------------------------

class StaticEngine:
    """Fixed-batch engine: one prefill, lockstep decode, done-masking."""

    def __init__(self, model, params, cache_len: int, eos_id: int = -1):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len))
        self._step = jax.jit(model.decode_step, donate_argnums=(1,))

    def generate(self, batch, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """batch: model input dict (prompt). Returns (B, max_new) tokens.
        Rows finished early emit ``eos_id``; an all-done batch exits the
        loop (and the remaining columns are already eos-padded)."""
        logits, cache = self._prefill(self.params, batch)
        B = logits.shape[0]
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "patch_stub":
            prompt_len += self.model.cfg.num_frontend_tokens
        key = jax.random.PRNGKey(seed)
        fill = self.eos_id if self.eos_id >= 0 else 0
        out = np.full((B, max_new_tokens), fill, np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, temperature, key)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, self.eos_id, np.asarray(tok)[:, 0])
            if self.eos_id >= 0:
                done |= out[:, t] == self.eos_id
                if done.all():
                    break
            pos = jnp.int32(prompt_len + t)
            logits, cache = self._step(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return out

    def _sample(self, logits, temperature: float, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, logits / temperature, -1).astype(jnp.int32)[:, None]


Engine = StaticEngine   # backwards-compatible alias


# ---------------------------------------------------------------------------
# Continuous batching over the slot pool
# ---------------------------------------------------------------------------

class ContinuousEngine:
    """Continuous-batching engine: slot-pool decode + cell-queue admission.

    ``step(now)`` is one micro-step; drive it from a traffic loop (see
    ``repro.launch.serve``) or use :meth:`generate` for the batch-API
    convenience path (same-arrival batch, used by the parity tests).
    """

    def __init__(self, model, params, *, cache_len: int, num_slots: int,
                 eos_id: int = -1, scheduler: Optional[CellQueueScheduler] = None,
                 comm=None, max_prefill_per_step: int = 1):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.max_prefill_per_step = max(1, int(max_prefill_per_step))
        self.kv = SlotKVCache(model, cache_len, num_slots)
        self.scheduler = scheduler or CellQueueScheduler(
            num_cells=4 * num_slots)
        if comm is not None:
            self._prefill_stream = comm.stream("prefill")
            self._decode_stream = comm.stream("decode")
        else:
            self._prefill_stream = _NullStream()
            self._decode_stream = _NullStream()

        self._prefill = jax.jit(lambda p, b: model.prefill(p, b, cache_len))
        self._decode = jax.jit(self._decode_impl(model),
                               donate_argnums=(1, 2))
        self._admit_state = jax.jit(self._admit_impl, donate_argnums=(0,))

        # per-slot sampling/position state lives ON DEVICE and is updated
        # inside the jits (donated) — the decode hot loop costs one
        # dispatch + one small token sync per micro-step, no host↔device
        # state shuttling
        S = num_slots
        self._state = {
            "tok": jnp.zeros((S, 1, 1), jnp.int32),    # next input token
            "pos": jnp.zeros((S,), jnp.int32),         # next decode position
            "keys": jnp.zeros((S, 2), jnp.uint32),     # per-slot PRNG keys
            "temp": jnp.zeros((S,), jnp.float32),
        }
        self._slot_req: List[Optional[ServeRequest]] = [None] * S
        self._slot_out: List[Optional[np.ndarray]] = [None] * S

    @staticmethod
    def _decode_impl(model):
        vstep = jax.vmap(model.decode_step, in_axes=(None, 0, 0, 0))

        def fn(params, buf, state):
            logits, buf = vstep(params, buf, state["tok"],
                                state["pos"])            # logits (S, 1, Vp)
            split = jax.vmap(jax.random.split)(state["keys"])  # (S, 2, 2)
            nxt = _sample_rows(logits[:, 0, :], split[:, 1], state["temp"])
            state = {"tok": nxt.reshape(-1, 1, 1),
                     "pos": state["pos"] + 1,
                     "keys": split[:, 0],
                     "temp": state["temp"]}
            return nxt, buf, state

        return fn

    @staticmethod
    def _admit_impl(state, logits, slot, key, temp, pos0):
        """Seed slot ``slot`` from the prefill logits: sample the first
        token with the request's own key, install (tok, pos, key, temp)."""
        key, sub = jax.random.split(key)
        tok0 = _sample_rows(logits, sub[None], temp[None])[0]
        state = {
            "tok": state["tok"].at[slot].set(tok0),
            "pos": state["pos"].at[slot].set(pos0),
            "keys": state["keys"].at[slot].set(key),
            "temp": state["temp"].at[slot].set(temp),
        }
        return state, tok0

    # -- request intake ----------------------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Queue a request through the cell-queue scheduler."""
        return self.scheduler.submit(req, now)

    @property
    def num_active(self) -> int:
        return self.kv.num_live

    @property
    def idle(self) -> bool:
        return self.kv.num_live == 0 and self.scheduler.num_waiting == 0

    # -- micro-step --------------------------------------------------------
    def step(self, now: float = 0.0) -> List[ServeRequest]:
        """One serving micro-step: admit + prefill up to
        ``max_prefill_per_step`` requests, then advance every live slot by
        one token. Returns the requests that finished this step."""
        finished: List[ServeRequest] = []
        n_admit = min(self.kv.num_free, self.max_prefill_per_step)
        for req in self.scheduler.admit(now, n_admit):
            done = self._admit(req, now)
            if done is not None:
                finished.append(done)
        if self.kv.num_live:
            finished.extend(self._decode_micro_step(now))
        return finished

    def _admit(self, req: ServeRequest, now: float) -> Optional[ServeRequest]:
        """Prefill one request into a freshly allocated slot. Returns the
        request if it finished immediately (EOS on the first token /
        max_new == 1), else None."""
        batch = {k: jnp.asarray(v) for k, v in req.batch.items()}
        logits, cache = self._prefill(self.params, batch)
        cache = self._prefill_stream.ordered(cache)

        slot = self.kv.alloc(req)
        prompt_len = req.prompt_len
        if self.model.cfg.frontend == "patch_stub":
            prompt_len += self.model.cfg.num_frontend_tokens
        self.kv.insert(slot, cache, length=prompt_len)

        key = jax.random.fold_in(jax.random.PRNGKey(req.seed), req.rid)
        self._state, tok0_dev = self._admit_state(
            self._state, logits, jnp.int32(slot), key,
            jnp.float32(req.temperature), jnp.int32(prompt_len))
        tok0 = int(np.asarray(tok0_dev))
        req.first_token_time = now
        fill = self.eos_id if self.eos_id >= 0 else 0
        out = np.full((req.max_new_tokens,), fill, np.int32)
        out[0] = tok0
        req.generated = 1
        if (0 <= self.eos_id == tok0) or req.max_new_tokens == 1:
            return self._finish(slot, req, out, now)

        self._slot_req[slot] = req
        self._slot_out[slot] = out
        return None

    def _decode_micro_step(self, now: float) -> List[ServeRequest]:
        state = self._decode_stream.ordered(self._state)
        nxt, buf, state = self._decode(self.params, self.kv.buffers, state)
        self.kv.swap_buffers(buf)
        self._state = state
        nxt_np = np.asarray(nxt)        # the one host sync per micro-step

        finished: List[ServeRequest] = []
        for slot in self.kv.live_slots:
            req = self._slot_req[slot]
            t = int(nxt_np[slot])
            out = self._slot_out[slot]
            out[req.generated] = t
            req.generated += 1
            self.kv.advance(slot)
            if (0 <= self.eos_id == t) \
                    or req.generated >= req.max_new_tokens:
                finished.append(self._finish(slot, req, out, now))
                self._slot_req[slot] = None
                self._slot_out[slot] = None
        return finished

    def _finish(self, slot: int, req: ServeRequest, out: np.ndarray,
                now: float) -> ServeRequest:
        req.output = out
        self.kv.free(slot)
        self.scheduler.record_finish(req, now)
        return req

    # -- batch-API convenience (parity with StaticEngine.generate) --------
    def generate(self, batch, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Same-arrival batch through the continuous path: split the batch
        into per-row requests, run micro-steps until drained, reassemble
        (B, max_new) in row order."""
        B = batch["tokens"].shape[0]
        reqs = []
        for i in range(B):
            row = {k: np.asarray(v[i:i + 1]) for k, v in batch.items()}
            req = ServeRequest(rid=i, batch=row,
                               max_new_tokens=max_new_tokens,
                               temperature=temperature, seed=seed)
            reqs.append(req)
            self.submit(req, 0.0)
        steps = 0
        limit = (B * (max_new_tokens + 2)) // max(1, self.kv.num_slots) \
            + B * (max_new_tokens + 2)
        while not self.idle:
            self.step(0.0)
            steps += 1
            if steps > limit:
                raise RuntimeError("continuous generate failed to drain")
        return np.stack([r.output for r in reqs])
