"""Batched serving engine: prefill + greedy/temperature decode over the
model bundle's cached decode_step.

Straightforward static-batch engine with per-sequence done-masking (EOS).
The decode loop is a host loop over a jit'd step (donated cache) — at test
scale this is the right trade-off; the dry-run cells lower the same
``decode_step`` that this engine drives.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class Engine:
    def __init__(self, model, params, cache_len: int, eos_id: int = -1):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len))
        self._step = jax.jit(model.decode_step, donate_argnums=(1,))

    def generate(self, batch, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """batch: model input dict (prompt). Returns (B, max_new) tokens."""
        logits, cache = self._prefill(self.params, batch)
        B = logits.shape[0]
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "patch_stub":
            prompt_len += self.model.cfg.num_frontend_tokens
        key = jax.random.PRNGKey(seed)
        out = np.zeros((B, max_new_tokens), np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, temperature, key)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, self.eos_id, np.asarray(tok)[:, 0])
            if self.eos_id >= 0:
                done |= out[:, t] == self.eos_id
                if done.all():
                    break
            pos = jnp.int32(prompt_len + t)
            logits, cache = self._step(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temperature, sub)
        return out

    def _sample(self, logits, temperature: float, key):
        if temperature <= 0.0:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        return jax.random.categorical(
            key, logits / temperature, -1).astype(jnp.int32)[:, None]
