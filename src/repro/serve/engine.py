"""Serving engines: static batch (parity baseline) and continuous batching
over the threadcomm substrate (DESIGN.md §8).

``StaticEngine`` is the original fixed-batch path: prefill a whole batch,
decode every row in lockstep until all are done. It stays as the parity
and throughput baseline.

``ContinuousEngine`` interleaves prefill and decode *micro-steps* over a
fixed pool of KV slots (:mod:`repro.serve.kv_cache`) — or, with
``kv_layout="paged"``, over a global pool of fixed-size KV *blocks*
leased through per-request block tables
(:mod:`repro.serve.block_pool`, DESIGN.md §9): admission then gates on
free blocks instead of free slots, prompts deposit chunk-by-chunk
through the tables, and decode is the model's batched block-table step
(`decode_step_paged`; the same computation's TPU hot-path kernel is
``kernels/paged_attention`` — a standalone validated artifact like
flash_attention, not yet dispatched from the model path).
Each host step admits requests from the cell-queue scheduler
(:mod:`repro.serve.scheduler`), deposits their prompts, then advances
every live slot by one token. Decode over the pool is a single jit'd
``vmap`` of the model's ``decode_step`` with *per-slot* positions and
donated buffers — each slot's state is fully independent (no shared
mutable state across in-flight requests), which is the serving-side
reading of the MPI+Threads lesson that accidental serialization, not
concurrency itself, is what kills throughput.

Prompt deposit follows the paper's rendezvous discipline, chunked
(DESIGN.md §8): with ``prefill_chunk > 0`` (and a model exposing
``prefill_chunk``) prompts stream into their slot in fixed-size chunks —
up to ``max_prefill_per_step`` chunk-rows from *different* requests are
batched into one fused dispatch per micro-step, interleaved with decode.
A long prompt therefore never monopolizes the device between two decode
steps (no prefill head-of-line blocking), and because the chunk jit's
shapes never change, prefill compiles O(1) XLA programs however many
distinct prompt lengths the traffic carries — versus one compile per
distinct length on the monolithic path (``prefill_chunk=0``), which
stays available as an explicit baseline. Every registry family runs the
chunked path (DESIGN.md §13): SSM/hybrid thread recurrent carried state
through the chunk steps, MoE routes per-token (dropless), and enc-dec
runs its encoder as a fixed pre-chunk on the paged layout. The engine
consults the model's structural capability flags
(``registry.derive_capabilities``) and *raises* naming the missing
capability when a path is unsupported (patch_stub frontends; enc-dec on
the slot layout) — never a silent monolithic fallback.

Threadcomm integration:

* ``comm=`` binds the engine to a (sub-)communicator; prefill inserts and
  decode steps are then threaded through two distinct ``CommStream``s
  ("prefill" / "decode"), giving each domain explicit program order while
  leaving the two free to overlap — the MPIX-stream discipline applied to
  serving.
* Data-parallel replica fan-out is ``Comm.split`` + ``shard_trace``: each
  replica family runs its own engine over its slice of the traffic (see
  ``tests/mp_cases.py::case_serve_replica_fanout``).
* The multi-rank serving fabric (:mod:`repro.serve.fabric`, DESIGN.md
  §10) composes engines across ranks: ``role="prefill"`` engines lease
  prompt-only paged blocks and park finished prefills in
  ``ready_handoffs`` for block-by-block KV migration to a decode rank
  (``begin_import``/``finish_import``), never running a decode dispatch
  themselves.
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass
from typing import Deque, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol
# telemetry (REPRO_TRACE=1, DESIGN.md §15): micro-step spans, admission
# residual hops, trial flush — one global read + None check when off
from repro.obs import flush_trial as _obs_flush_trial
from repro.obs import metrics as obs_metrics
from repro.obs.trace import active as _tr_active
from repro.serve.block_pool import PagedKVCache
from repro.serve.kv_cache import SlotError, SlotKVCache
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import CellQueueScheduler, ServeRequest


def _sample_rows(logits, keys, temps):
    """Per-row sampling: greedy when temp <= 0, else temperature
    categorical with that row's own PRNG key. logits (B, Vp)."""
    greedy = jnp.argmax(logits, -1)
    drawn = jax.vmap(
        lambda l, k, t: jax.random.categorical(
            k, l / jnp.maximum(t, 1e-6), -1))(logits, keys, temps)
    return jnp.where(temps > 0.0, drawn, greedy).astype(jnp.int32)


class _NullStream:
    """Stand-in when no communicator is bound: no ordering constraints."""

    def ordered(self, value):
        return value


# ---------------------------------------------------------------------------
# Static batch (the original Engine; parity + throughput baseline)
# ---------------------------------------------------------------------------

class StaticEngine:
    """Fixed-batch engine: one prefill, lockstep decode, done-masking."""

    def __init__(self, model, params, cache_len: int, eos_id: int = -1):
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cache_len))
        self._step = jax.jit(model.decode_step, donate_argnums=(1,))

    def generate(self, batch, max_new_tokens: int, *,
                 temperature=0.0, seed: int = 0) -> np.ndarray:
        """batch: model input dict (prompt). Returns (B, max_new) tokens.
        Rows finished early emit ``eos_id``; an all-done batch exits the
        loop (and the remaining columns are already eos-padded).

        ``temperature`` is a scalar or a per-row (B,) vector — a mixed
        batch samples each row at its own temperature (per-row split
        keys) instead of silently applying one row's temperature to all.
        """
        logits, cache = self._prefill(self.params, batch)
        B = logits.shape[0]
        prompt_len = batch["tokens"].shape[1]
        if self.model.cfg.frontend == "patch_stub":
            prompt_len += self.model.cfg.num_frontend_tokens
        temps = np.asarray(temperature, np.float32)
        if temps.ndim == 0:
            temps = np.full((B,), float(temps), np.float32)
        elif temps.shape != (B,):
            raise ValueError(f"temperature must be scalar or ({B},), got "
                             f"shape {temps.shape}")
        key = jax.random.PRNGKey(seed)
        fill = self.eos_id if self.eos_id >= 0 else 0
        out = np.full((B, max_new_tokens), fill, np.int32)
        done = np.zeros((B,), bool)
        tok = self._sample(logits, temps, key)
        for t in range(max_new_tokens):
            out[:, t] = np.where(done, self.eos_id, np.asarray(tok)[:, 0])
            if self.eos_id >= 0:
                done |= out[:, t] == self.eos_id
                if done.all():
                    break
            pos = jnp.int32(prompt_len + t)
            logits, cache = self._step(self.params, cache, tok, pos)
            key, sub = jax.random.split(key)
            tok = self._sample(logits, temps, sub)
        return out

    def _sample(self, logits, temps: np.ndarray, key):
        if (temps <= 0.0).all():
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        keys = jax.random.split(key, logits.shape[0])
        return _sample_rows(logits, keys, jnp.asarray(temps))[:, None]


Engine = StaticEngine   # backwards-compatible alias


# ---------------------------------------------------------------------------
# Continuous batching over the slot pool
# ---------------------------------------------------------------------------

#: parked per-slot decode position: so far below zero that the drop-mode
#: cache writes in ``decode_step`` discard everything a free or
#: still-prefilling slot's vmap row produces
PARK_POS = -(2 ** 30)


@dataclass(eq=False)      # identity equality: deque.remove must never
class _PrefillJob:        # field-compare requests (ndarray __eq__ raises)
    """A partially-deposited prompt: the engine streams ``tokens`` into
    ``slot`` chunk by chunk (``off`` tokens landed so far)."""
    req: ServeRequest
    slot: int
    tokens: np.ndarray            # (prompt_len,) int32
    key: jax.Array                # per-request PRNG key (fold_in(rid))
    off: int = 0


@dataclass(eq=False)
class KVHandoff:
    """A prefill-complete request ready to migrate to a decode rank
    (disaggregated fabric, DESIGN.md §10): the local request row still
    holds the prompt's KV blocks and the sampled-first-token decode
    state. The owning engine keeps the lease until
    :meth:`ContinuousEngine.release_handoff` — the source blocks must
    not be recycled while the transport is still copying out of them."""
    req: ServeRequest
    slot: int                     # source request row
    out: np.ndarray               # (max_new,) output buffer, out[0] = tok0
    length: int                   # resident prompt tokens
    blocks: List[int]             # source pool block ids, table order


class ContinuousEngine:
    """Continuous-batching engine: slot-pool decode + cell-queue admission
    + chunked, batched prefill.

    ``step(now)`` is one micro-step; drive it from a traffic loop (see
    ``repro.launch.serve``) or use :meth:`generate` for the batch-API
    convenience path (same-arrival batch, used by the parity tests).
    """

    def __init__(self, model, params, *, cache_len: int, num_slots: int,
                 eos_id: int = -1, scheduler: Optional[CellQueueScheduler] = None,
                 comm=None, max_prefill_per_step: int = 1,
                 prefill_chunk: int = 64, kv_layout: str = "slot",
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 role: str = "full", prefix_cache: bool = False,
                 speculate: int = 0, draft_model=None, draft_params=None):
        if kv_layout not in ("slot", "paged"):
            raise ValueError(f"unknown kv_layout {kv_layout!r} "
                             "(expected 'slot' or 'paged')")
        if role not in ("full", "prefill", "decode"):
            raise ValueError(f"unknown role {role!r} "
                             "(expected 'full', 'prefill' or 'decode')")
        if role == "prefill" and kv_layout != "paged":
            raise ValueError("a prefill-rank engine hands its KV off "
                             "block-by-block; it requires kv_layout='paged'")
        #: fabric role (DESIGN.md §10): a ``"prefill"`` engine leases
        #: blocks for the prompt only, never decodes, and parks every
        #: prefill-complete request in :attr:`ready_handoffs` for the
        #: transport to migrate; a ``"decode"`` engine receives requests
        #: through :meth:`begin_import`/:meth:`finish_import` instead of
        #: prefilling them. ``"full"`` is the single-engine behavior.
        self.role = role
        self.model = model
        self.params = params
        self.cache_len = cache_len
        self.eos_id = eos_id
        self.max_prefill_per_step = max(1, int(max_prefill_per_step))
        self.kv_layout = kv_layout
        #: structural serving capabilities (registry.derive_capabilities);
        #: None for bare stub models, which are treated as fully capable
        self.capabilities = caps = getattr(model, "capabilities", None)
        # chunked prompt deposit: every registry family chunks (state
        # threading for SSM/hybrid, dropless MoE routing, enc-dec via the
        # paged decoder path — DESIGN.md §13). A family that STILL can't
        # (patch_stub frontend; enc-dec on the slot layout) raises here,
        # naming the missing capability — never a silent monolithic
        # fallback (pass prefill_chunk=0 to choose monolithic explicitly).
        chunk = int(prefill_chunk) if prefill_chunk else 0
        if chunk:
            has_chunk = (getattr(model, "prefill_chunk_paged", None)
                         if kv_layout == "paged"
                         else getattr(model, "prefill_chunk", None))
            if has_chunk is None:
                missing = ("chunked_prefill"
                           if caps is None or not caps.chunked_prefill
                           else "slot_chunk")
                hint = (" — this family chunks on the paged path only; "
                        "use kv_layout='paged'"
                        if caps is not None and caps.chunked_prefill
                        and kv_layout == "slot" else "")
                why = (f" ({caps.reason})"
                       if caps is not None and caps.reason else "")
                raise ValueError(
                    f"model lacks capability {missing!r} for chunked "
                    f"prefill on the {kv_layout} layout{hint}{why}; pass "
                    "prefill_chunk=0 for explicit monolithic prefill")
            chunk = min(chunk, int(cache_len))
            mult = int(caps.chunk_multiple) if caps is not None else 1
            if mult > 1:
                # recurrent families resume bit-exactly only when chunk
                # boundaries fall on ssm_chunk multiples: clamp down
                chunk = (chunk // mult) * mult
                if chunk == 0:
                    raise ValueError(
                        f"prefill_chunk={prefill_chunk} (after the "
                        f"cache_len={cache_len} clamp) is below this "
                        f"family's chunk_multiple={mult}; chunk boundaries "
                        f"must fall on multiples of {mult} for bit-exact "
                        "recurrent-state resume")
        self.prefill_chunk = chunk
        if kv_layout == "paged":
            if getattr(model, "decode_step_paged", None) is None:
                why = (f": {caps.reason}"
                       if caps is not None and caps.reason else "")
                raise ValueError(
                    "model lacks capability 'paged_decode' — no "
                    f"block-table paged decode path{why}")
            if not self.prefill_chunk:
                raise ValueError("paged KV deposits prompts chunk-by-chunk;"
                                 " prefill_chunk must be > 0")
            # equal-HBM default: the same token capacity the slot pool
            # would reserve, repartitioned into leased blocks
            mbr = -(-int(cache_len) // int(block_size))
            nblocks = (int(num_blocks) if num_blocks
                       else -(-num_slots * int(cache_len) // int(block_size)))
            self.kv = PagedKVCache(model, num_blocks=nblocks,
                                   block_size=int(block_size),
                                   num_slots=num_slots,
                                   max_blocks_per_req=mbr)
        else:
            self.kv = SlotKVCache(model, cache_len, num_slots)
        if prefix_cache:
            # radix-tree prefix cache (DESIGN.md §12): admission walks
            # the trie, leases every hit block at refcount+1, and starts
            # chunked prefill at the first miss offset; the cache is the
            # pool's attached reclaimer (LRU eviction of parked blocks)
            if kv_layout != "paged":
                raise ValueError("prefix caching shares paged KV blocks; "
                                 "it requires kv_layout='paged'")
            if role != "full":
                raise ValueError("prefix caching is not supported on "
                                 "disaggregated prefill/decode ranks "
                                 "(migrated blocks leave the local pool)")
            if caps is not None and not caps.prefix_cache:
                raise ValueError("model lacks capability 'prefix_cache': "
                                 + caps.reason)
            if getattr(model, "clone_paged_block", None) is None:
                raise ValueError("prefix caching needs the model's "
                                 "copy-on-write block clone "
                                 "(clone_paged_block)")
            self.prefix_cache = PrefixCache(self.kv.pool)
            self._cow_clone = jax.jit(model.clone_paged_block,
                                      donate_argnums=(0,))
        else:
            self.prefix_cache = None
        # speculative decoding (DESIGN.md §14): a drafter proposes k
        # tokens per round on its OWN paged pool; the target verifies
        # them in one fused (k+1)-query dispatch and rejected draft KV
        # rows roll back structurally (length decrement + the next
        # dispatch's drop-mode overwrite — no blanking)
        self.speculate = int(speculate)
        if self.speculate < 0:
            raise ValueError(f"speculate must be >= 0, got {speculate}")
        if self.speculate:
            if kv_layout != "paged":
                raise ValueError("speculative decoding rolls rejected "
                                 "draft KV back through block tables; it "
                                 "requires kv_layout='paged'")
            if role != "full":
                raise ValueError("speculative decoding needs draft and "
                                 "verify on one engine; it is not "
                                 "supported on disaggregated "
                                 "prefill/decode ranks")
            if self.prefix_cache is not None:
                raise ValueError(
                    "speculative decoding does not compose with prefix "
                    "caching: rolled-back draft rows would sit inside "
                    "blocks the radix cache could lease to another "
                    "request as canonical prefix KV")
            if caps is not None and not caps.speculative:
                raise ValueError("model lacks capability 'speculative': "
                                 + caps.reason)
            if getattr(model, "verify_step_paged", None) is None:
                raise ValueError("speculative decoding needs the model's "
                                 "k-token teacher-forced verify dispatch "
                                 "(verify_step_paged)")
            if draft_model is None:
                # self-speculation: the target drafts for itself on a
                # second pool — the degenerate pairing that exercises
                # the full draft-verify-rollback machinery with a
                # near-1.0 acceptance rate (smoke/CI default)
                draft_model, draft_params = model, params
            else:
                if draft_params is None:
                    raise ValueError("draft_model needs draft_params")
                if draft_model.cfg.vocab_size != model.cfg.vocab_size:
                    raise ValueError(
                        f"drafter vocab {draft_model.cfg.vocab_size} != "
                        f"target vocab {model.cfg.vocab_size}: drafted "
                        "token ids would not index the target's "
                        "distribution")
                dcaps = getattr(draft_model, "capabilities", None)
                if dcaps is not None and not dcaps.speculative:
                    raise ValueError("draft model lacks capability "
                                     "'speculative': " + dcaps.reason)
                if getattr(draft_model, "verify_step_paged", None) is None:
                    raise ValueError(
                        "the drafter resyncs through its own teacher-"
                        "forced verify dispatch (verify_step_paged)")
            self.draft_model = draft_model
            self.draft_params = draft_params
            # the drafter's pool mirrors the target's geometry so rows
            # and leases stay 1:1 (alloc/free in lockstep); its HBM cost
            # is the drafter's own (smaller) per-token KV
            self.draft_kv = PagedKVCache(
                draft_model, num_blocks=self.kv.pool.num_blocks,
                block_size=self.kv.block_size, num_slots=num_slots,
                max_blocks_per_req=self.kv.max_blocks_per_req)
            #: drafter's canonical resident tokens per row (host-side;
            #: the drafter pool's own length bookkeeping is unused — the
            #: model path takes explicit positions)
            self._draft_len = np.zeros((num_slots,), np.int32)
        self.scheduler = scheduler or CellQueueScheduler(
            num_cells=4 * num_slots,
            prefill_chunk_bytes=4 * self.prefill_chunk,
            block_bytes=(4 * int(block_size)
                         if kv_layout == "paged" else 0),
            state_bytes=self._carried_state_bytes())
        if comm is not None:
            self._prefill_stream = comm.stream("prefill")
            self._decode_stream = comm.stream("decode")
            # draft and verify are distinct execution domains (the
            # drafter's pool advances independently of the target's):
            # each gets its own program order, free to overlap the other
            self._draft_stream = comm.stream("draft")
            self._verify_stream = comm.stream("verify")
        else:
            self._prefill_stream = _NullStream()
            self._decode_stream = _NullStream()
            self._draft_stream = _NullStream()
            self._verify_stream = _NullStream()

        # trace counters ~= XLA compile counts (a jit retraces exactly
        # when it compiles a new program); the bench artifact uses these
        # to show chunked prefill compiles O(1) programs while monolithic
        # prefill compiles one per distinct prompt length
        self.prefill_compiles = 0
        self.decode_compiles = 0

        def _prefill_traced(p, b):
            self.prefill_compiles += 1
            return model.prefill(p, b, cache_len)

        decode_fn = (self._decode_impl_paged(model)
                     if kv_layout == "paged" else self._decode_impl(model))

        def _decode_traced(p, buf, state, *rest):
            self.decode_compiles += 1
            return decode_fn(p, buf, state, *rest)

        self._prefill = jax.jit(_prefill_traced)
        self._decode = jax.jit(_decode_traced, donate_argnums=(1, 2))
        # enc-dec: the encoder pass as a fixed pre-chunk at admission —
        # installs the request's per-layer cross K/V carried state into
        # its cache row before the decoder chunk stream starts
        enc = getattr(model, "encode_prechunk", None)
        self._encode = (jax.jit(enc, donate_argnums=(1,))
                        if enc is not None and kv_layout == "paged"
                        else None)
        self._admit_state = jax.jit(self._admit_impl, donate_argnums=(0,))
        self._park_state = jax.jit(self._park_impl, donate_argnums=(0,))
        self._import_state = jax.jit(self._import_state_impl,
                                     donate_argnums=(0,))
        if self.prefill_chunk:
            chunk_fn = (self._chunk_impl_paged(model, num_slots)
                        if kv_layout == "paged"
                        else self._chunk_impl(model, num_slots))

            def _chunk_traced(p, buf, state, *rest):
                self.prefill_compiles += 1
                return chunk_fn(p, buf, state, *rest)

            self._chunk = jax.jit(_chunk_traced, donate_argnums=(1, 2))
        if self.speculate:
            spec_fn = self._spec_round_impl(model, self.draft_model,
                                            self.speculate)

            def _spec_traced(p, dp, buf, dbuf, *rest):
                self.decode_compiles += 1
                return spec_fn(p, dp, buf, dbuf, *rest)

            self._spec_round = jax.jit(_spec_traced, donate_argnums=(2, 3))

            def _draft_chunk_fn(dp, dbuf, tokens, tables, rows, pos0,
                                n_valid):
                # mirror of the target's prompt deposit into the
                # drafter's pool: logits are discarded (the drafter's
                # first proposal comes from the resync dispatch)
                _, dbuf = self.draft_model.prefill_chunk_paged(
                    dp, dbuf, tokens, tables, rows, pos0, n_valid)
                return dbuf

            self._draft_chunk = jax.jit(_draft_chunk_fn,
                                        donate_argnums=(1,))
        #: partially-deposited requests, FIFO; each micro-step serves the
        #: first ``max_prefill_per_step`` of them with one fused dispatch
        self._prefilling: Deque[_PrefillJob] = deque()
        #: role="prefill": prefill-complete requests awaiting migration
        #: (their rows/blocks stay leased until release_handoff)
        self.ready_handoffs: List[KVHandoff] = []

        # per-slot sampling/position state lives ON DEVICE and is updated
        # inside the jits (donated) — the decode hot loop costs one
        # dispatch + one small token sync per micro-step, no host↔device
        # state shuttling. Positions start PARKED (far negative): rows of
        # slots that are free or mid-prefill write nothing (drop-mode
        # scatter in decode_step) however often the pool vmap advances.
        S = num_slots
        self._state = self._fresh_state(S)
        self._slot_req: List[Optional[ServeRequest]] = [None] * S
        self._slot_out: List[Optional[np.ndarray]] = [None] * S

        # serving accounting: peak in-flight requests plus resident-vs-
        # reserved token sums — the slot-vs-paged HBM-efficiency evidence
        # the traffic driver reports (bytes pinned per resident token)
        self.peak_live = 0
        self._resident_tok_sum = 0
        self._reserved_tok_sum = 0

        # prefix-cache accounting (stays zero when the cache is off):
        # hit tokens never re-prefill, so saved tokens == hit tokens and
        # saved dispatches is the per-request chunk-count difference
        self.prefix_lookups = 0
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.prefix_prompt_tokens = 0
        self.prefill_dispatches_saved = 0
        self.prefix_cow_clones = 0

    def _carried_state_bytes(self) -> int:
        """Per-request bytes of carried (non-KV) state — the scheduler
        prices this one extra interthread handoff per admission (the
        state row travels with the request, unlike pool-resident KV)."""
        caps = self.capabilities
        if caps is None or not caps.carried_state:
            return 0
        buf = self.kv.buffers
        total = sum(int(buf[name].nbytes) for name in caps.state_leaves
                    if isinstance(buf, dict) and name in buf)
        return total // max(1, self.kv.num_slots)

    @staticmethod
    def _fresh_state(S: int):
        return {
            "tok": jnp.zeros((S, 1, 1), jnp.int32),    # next input token
            "pos": jnp.full((S,), PARK_POS, jnp.int32),  # next decode pos
            "keys": jnp.zeros((S, 2), jnp.uint32),     # per-slot PRNG keys
            "temp": jnp.zeros((S,), jnp.float32),
        }

    @staticmethod
    def _advance_state(state, logits):
        """Shared decode tail of the slot and paged dispatches: sample
        each row with its own key chain and advance (tok, pos, keys).
        MUST stay one copy — a sampling fix applied to one layout only
        would silently diverge their token streams and break the
        slot-vs-paged parity CI asserts. logits (S, Vp)."""
        split = jax.vmap(jax.random.split)(state["keys"])      # (S, 2, 2)
        nxt = _sample_rows(logits, split[:, 1], state["temp"])
        return nxt, {"tok": nxt.reshape(-1, 1, 1),
                     "pos": state["pos"] + 1,
                     "keys": split[:, 0],
                     "temp": state["temp"]}

    @classmethod
    def _decode_impl(cls, model):
        vstep = jax.vmap(model.decode_step, in_axes=(None, 0, 0, 0))

        def fn(params, buf, state):
            logits, buf = vstep(params, buf, state["tok"],
                                state["pos"])            # logits (S, 1, Vp)
            nxt, state = cls._advance_state(state, logits[:, 0, :])
            return nxt, buf, state

        return fn

    @staticmethod
    def _admit_impl(state, logits, slot, key, temp, pos0):
        """Seed slot ``slot`` from the prefill logits: sample the first
        token with the request's own key, install (tok, pos, key, temp)."""
        key, sub = jax.random.split(key)
        tok0 = _sample_rows(logits, sub[None], temp[None])[0]
        # scatter-drop: slot-indexed writes carry explicit drop semantics
        # like every other slot scatter, so a bad index writes nothing
        # instead of clamping onto a live row
        state = {
            "tok": state["tok"].at[slot].set(tok0, mode="drop"),
            "pos": state["pos"].at[slot].set(pos0, mode="drop"),
            "keys": state["keys"].at[slot].set(key, mode="drop"),
            "temp": state["temp"].at[slot].set(temp, mode="drop"),
        }
        return state, tok0

    @staticmethod
    def _park_impl(state, slot):
        """Park a retired slot's position: its decode-vmap row keeps
        computing, but the drop-mode cache writes discard everything."""
        # scatter-drop: same drop discipline as the cache writes
        return {**state,
                "pos": state["pos"].at[slot].set(PARK_POS, mode="drop")}

    @staticmethod
    def _import_state_impl(state, slot, tok, pos, key, temp):
        """Install a *migrated* request's decode state at ``slot`` — the
        exact (tok, pos, key, temp) the source rank's finalize produced,
        no resampling (the first token was already drawn there; replaying
        the draw here would fork the request's PRNG chain)."""
        # scatter-drop: slot-indexed writes carry explicit drop semantics
        return {
            "tok": state["tok"].at[slot].set(tok, mode="drop"),
            "pos": state["pos"].at[slot].set(pos, mode="drop"),
            "keys": state["keys"].at[slot].set(key, mode="drop"),
            "temp": state["temp"].at[slot].set(temp, mode="drop"),
        }

    @staticmethod
    def _install_finalized_rows(state, logits, rows, fin_pos, keys, temps,
                                drop_row):
        """Shared chunked-prefill tail of the slot and paged dispatches:
        sample the first token of every chunk-row and install the decode
        state of rows whose prompt just completed (``fin_pos >= 0``) —
        exactly as monolithic admission would; non-final and padding rows
        aim at ``drop_row`` and write nothing. One copy for both layouts,
        for the same parity reason as :meth:`_advance_state`."""
        split = jax.vmap(jax.random.split)(keys)          # (P, 2, 2)
        tok0 = _sample_rows(logits, split[:, 1], temps)   # (P,)
        fin = fin_pos >= 0
        trow = jnp.where(fin, rows, drop_row)             # drop non-final
        state = {
            "tok": state["tok"].at[trow].set(
                tok0.reshape(-1, 1, 1), mode="drop"),
            "pos": state["pos"].at[trow].set(fin_pos, mode="drop"),
            "keys": state["keys"].at[trow].set(split[:, 0], mode="drop"),
            "temp": state["temp"].at[trow].set(temps, mode="drop"),
        }
        return state, tok0

    @classmethod
    def _chunk_impl(cls, model, num_slots):
        """One fused chunked-prefill dispatch over up to P chunk-rows from
        different requests: gather their slot rows, run the model's
        fixed-shape ``prefill_chunk`` vmapped across rows, scatter the
        rows back, then the shared finalize tail. Padding rows carry
        ``slots == num_slots``: the gather clamps and every write
        drops."""
        vchunk = jax.vmap(model.prefill_chunk, in_axes=(None, 0, 0, 0, 0))

        def fn(params, buf, state, tokens, slots, pos0, n_valid, fin_pos,
               keys, temps):
            rows = SlotKVCache.rows_at(buf, slots)
            logits, new_rows = vchunk(params, rows, tokens, pos0, n_valid)
            buf = SlotKVCache.rows_into(buf, new_rows, slots)
            state, tok0 = cls._install_finalized_rows(
                state, logits, slots, fin_pos, keys, temps, num_slots)
            return buf, state, tok0

        return fn

    @classmethod
    def _decode_impl_paged(cls, model):
        """One decode micro-step over the paged pool: the model's batched
        block-table decode (no outer vmap — the pool is one shared
        buffer), then the same in-jit sampling tail as the slot path."""
        def fn(params, buf, state, tables):
            logits, buf = model.decode_step_paged(
                params, buf, state["tok"][:, 0], state["pos"],
                tables)                                # logits (S, Vp)
            nxt, state = cls._advance_state(state, logits)
            return nxt, buf, state

        return fn

    @classmethod
    def _chunk_impl_paged(cls, model, num_slots):
        """One fused chunked-prefill dispatch through block tables: up to
        P chunk-rows write straight into the shared pool (the table IS
        the indirection — no slot-row gather/scatter), then the shared
        finalize tail. Padding rows carry an all ``-1`` table (writes
        drop) and ``rows == num_slots`` (state installs drop — for both
        the sampling state and the model's carried recurrent state, which
        the chunk step gathers/scatters at the same row indices)."""
        def fn(params, buf, state, tokens, rows, tables, pos0, n_valid,
               fin_pos, keys, temps):
            logits, buf = model.prefill_chunk_paged(
                params, buf, tokens, tables, rows, pos0, n_valid)
            state, tok0 = cls._install_finalized_rows(
                state, logits, rows, fin_pos, keys, temps, num_slots)
            return buf, state, tok0

        return fn

    @staticmethod
    def _spec_round_impl(model, draft_model, k):
        """One fused draft–verify round (DESIGN.md §14), everything on
        device — drafter resync, k-token autoregressive draft, the
        target's single (k+1)-query verify, and longest-matching-prefix
        acceptance — so the host pays ONE token sync per round (the
        spec-mode analogue of ``_decode_micro_step``'s one sync).

        Per live row: the drafter first *resyncs* — a fixed width-2
        teacher-forced dispatch consuming the ``u ∈ {1, 2}`` canonical
        tokens it has not seen (``u == 2`` exactly after a fully-accepted
        round; the emitted-token history lives on the host, so ``prev``/
        ``cur`` arrive as inputs) — whose last valid logits row yields
        draft 1; then ``k - 1`` single-token drafter decode steps extend
        the proposal. The target verifies ``[cur, d_1 .. d_k]`` in one
        fused dispatch; ``greedy[:, j]`` is its next-token choice after
        consuming tokens through ``j``, so the longest matching prefix
        plus the target's own token at the first mismatch reproduces
        sequential greedy decode token-for-token. Returned ``greedy`` is
        the emission buffer itself: tokens ``greedy[b, :n_emit[b]]`` are
        exactly what sequential decode would have produced.

        Rollback is structural: rejected draft rows (target pool) and
        unaccepted drafter rows sit at positions beyond the new canonical
        length — out of causal range (``kpos <= qpos``) for every later
        valid query until a later dispatch's drop-mode write overwrites
        them. Drafter steps beyond ``n_draft`` park their write position
        (``PARK_POS``): near the request's token budget the clamp
        ``n_draft < k`` would otherwise let a stale draft write overrun
        the row's block lease."""
        def fn(params, dparams, buf, dbuf, cur, prev, u, sync_pos, tpos,
               n_draft, tables, dtables):
            sync_tok = jnp.where((u == 2)[:, None],
                                 jnp.stack([prev, cur], axis=1),
                                 jnp.stack([cur, cur], axis=1))
            dlogits, dbuf = draft_model.verify_step_paged(
                dparams, dbuf, sync_tok, sync_pos, dtables, u)
            dnext = jnp.argmax(dlogits, -1).astype(jnp.int32)
            drafts = [jnp.take_along_axis(
                dnext, jnp.maximum(u - 1, 0)[:, None], axis=1)[:, 0]]
            base = sync_pos + u            # next drafter write position
            for j in range(k - 1):
                pos_j = jnp.where(j + 1 <= n_draft, base + j, PARK_POS)
                lg, dbuf = draft_model.decode_step_paged(
                    dparams, dbuf, drafts[-1][:, None], pos_j, dtables)
                drafts.append(jnp.argmax(lg, -1).astype(jnp.int32))
            drafts = jnp.stack(drafts, axis=1)                    # (S, k)
            vtok = jnp.concatenate([cur[:, None], drafts], axis=1)
            logits, buf = model.verify_step_paged(
                params, buf, vtok, tpos, tables, n_draft + 1)
            greedy = jnp.argmax(logits, -1).astype(jnp.int32)   # (S, k+1)
            match = ((drafts == greedy[:, :k])
                     & (jnp.arange(k)[None, :] < n_draft[:, None]))
            n_acc = jnp.sum(jnp.cumprod(match.astype(jnp.int32), 1), 1)
            return greedy, n_acc + 1, buf, dbuf

        return fn

    # -- request intake ----------------------------------------------------
    def submit(self, req: ServeRequest, now: float = 0.0) -> str:
        """Queue a request through the cell-queue scheduler. A paged
        request whose token budget can never fit its block-table is
        rejected here, at submit — not discovered as a crash in the
        admission gate once it reaches the queue head."""
        if self.speculate and req.temperature > 0.0:
            raise ValueError(
                f"request {req.rid}: speculative decoding verifies "
                "greedy token identity (longest-matching-prefix "
                "acceptance is exact for argmax only); temperature must "
                f"be 0, got {req.temperature}")
        if self.kv_layout == "paged":
            budget = self._token_budget(req)
            cap = self.admittable_tokens
            if budget > cap:
                # a prefill-rank lease is prompt-only; the message must
                # name the quantity actually rejected
                what = ("prompt" if self.role == "prefill"
                        else "prompt+max_new")
                fix = ("" if self.role == "prefill"
                       else " or lower max_new_tokens")
                raise ValueError(
                    f"request {req.rid}: {what} = {budget} tokens "
                    f"exceeds the admittable capacity {cap} (= min(table "
                    f"cap {self.kv.max_blocks_per_req}, pool "
                    f"{self.kv.pool.num_blocks}) blocks x "
                    f"{self.kv.block_size}); raise cache_len/num_blocks"
                    f"{fix}")
        return self.scheduler.submit(req, now)

    @property
    def admittable_tokens(self) -> int:
        """Largest token budget one request could ever lease here: a
        lease must fit BOTH caps, the per-request table and the whole
        pool — a request needing more blocks than exist would otherwise
        be accepted and livelock admission (head-of-line deferral that
        can never clear). Unbounded for the slot layout (ring
        recycling serves arbitrarily long decodes at fixed footprint)."""
        if self.kv_layout != "paged":
            return 2 ** 31 - 1
        return (min(self.kv.max_blocks_per_req, self.kv.pool.num_blocks)
                * self.kv.block_size)

    @property
    def num_active(self) -> int:
        return self.kv.num_live

    @property
    def num_prefilling(self) -> int:
        """Requests admitted to a slot but still streaming their prompt."""
        return len(self._prefilling)

    @property
    def num_decoding(self) -> int:
        return sum(r is not None for r in self._slot_req)

    @property
    def idle(self) -> bool:
        return self.kv.num_live == 0 and self.scheduler.num_waiting == 0

    # -- micro-step --------------------------------------------------------
    def step(self, now: float = 0.0) -> List[ServeRequest]:
        """One serving micro-step: deposit prompt material for up to
        ``max_prefill_per_step`` requests (one chunk-row each, fused into
        a single dispatch on the chunked path), then advance every
        decoding slot by one token. Returns the requests that finished
        this step."""
        tr = _tr_active()
        if tr is not None:
            # runnable-work hint for the serialization-stall detector:
            # rows + queued requests this engine could be advancing
            tr.set_runnable(self.kv.num_live + self.scheduler.num_waiting)
        finished: List[ServeRequest] = []
        if self.prefill_chunk:
            # admission keeps at most max_prefill_per_step prompts
            # in flight; each gets one chunk per micro-step, so decode
            # stalls are bounded by one chunk of prefill compute
            budget = min(self.kv.num_free,
                         self.max_prefill_per_step - len(self._prefilling))
            # paged: the second admission gate is the block pool — a
            # request is held back (head-of-line) until its whole token
            # budget (prompt + max_new) fits in free blocks. Admit one
            # request at a time so each lease is debited from the free
            # pool before the next candidate is gated. With the prefix
            # cache, only the miss tail needs fresh blocks: the gate
            # prices the hit so shared-prefix bursts admit earlier.
            can = ((lambda r: self.kv.can_admit(
                self._token_budget(r),
                hit=(self._prefix_lookup(r) if self.prefix_cache
                     is not None else None)))
                if self.kv_layout == "paged" else None)
            while budget > 0:
                admitted = self.scheduler.admit(now, 1, can_admit=can)
                if not admitted:
                    break
                req = admitted[0]
                if tr is None:
                    self._begin_prefill(req)
                else:
                    # the admission hop's wall-clock twin of the §3.2
                    # price stamped on the request (repriced to the
                    # prefix-hit model when the radix cache served it)
                    t0 = time.perf_counter()
                    self._begin_prefill(req)
                    tr.hop("prefix_hit" if req.prefix_hit_tokens > 0
                           else "admission", req.admit_cost_s, t0,
                           time.perf_counter(), rid=req.rid)
                budget -= 1
            if self._prefilling:
                if tr is None:
                    finished.extend(self._prefill_chunk_step(now))
                else:
                    nj = min(len(self._prefilling),
                             self.max_prefill_per_step)
                    t0 = time.perf_counter()
                    finished.extend(self._prefill_chunk_step(now))
                    tr.complete("prefill_chunk", t0, time.perf_counter(),
                                cat="engine", jobs=nj)
        else:
            n_admit = min(self.kv.num_free, self.max_prefill_per_step)
            for req in self.scheduler.admit(now, n_admit):
                if tr is None:
                    done = self._admit(req, now)
                else:
                    t0 = time.perf_counter()
                    done = self._admit(req, now)
                    tr.hop("admission", req.admit_cost_s, t0,
                           time.perf_counter(), rid=req.rid)
                if done is not None:
                    finished.append(done)
        if self.num_decoding:
            if tr is None:
                finished.extend(self._spec_micro_step(now)
                                if self.speculate
                                else self._decode_micro_step(now))
            elif self.speculate:
                t0 = time.perf_counter()
                finished.extend(self._spec_micro_step(now))
                tr.complete("spec_round", t0, time.perf_counter(),
                            cat="engine")
            else:
                rows = self.num_decoding
                t0 = time.perf_counter()
                finished.extend(self._decode_micro_step(now))
                tr.complete("decode", t0, time.perf_counter(),
                            cat="engine", rows=rows)
        self._account()
        return finished

    def _token_budget(self, req: ServeRequest) -> int:
        """Token capacity a request leases at admission: the prompt plus
        every token it may generate (no mid-decode block exhaustion). A
        prefill-rank engine leases the prompt only — the first generated
        token's KV (and every one after it) is written on the decode
        rank that receives the migrated blocks."""
        if self.role == "prefill":
            return req.prompt_len
        return req.prompt_len + req.max_new_tokens

    def _account(self) -> None:
        live = self.kv.num_live
        self.peak_live = max(self.peak_live, live)
        if live:
            self._resident_tok_sum += int(self.kv.lengths.sum())
            self._reserved_tok_sum += (
                self.kv.resident_capacity_tokens
                if self.kv_layout == "paged" else live * self.cache_len)

    def kv_accounting(self) -> dict:
        """Thin alias — the canonical schema lives in
        :func:`repro.obs.metrics.engine_kv_accounting` (DESIGN.md §15),
        so every stats surface is assembled in one place."""
        return obs_metrics.engine_kv_accounting(self)

    def prefix_stats(self) -> dict:
        """Thin alias — canonical schema:
        :func:`repro.obs.metrics.engine_prefix_stats`."""
        return obs_metrics.engine_prefix_stats(self)

    @property
    def decode_tokens_per_dispatch(self) -> float:
        """Tokens one decode dispatch yields on this engine: 1.0 without
        speculation; with it, the observed mean accepted-per-dispatch
        (or the ``(k + 2) / 2`` uniform-acceptance prior before any
        round has run). The fabric's placement cost model divides decode
        dispatch counts by this — a hardcoded one-token-per-dispatch
        assumption would systematically overprice speculative ranks."""
        if not self.speculate:
            return 1.0
        sch = self.scheduler
        if sch.n_spec_dispatches:
            return sch.spec_accepted_tokens / sch.n_spec_dispatches
        return (self.speculate + 2) / 2

    def spec_stats(self) -> dict:
        """Thin alias — canonical schema:
        :func:`repro.obs.metrics.engine_spec_stats`."""
        return obs_metrics.engine_spec_stats(self)

    # -- chunked prompt deposit (rendezvous-style streaming) ---------------
    def _begin_prefill(self, req: ServeRequest) -> None:
        """Claim a slot (or lease blocks + a request row) and enter the
        ``prefilling`` state: the prompt will stream in chunk by chunk
        across micro-steps."""
        resident = 0
        if self.kv_layout == "paged":
            # no blanking needed: paged masking is structural (a stale
            # page of a block's previous owner is never at a position
            # <= qpos of the new owner)
            if self.prefix_cache is not None:
                slot, resident = self._admit_with_prefix(req)
            else:
                slot = self.kv.alloc(req, self._token_budget(req))
            if self.speculate:
                # lockstep lease: the drafter's pool mirrors every
                # alloc/free, so both pools always hand out the same row
                dslot = self.draft_kv.alloc(req, self._token_budget(req))
                if dslot != slot:
                    raise SlotError(
                        f"drafter row {dslot} diverged from target row "
                        f"{slot} for request {req.rid}: the pools' "
                        "alloc/free lockstep broke")
            if self._encode is not None:
                # enc-dec: the fixed encoder pre-chunk — install this
                # request's cross K/V carried state into its row before
                # the decoder prompt starts streaming
                buf = self._encode(self.params, self.kv.buffers,
                                   jnp.asarray(req.batch["frames"]),
                                   jnp.full((1,), slot, jnp.int32))
                self.kv.swap_buffers(self._prefill_stream.ordered(buf))
        else:
            slot = self.kv.alloc(req)
            self.kv.reset_slot(slot)   # stale pages must not alias history
        req.state = "prefilling"
        tokens = np.asarray(req.batch["tokens"][0], np.int32)
        key = jax.random.fold_in(jax.random.PRNGKey(req.seed), req.rid)
        self._prefilling.append(_PrefillJob(req=req, slot=slot,
                                            tokens=tokens, key=key,
                                            off=resident))

    def _prefix_lookup(self, req: ServeRequest):
        """Longest cached prefix of the prompt, clamped one token short
        of the full length: the final chunk always re-prefills, so its
        last-position logits exist to seed decode."""
        tokens = np.asarray(req.batch["tokens"][0], np.int32)
        return self.prefix_cache.lookup(tokens, limit=len(tokens) - 1)

    def _admit_with_prefix(self, req: ServeRequest):
        """Paged admission through the radix cache: lease every hit
        block at refcount+1, allocate fresh blocks for the miss tail
        only, clone the divergent block for a partial (CoW) hit, and
        start chunked prefill at the first miss offset. Returns
        ``(slot, resident)`` — resident tokens never re-prefill."""
        hit = self._prefix_lookup(req)
        slot = self.kv.alloc_prefix(req, self._token_budget(req), hit,
                                    self.prefix_cache)
        resident = hit.tokens
        if hit.cow_src is not None:
            # copy-on-write: duplicate the shared block's pages into the
            # request's first fresh (private) block on device, then drop
            # the temporary source reference — the request resumes its
            # chunked deposit mid-block and overwrites only the
            # divergent tail, never touching the shared source
            dst = self.kv.blocks_of(slot)[len(hit.blocks)]
            buf = self._cow_clone(self.kv.buffers, jnp.int32(hit.cow_src),
                                  jnp.int32(dst))
            self.kv.swap_buffers(self._prefill_stream.ordered(buf))
            self.prefix_cache.release_cow(hit.cow_src)
            resident += hit.cow_tokens
            self.prefix_cow_clones += 1
        if resident:
            self.kv.advance(slot, resident)
        plen = req.prompt_len
        self.prefix_lookups += 1
        self.prefix_prompt_tokens += plen
        if resident:
            C = self.prefill_chunk
            self.prefix_hits += 1
            self.prefix_hit_tokens += resident
            self.prefill_dispatches_saved += (
                -(-plen // C) - -(-(plen - resident) // C))
            req.prefix_hit_tokens = resident
            self.scheduler.reprice_prefix(
                req, resident, cow_blocks=int(hit.cow_src is not None))
        return slot, resident

    def _prefill_chunk_step(self, now: float) -> List[ServeRequest]:
        """One fused dispatch: the next chunk of up to
        ``max_prefill_per_step`` prefilling requests, batched row-wise at
        fixed shapes (shorter tails padded + masked, absent rows aimed at
        the drop slot)."""
        P, C = self.max_prefill_per_step, self.prefill_chunk
        S = self.kv.num_slots
        jobs = list(self._prefilling)[:P]
        tok = np.zeros((P, C), np.int32)
        slots = np.full((P,), S, np.int32)         # S = drop row
        pos0 = np.zeros((P,), np.int32)
        n_valid = np.zeros((P,), np.int32)
        fin_pos = np.full((P,), -1, np.int32)
        temps = np.zeros((P,), np.float32)
        keys = np.zeros((P, 2), np.uint32)
        for i, job in enumerate(jobs):
            n = min(C, len(job.tokens) - job.off)
            tok[i, :n] = job.tokens[job.off:job.off + n]
            slots[i] = job.slot
            pos0[i] = job.off
            n_valid[i] = n
            if job.off + n >= len(job.tokens):
                fin_pos[i] = len(job.tokens)       # next decode position
            temps[i] = job.req.temperature
            keys[i] = np.asarray(job.key, np.uint32)
            job.req.prefill_chunks += 1
        if self.kv_layout == "paged":
            # rows double as state-install targets (S = drop row); the
            # block tables are the write indirection — padding rows carry
            # all -1 tables, so every pool write drops
            buf, state, tok0 = self._chunk(
                self.params, self.kv.buffers, self._state, jnp.asarray(tok),
                jnp.asarray(slots), jnp.asarray(self.kv.table_rows(slots)),
                jnp.asarray(pos0), jnp.asarray(n_valid),
                jnp.asarray(fin_pos), jnp.asarray(keys), jnp.asarray(temps))
        else:
            buf, state, tok0 = self._chunk(
                self.params, self.kv.buffers, self._state, jnp.asarray(tok),
                jnp.asarray(slots), jnp.asarray(pos0), jnp.asarray(n_valid),
                jnp.asarray(fin_pos), jnp.asarray(keys), jnp.asarray(temps))
        self.kv.swap_buffers(self._prefill_stream.ordered(buf))
        self._state = state
        if self.kv_layout == "paged" and self.speculate:
            # mirror the prompt chunk into the drafter's pool: same
            # tokens/offsets, the drafter's own tables (its rows were
            # leased in lockstep at _begin_prefill)
            dbuf = self._draft_chunk(
                self.draft_params, self.draft_kv.buffers,
                jnp.asarray(tok),
                jnp.asarray(self.draft_kv.table_rows(slots)),
                jnp.asarray(slots), jnp.asarray(pos0),
                jnp.asarray(n_valid))
            self.draft_kv.swap_buffers(self._draft_stream.ordered(dbuf))

        finished: List[ServeRequest] = []
        tok0_np = None
        for i, job in enumerate(jobs):
            job.off += int(n_valid[i])
            self.kv.advance(job.slot, int(n_valid[i]))  # pages appended
            if fin_pos[i] < 0:
                continue
            if self.speculate:
                # drafter now holds the full prompt; emitted tokens are
                # what each round's resync dispatch will feed it
                self._draft_len[job.slot] = len(job.tokens)
            if tok0_np is None:       # host sync only when a prompt completes
                tok0_np = np.asarray(tok0)
            self._prefilling.remove(job)
            if self.prefix_cache is not None:
                # index the finished prompt's full blocks now — before
                # the request can finish immediately (EOS first token)
                # and free them down to parked
                self.prefix_cache.insert(job.tokens,
                                         self.kv.blocks_of(job.slot))
            done = self._install_first_token(job.slot, job.req,
                                             int(tok0_np[i]), now)
            if done is not None:
                finished.append(done)
        return finished

    def _admit(self, req: ServeRequest, now: float) -> Optional[ServeRequest]:
        """Prefill one request into a freshly allocated slot. Returns the
        request if it finished immediately (EOS on the first token /
        max_new == 1), else None."""
        batch = {k: jnp.asarray(v) for k, v in req.batch.items()}
        logits, cache = self._prefill(self.params, batch)
        cache = self._prefill_stream.ordered(cache)

        slot = self.kv.alloc(req)
        prompt_len = req.prompt_len
        if self.model.cfg.frontend == "patch_stub":
            prompt_len += self.model.cfg.num_frontend_tokens
        self.kv.insert(slot, cache, length=prompt_len)

        key = jax.random.fold_in(jax.random.PRNGKey(req.seed), req.rid)
        self._state, tok0_dev = self._admit_state(
            self._state, logits, jnp.int32(slot), key,
            jnp.float32(req.temperature), jnp.int32(prompt_len))
        tok0 = int(np.asarray(tok0_dev))
        return self._install_first_token(slot, req, tok0, now)

    def _install_first_token(self, slot: int, req: ServeRequest, tok0: int,
                             now: float) -> Optional[ServeRequest]:
        """Record a freshly-admitted request's first sampled token and
        either finish it immediately (EOS first token / max_new == 1) or
        enter decoding. Shared by monolithic admission and the final
        chunk of a chunked deposit. Returns the request iff finished."""
        req.first_token_time = now
        req.state = "decoding"
        fill = self.eos_id if self.eos_id >= 0 else 0
        out = np.full((req.max_new_tokens,), fill, np.int32)
        out[0] = tok0
        req.generated = 1
        if (0 <= self.eos_id == tok0) or req.max_new_tokens == 1:
            return self._finish(slot, req, out, now)
        if self.role == "prefill":
            # disaggregated fabric: the request does NOT enter this
            # engine's decode pool (never setting _slot_req keeps
            # num_decoding at 0, so no decode dispatch can advance the
            # held state before the transport ships it)
            req.state = "migrating"
            self.ready_handoffs.append(KVHandoff(
                req=req, slot=slot, out=out, length=self.kv.length(slot),
                blocks=self.kv.blocks_of(slot)))
            return None
        self._slot_req[slot] = req
        self._slot_out[slot] = out
        return None

    def _decode_micro_step(self, now: float) -> List[ServeRequest]:
        state = self._decode_stream.ordered(self._state)
        if self.kv_layout == "paged":
            nxt, buf, state = self._decode(self.params, self.kv.buffers,
                                           state, self.kv.tables_device())
        else:
            nxt, buf, state = self._decode(self.params, self.kv.buffers,
                                           state)
        self.kv.swap_buffers(buf)
        self._state = state
        nxt_np = np.asarray(nxt)        # the one host sync per micro-step

        finished: List[ServeRequest] = []
        for slot in self.kv.live_slots:
            req = self._slot_req[slot]
            if req is None:        # slot still mid-prefill: nothing to read
                continue
            t = int(nxt_np[slot])
            out = self._slot_out[slot]
            out[req.generated] = t
            req.generated += 1
            self.kv.advance(slot)
            if (0 <= self.eos_id == t) \
                    or req.generated >= req.max_new_tokens:
                finished.append(self._finish(slot, req, out, now))
                self._slot_req[slot] = None
                self._slot_out[slot] = None
        return finished

    def _spec_micro_step(self, now: float) -> List[ServeRequest]:
        """Spec-mode decode micro-step: ONE fused draft–verify round over
        every decoding row (``_spec_round_impl``) replaces up to ``k+1``
        single-token dispatches. The host builds the round's inputs from
        its own bookkeeping (emitted tokens, canonical lengths, drafter
        coverage), dispatches, then syncs the emission buffer once.

        Per row: ``tpos`` (the target's next write position) is the
        row's canonical resident length ``P + g - 1``; the canonical
        context is one token longer (the pending token ``cur``); the
        drafter has consumed ``u = canon - draft_len ∈ {1, 2}`` fewer
        tokens. ``n_draft`` clamps to ``remaining - 1`` so the budget is
        never overdrawn — at ``remaining == 1`` the round degenerates to
        a plain (teacher-forced width-1) decode of the same fixed
        shape."""
        k = self.speculate
        S = self.kv.num_slots
        cur = np.zeros((S,), np.int32)
        prev = np.zeros((S,), np.int32)
        u = np.ones((S,), np.int32)
        sync_pos = np.full((S,), PARK_POS, np.int32)
        tpos = np.full((S,), PARK_POS, np.int32)
        n_draft = np.zeros((S,), np.int32)
        live: List[int] = []
        for slot in self.kv.live_slots:
            req = self._slot_req[slot]
            if req is None:        # slot still mid-prefill: parked row
                continue
            g = req.generated
            out = self._slot_out[slot]
            cur[slot] = out[g - 1]
            prev[slot] = out[g - 2] if g >= 2 else out[g - 1]
            canon = self.kv.length(slot) + 1   # resident + pending token
            uu = canon - int(self._draft_len[slot])
            u[slot] = uu
            sync_pos[slot] = canon - uu
            tpos[slot] = canon - 1
            n_draft[slot] = min(k, req.max_new_tokens - g - 1)
            live.append(slot)
        tr = _tr_active()
        t_disp = time.perf_counter() if tr is not None else 0.0
        greedy, n_emit, buf, dbuf = self._spec_round(
            self.params, self.draft_params, self.kv.buffers,
            self.draft_kv.buffers, jnp.asarray(cur), jnp.asarray(prev),
            jnp.asarray(u), jnp.asarray(sync_pos), jnp.asarray(tpos),
            jnp.asarray(n_draft), self.kv.tables_device(),
            self.draft_kv.tables_device())
        self.kv.swap_buffers(self._verify_stream.ordered(buf))
        self.draft_kv.swap_buffers(self._draft_stream.ordered(dbuf))
        greedy_np = np.asarray(greedy)     # the one host sync per round
        n_emit_np = np.asarray(n_emit)

        cost = protocol.speculative_verify_latency(k)
        if tr is not None:
            # the round's modeled price is per live row; the measured
            # twin is the fused dispatch + its one host sync
            tr.hop("spec_verify", cost * max(1, len(live)), t_disp,
                   time.perf_counter(), rows=len(live), k=k)
        finished: List[ServeRequest] = []
        for slot in live:
            req = self._slot_req[slot]
            out = self._slot_out[slot]
            g = req.generated
            ne = int(n_emit_np[slot])
            em = greedy_np[slot, :ne]
            keep = ne
            if self.eos_id >= 0:
                hits = np.nonzero(em == self.eos_id)[0]
                if hits.size:                  # truncate at first EOS —
                    keep = int(hits[0]) + 1    # post-EOS columns stay
            out[g:g + keep] = em[:keep]        # eos/0-filled
            req.generated = g + keep
            # drafter coverage after this round: the resync + draft
            # steps deposited through position canon + min(n_acc, k) - 1,
            # of which min(n_acc, k-1) past-canon rows are canonical
            canon = self.kv.length(slot) + 1
            self._draft_len[slot] = canon + min(ne - 1, k - 1)
            self.kv.advance(slot, keep)        # accepted rows only: the
            # stale draft rows beyond stay structurally rolled back
            self.scheduler.record_spec_dispatch(
                keep, int(n_draft[slot]), ne - 1, cost)
            if (self.eos_id >= 0 and em[keep - 1] == self.eos_id) \
                    or req.generated >= req.max_new_tokens:
                finished.append(self._finish(slot, req, out, now))
                self._slot_req[slot] = None
                self._slot_out[slot] = None
        return finished

    def _finish(self, slot: int, req: ServeRequest, out: np.ndarray,
                now: float) -> ServeRequest:
        req.output = out
        self.kv.free(slot)
        if self.speculate:
            self.draft_kv.free(slot)       # lockstep with the target row
            self._draft_len[slot] = 0
        # park the freed slot's device position so its decode-vmap row
        # stops writing (stale-slot advance was silently corrupting
        # engine reuse before)
        self._state = self._park_state(self._state, jnp.int32(slot))
        self.scheduler.record_finish(req, now)
        return req

    # -- disaggregated KV handoff (fabric transport surface; paged only) ---
    def take_handoffs(self) -> List[KVHandoff]:
        """Drain the prefill-complete requests awaiting migration. The
        caller (the fabric's transport hop) owns getting each one to a
        decode rank and then calling :meth:`release_handoff` — until
        then this engine keeps the source blocks leased."""
        out, self.ready_handoffs = self.ready_handoffs, []
        return out

    def handoff_state(self, slot: int):
        """The per-request decode-state row migrating with the KV: the
        device-resident (tok, pos, keys, temp) the finalize tail
        installed (pos = prompt_len, tok = the first sampled token,
        keys = the request's advanced PRNG chain)."""
        return {k: self._state[k][slot] for k in
                ("tok", "pos", "keys", "temp")}

    def release_handoff(self, slot: int) -> None:
        """Migration complete: return the source row + blocks to the
        local pools and park the row's device state."""
        self.kv.free(slot)
        self._state = self._park_state(self._state, jnp.int32(slot))

    def begin_import(self, req: ServeRequest):
        """Decode-rank half of the handoff, part 1: claim a request row
        and lease blocks for the request's FULL budget (prompt +
        max_new) *before* the transport copies — the lease is the posted
        receive of the rendezvous discipline. Returns ``(slot,
        dst_blocks)``; the transport writes the migrated prompt KV into
        the first ``blocks_for(prompt_len)`` of ``dst_blocks``."""
        if self.kv_layout != "paged":
            raise ValueError("KV-block import needs kv_layout='paged'")
        slot = self.kv.alloc(req, req.prompt_len + req.max_new_tokens)
        return slot, self.kv.blocks_of(slot)

    def finish_import(self, slot: int, handoff: KVHandoff, state_row,
                      now: float) -> None:
        """Decode-rank half, part 2 (after the transport's waitall):
        install the migrated decode state at ``slot`` and enter the
        request into this engine's decode pool, continuing exactly where
        the prefill rank stopped (generated == 1, next position ==
        prompt_len)."""
        req = handoff.req
        self.kv.advance(slot, handoff.length)    # resident prompt tokens
        self._state = self._import_state(
            self._state, jnp.int32(slot), state_row["tok"],
            state_row["pos"], state_row["keys"], state_row["temp"])
        req.state = "decoding"
        self._slot_req[slot] = req
        self._slot_out[slot] = handoff.out

    def reset(self, *, strict: bool = False,
              preserve_prefix: bool = False) -> None:
        """Return the engine to its post-construction state: every slot
        freed, device-side sampling/position state re-zeroed (positions
        parked), scheduler queues and accounting cleared. Used by traffic
        drivers after jit warm-up so warm requests leave no stale device
        state or accounting behind (compiled programs are kept).

        ``preserve_prefix=True`` (prefix cache only) keeps the parked
        radix index and the device pool content across the reset — the
        warm-cache trial: rows, counters and scheduler state clear, the
        cache stays populated.

        Slots still holding requests are lease leaks: named via
        ``LeaseLeakWarning``, or ``LeaseLeakError`` when ``strict``."""
        S = self.kv.num_slots
        self._state = self._fresh_state(S)
        self._slot_req = [None] * S
        self._slot_out = [None] * S
        self._prefilling.clear()
        self.ready_handoffs.clear()
        if self.prefix_cache is not None and preserve_prefix:
            self.kv.reset_rows(strict=strict)
        else:
            if self.prefix_cache is not None:
                # drop the cache's references first: parked blocks are
                # retention by design, not leaks for the pool to name
                self.prefix_cache.clear()
            self.kv.reset(strict=strict)
        if self.speculate:
            self.draft_kv.reset(strict=strict)
            self._draft_len[:] = 0
        self.scheduler.reset()
        self.peak_live = 0
        self._resident_tok_sum = 0
        self._reserved_tok_sum = 0
        self.prefix_lookups = self.prefix_hits = 0
        self.prefix_hit_tokens = self.prefix_prompt_tokens = 0
        self.prefill_dispatches_saved = self.prefix_cow_clones = 0
        if self.prefix_cache is not None:
            self.prefix_cache.reset_stats()
        # the telemetry subsystem is trial-scoped too: residual pairs and
        # push-registry observations recorded during warm-up (compile-
        # dominated, wildly off-model) must not aggregate into the
        # measured trial — the PR 5 req_log aliasing class, one layer up.
        # Applies to BOTH reset flavors: preserve_prefix=True keeps the
        # radix index warm but the trial's measurements still restart.
        _obs_flush_trial()

    # -- batch-API convenience (parity with StaticEngine.generate) --------
    def generate(self, batch, max_new_tokens: int, *,
                 temperature: float = 0.0, seed: int = 0) -> np.ndarray:
        """Same-arrival batch through the continuous path: split the batch
        into per-row requests, run micro-steps until drained, reassemble
        (B, max_new) in row order."""
        B = batch["tokens"].shape[0]
        reqs = []
        for i in range(B):
            row = {k: np.asarray(v[i:i + 1]) for k, v in batch.items()}
            req = ServeRequest(rid=i, batch=row,
                               max_new_tokens=max_new_tokens,
                               temperature=temperature, seed=seed)
            reqs.append(req)
            self.submit(req, 0.0)
        steps = 0
        chunk_steps = (sum(-(-r.prompt_len // self.prefill_chunk) + 1
                           for r in reqs) if self.prefill_chunk else B)
        limit = (B * (max_new_tokens + 2)) // max(1, self.kv.num_slots) \
            + B * (max_new_tokens + 2) + chunk_steps
        while not self.idle:
            self.step(0.0)
            steps += 1
            if steps > limit:
                raise RuntimeError("continuous generate failed to drain")
        return np.stack([r.output for r in reqs])
