"""Gemma-2B [arXiv:2403.08295] — dense, MQA (kv=1), head_dim=256, GeGLU,
18L, d_model=2048, d_ff=16384, vocab=256000. Gemma details: sqrt(d_model)
embedding scale, (1+w) RMSNorm, tied embeddings."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    block="dense",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rmsnorm_unit_offset=True,
    norm_eps=1e-6,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma-smoke",
    family="dense",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    head_dim=32,
    d_ff=128,
    vocab_size=256,
    mlp_act="geglu",
    tie_embeddings=True,
    embed_scale=True,
    rmsnorm_unit_offset=True,
    norm_eps=1e-6,
)
