"""Architecture registry: one module per assigned architecture.

Each module exports ``CONFIG`` (the exact published config) and
``SMOKE_CONFIG`` (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib
from typing import Dict

from repro.config import ModelConfig

_ARCH_MODULES = {
    "hymba-1.5b": "hymba_1p5b",
    "internvl2-76b": "internvl2_76b",
    "dbrx-132b": "dbrx_132b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "gemma-2b": "gemma_2b",
    "qwen3-14b": "qwen3_14b",
    "qwen2.5-14b": "qwen2p5_14b",
    "yi-9b": "yi_9b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-370m": "mamba2_370m",
}

ARCH_NAMES = tuple(_ARCH_MODULES)


def _load(arch: str):
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _load(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _load(arch).SMOKE_CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_NAMES}
