"""Qwen2.5-14B [hf:Qwen/Qwen2.5-*] — dense GQA with QKV bias.
48L, d_model=5120, 40 heads (kv=8), d_ff=13824, vocab=152064."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    block="dense",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=13824,
    vocab_size=152064,
    qkv_bias=True,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen2.5-smoke",
    family="dense",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qkv_bias=True,
    mlp_act="swiglu",
    norm_eps=1e-6,
)
