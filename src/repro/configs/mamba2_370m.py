"""Mamba2-370M [arXiv:2405.21060] — attention-free SSD (state-space duality).
48L, d_model=1024, d_inner=2048 (32 heads x head_dim 64), ssm_state=128,
vocab=50280."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-370m",
    family="ssm",
    block="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_d_inner=2048,
    ssm_head_dim=64,
    ssm_conv=4,
    tie_embeddings=True,
    pos_embed="none",
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    block="ssm",
    num_layers=2,
    d_model=64,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=256,
    ssm_state=16,
    ssm_d_inner=128,
    ssm_head_dim=32,
    ssm_conv=4,
    ssm_chunk=8,
    tie_embeddings=True,
    pos_embed="none",
)
