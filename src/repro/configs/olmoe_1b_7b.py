"""OLMoE-1B-7B [arXiv:2409.02060] — 64 experts, top-8, MHA with QK-norm.
16L, d_model=2048, 16 heads (kv=16), per-expert d_ff=1024, vocab=50304."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    block="moe",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=1024,
    vocab_size=50304,
    qk_norm=True,
    mlp_act="swiglu",
    num_experts=64,
    top_k=8,
)

SMOKE_CONFIG = ModelConfig(
    name="olmoe-smoke",
    family="moe",
    block="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=32,
    vocab_size=256,
    qk_norm=True,
    mlp_act="swiglu",
    num_experts=8,
    top_k=2,
    moe_group_size=32,
)
