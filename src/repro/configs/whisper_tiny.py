"""Whisper-tiny [arXiv:2212.04356] — encoder-decoder audio transformer.
4L enc + 4L dec, d_model=384, 6 heads (kv=6), d_ff=1536, vocab=51865.
Conv mel frontend is a STUB: ``input_specs`` provides precomputed frame
embeddings (B, 1500, d_model). LayerNorm + ungated GELU MLP, learned
positional embeddings on the decoder, sinusoidal on the encoder."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    block="dense",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    mlp_act="gelu",
    norm_type="layernorm",
    is_encoder_decoder=True,
    num_encoder_layers=4,
    encoder_seq=1500,
    frontend="audio_stub",
    pos_embed="learned",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_act="gelu",
    norm_type="layernorm",
    is_encoder_decoder=True,
    num_encoder_layers=2,
    encoder_seq=32,
    frontend="audio_stub",
    pos_embed="learned",
)
