"""InternVL2-Llama3-76B [arXiv:2404.16821] — VLM: InternViT-6B frontend (STUB:
``input_specs`` provides precomputed patch embeddings) + Llama-3-70B-class LM
backbone: 80L, d_model=8192, 64 heads (GQA kv=8), d_ff=28672, vocab=128256."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    block="dense",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    mlp_act="swiglu",
    rope_theta=500_000.0,
    frontend="patch_stub",
    num_frontend_tokens=256,   # one image tile worth of projected patch tokens
)

SMOKE_CONFIG = ModelConfig(
    name="internvl2-smoke",
    family="vlm",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_act="swiglu",
    frontend="patch_stub",
    num_frontend_tokens=8,
)
