"""Hymba-1.5B [arXiv:2411.13676] — hybrid-head: parallel attention + Mamba(SSD)
heads in every block. 32L, d_model=1600, 25 heads (GQA kv=5), d_ff=5504,
vocab=32001, ssm_state=16. Sliding-window attention everywhere except three
global layers (first / middle / last), per the Hymba paper."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    block="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    swa_window=2048,
    global_layers=(0, 15, 31),
    mlp_act="swiglu",
    ssm_state=16,
    ssm_d_inner=3200,     # 2x expansion
    ssm_head_dim=64,      # 50 SSM heads
    ssm_conv=4,
    rope_theta=10_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="hymba-smoke",
    family="hybrid",
    block="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    swa_window=16,
    global_layers=(0,),
    mlp_act="swiglu",
    ssm_state=8,
    ssm_d_inner=128,
    ssm_head_dim=32,
    ssm_conv=4,
    ssm_chunk=8,
)
