"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE: 16 experts, top-4.
40L, d_model=6144, 48 heads (GQA kv=8), per-expert d_ff=10752, vocab=100352."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    block="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    mlp_act="swiglu",
    num_experts=16,
    top_k=4,
    rope_theta=500_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="dbrx-smoke",
    family="moe",
    block="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=96,
    vocab_size=256,
    mlp_act="swiglu",
    num_experts=4,
    top_k=2,
    moe_group_size=32,
)
