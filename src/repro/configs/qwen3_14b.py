"""Qwen3-14B [hf:Qwen/Qwen3-*] — dense GQA with QK-norm, no biases.
40L, d_model=5120, 40 heads (kv=8), d_ff=17408, vocab=151936."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    block="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab_size=151936,
    qk_norm=True,
    mlp_act="swiglu",
    rope_theta=1_000_000.0,
    norm_eps=1e-6,
)

SMOKE_CONFIG = ModelConfig(
    name="qwen3-smoke",
    family="dense",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    qk_norm=True,
    mlp_act="swiglu",
    norm_eps=1e-6,
)
