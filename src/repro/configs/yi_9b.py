"""Yi-9B [arXiv:2403.04652] — llama-arch dense GQA.
48L, d_model=4096, 32 heads (kv=4), d_ff=11008, vocab=64000."""

from repro.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    block="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64000,
    mlp_act="swiglu",
    rope_theta=5_000_000.0,
)

SMOKE_CONFIG = ModelConfig(
    name="yi-smoke",
    family="dense",
    block="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    mlp_act="swiglu",
)
