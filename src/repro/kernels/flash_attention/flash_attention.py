"""Blocked online-softmax attention (flash attention) for TPU.

Grid (B, H, nq, nk) — the kv index is minor-most so TPU grid iteration
visits all kv blocks of one (b, h, iq) consecutively; running max / sum /
accumulator live in VMEM scratch across those steps and the output block is
emitted at the last kv step. MXU-friendly block shapes (multiples of 128 on
the contracting dims at production sizes; the interpret-mode tests also
sweep smaller shapes).

GQA is free: the k/v BlockSpec index_map folds the query head onto its kv
group (h -> h * Hkv // H), so kv heads are never materialized per q head.
Causal + sliding-window masking via absolute positions (q_offset supports
decode/prefill-continuation).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, q_offset: int,
                  block_q: int, block_k: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0]                                   # (bq, hd)
    k = k_ref[0, 0]                                   # (bk, hd)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (bq, bk)

    iq = pl.program_id(2)
    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ik == nk - 1)
    def _emit():
        o_ref[0, 0] = (acc_scr[...]
                       / jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention_fwd(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, block_q: int = 128,
                        block_k: int = 128, interpret: bool = True):
    """q: (B, H, Sq, hd); k, v: (B, Hkv, Sk, hd) with H % Hkv == 0.
    Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0, (H, Hkv)
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    nq, nk = Sq // bq, Sk // bk
    scale = 1.0 / math.sqrt(hd)
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, block_q=bq, block_k=bk, nk=nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, Hkv_=Hkv, H_=H:
                         (b, h * Hkv_ // H_, ik, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b, h, iq, ik, Hkv_=Hkv, H_=H:
                         (b, h * Hkv_ // H_, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
