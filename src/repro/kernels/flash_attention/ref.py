"""Pure-jnp oracle for the flash attention kernel."""

import math

import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q: (B,H,Sq,hd); k,v: (B,Hkv,Sk,hd). Dense softmax reference."""
    B, H, Sq, hd = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    if Hkv != H:
        k = jnp.repeat(k, H // Hkv, axis=1)
        v = jnp.repeat(v, H // Hkv, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    q_pos = q_offset + jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= k_pos <= q_pos
    if window > 0:
        ok &= k_pos > q_pos - window
    s = jnp.where(ok, s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)
