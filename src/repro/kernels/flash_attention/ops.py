"""jit'd public wrapper for flash attention (layout adapter + dispatch).

Models hold (B, S, H, hd); the kernel wants (B, H, S, hd). On TPU set
interpret=False; interpret=True executes the kernel body in python on CPU
for validation (this container).
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.flash_attention.flash_attention import flash_attention_fwd


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "block_q", "block_k",
                     "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128, interpret=True):
    """q: (B, Sq, H, hd); k, v: (B, Sk, Hkv, hd) -> (B, Sq, H, hd)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_fwd(qt, kt, vt, causal=causal, window=window,
                              q_offset=q_offset, block_q=block_q,
                              block_k=block_k, interpret=interpret)
    return out.transpose(0, 2, 1, 3)
