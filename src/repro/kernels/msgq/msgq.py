"""Cell-queue message copy kernels (paper §3.2, TPU adaptation).

The paper's interthread messaging moves a message through a bounded pool of
fixed-size shared-memory cells (eager, 2 copies) or directly from the sender
buffer (1-copy). The TPU analogue (DESIGN.md §2): the cell pool becomes a
bounded VMEM staging buffer and the 1-copy path a direct HBM→HBM block DMA.
The lockless-MPSC atomics do not transfer — Pallas grids are scheduled, not
racing — but the protocol structure (bounded cells / staging vs direct) and
its bandwidth consequences do.

Kernels:
  * eager_kernel:    per-cell staged copy through a VMEM scratch cell
                     (explicit second copy: src→cell, cell→dst).
  * one_copy_kernel: direct block copy, no staging scratch.
Both use explicit BlockSpec tiling; one cell/block per grid step.
"""

from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _eager_kernel(src_ref, dst_ref, cell_ref):
    # copy 1: message fragment -> staging cell (the shared-memory cell)
    cell_ref[...] = src_ref[...]
    # copy 2: cell -> receiver buffer (receiver consumes the cell)
    dst_ref[...] = cell_ref[...]


def _one_copy_kernel(src_ref, dst_ref):
    # receiver copies directly from the sender buffer (shared address space)
    dst_ref[...] = src_ref[...]


@functools.partial(jax.jit, static_argnames=("cell_elems", "interpret"))
def eager_copy(msg: jax.Array, *, cell_elems: int = 2048,
               interpret: bool = True) -> jax.Array:
    """Eager-protocol copy: message staged through one reused VMEM cell
    (the bounded cell pool). msg: 1-D, length multiple of cell_elems
    (ops.py pads)."""
    (n,) = msg.shape
    assert n % cell_elems == 0, (n, cell_elems)
    ncells = n // cell_elems
    return pl.pallas_call(
        _eager_kernel,
        grid=(ncells,),
        in_specs=[pl.BlockSpec((cell_elems,), lambda i: (i,))],
        out_specs=pl.BlockSpec((cell_elems,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(msg.shape, msg.dtype),
        scratch_shapes=[pltpu.VMEM((cell_elems,), msg.dtype)],
        interpret=interpret,
    )(msg)


@functools.partial(jax.jit, static_argnames=("block_elems", "interpret"))
def one_copy(msg: jax.Array, *, block_elems: int = 65536,
             interpret: bool = True) -> jax.Array:
    """1-copy protocol: direct blocked DMA, no staging."""
    (n,) = msg.shape
    block = min(block_elems, n)
    assert n % block == 0, (n, block)
    nblocks = n // block
    return pl.pallas_call(
        _one_copy_kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(msg.shape, msg.dtype),
        interpret=interpret,
    )(msg)
