"""jit'd wrapper + protocol dispatch for the msgq kernels.

Selects eager (VMEM-staged, 2 copies) vs 1-copy (direct) by message size,
using the paper's interthread threshold. ``copy_accounting`` reports the
bytes each protocol moves — the quantity behind the Fig.3 bandwidth curves.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

from repro.core import protocol
from repro.kernels.msgq import msgq


def _pad_to(x, m):
    pad = (-x.size) % m
    return (jnp.pad(x, (0, pad)), pad)


def msgq_copy(msg, *, force_protocol: Optional[str] = None,
              cell_elems: int = 1024, interpret: bool = True):
    """Copy a message through the selected protocol. msg: any shape."""
    flat = msg.reshape(-1)
    nbytes = flat.size * flat.dtype.itemsize
    proto = (protocol.validate_protocol(force_protocol) if force_protocol
             else protocol.select_protocol(
                 nbytes, cell=cell_elems * flat.dtype.itemsize))
    if proto in ("eager_fast", "eager"):
        padded, pad = _pad_to(flat, cell_elems)
        out = msgq.eager_copy(padded, cell_elems=cell_elems,
                              interpret=interpret)
    else:
        block = min(65536, max(256, 1 << (flat.size - 1).bit_length()))
        padded, pad = _pad_to(flat, block)
        out = msgq.one_copy(padded, block_elems=block, interpret=interpret)
    if pad:
        out = out[:flat.size]
    return out.reshape(msg.shape), proto


def copy_accounting(nbytes: int, proto: str,
                    cell_bytes: int = 4096) -> Dict[str, float]:
    """Bytes moved / DMA issues per protocol (feeds bench_p2p)."""
    ncells = -(-nbytes // cell_bytes)
    if proto in ("eager_fast", "eager"):
        return {"bytes_moved": 2.0 * nbytes, "dma_issues": 2 * ncells,
                "staging_bytes": min(nbytes, cell_bytes)}
    return {"bytes_moved": float(nbytes), "dma_issues": ncells,
            "staging_bytes": 0.0}
