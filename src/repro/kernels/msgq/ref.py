"""Oracle for the msgq kernels: a message copy is ... a copy."""

import jax.numpy as jnp


def msgq_copy_ref(msg):
    return jnp.array(msg, copy=True)
