from repro.kernels.msgq.ops import msgq_copy, copy_accounting  # noqa: F401
