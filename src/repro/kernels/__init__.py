"""Pallas TPU kernels for the perf-critical layers.

msgq/            paper §3.2: cell-queue message copy (eager 2-copy through
                 VMEM staging cells vs direct 1-copy HBM DMA)
flash_attention/ blocked online-softmax attention (GQA, causal, window)
ssd_scan/        Mamba2 SSD chunk scan with carried state

Each kernel ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper + dispatch), ref.py (pure-jnp oracle). Validated with
interpret=True on CPU; compiled for TPU on real hardware.
"""
