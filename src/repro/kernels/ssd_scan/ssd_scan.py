"""Mamba2 SSD chunk-scan kernel (state-space duality, arXiv:2405.21060).

Grid (B, H, n_chunks) with the chunk index minor-most: the running SSM
state (head_dim × state) lives in VMEM scratch and is carried across the
sequential chunk steps of each (b, h) pair, reset at chunk 0. Each grid
step computes the intra-chunk quadratic (attention-like) term plus the
contribution of the carried state, then folds the chunk into the state —
the SSD blocked algorithm with O(l·p + p·n) VMEM per step.

Block shapes: chunk length l and head_dim p are the MXU-facing dims; at
production sizes use l=128/p=64-128 (multiples of the 128 lane width where
possible). ngroups=1 (all assigned configs): B/C blocks are shared across
heads via the index_map.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, s0_ref, y_ref, fs_ref,
                state_scr, *, chunk: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        # seed the carried state from the caller (zeros for a fresh
        # sequence; a previous call's final state to resume a chunked
        # prefill bit-exactly — DESIGN.md §13)
        state_scr[...] = s0_ref[0, 0].astype(jnp.float32)

    x = x_ref[0, 0].astype(jnp.float32)        # (l, p)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (l,)
    A = a_ref[0]                               # scalar (negative)
    Bm = b_ref[0, 0].astype(jnp.float32)       # (l, n)
    Cm = c_ref[0, 0].astype(jnp.float32)       # (l, n)

    dA = dt * A                                # (l,)
    cum = jnp.cumsum(dA)                       # (l,)
    # lower-triangular decay matrix L[i,j] = exp(sum_{k=j+1..i} dA_k)
    seg = cum[:, None] - cum[None, :]
    li = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    lj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    Lm = jnp.where(li >= lj, jnp.exp(seg), 0.0)

    xdt = x * dt[:, None]                      # (l, p)
    scores = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * Lm          # (l, l)
    y_diag = jax.lax.dot_general(
        scores, xdt, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (l, p)

    state = state_scr[...]                     # (p, n)
    # contribution of the carried state: exp(cum) * C @ state^T
    y_off = jax.lax.dot_general(
        Cm, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]

    # fold this chunk into the state
    decay_states = jnp.exp(cum[-1] - cum)      # (l,)
    upd = jax.lax.dot_general(
        xdt, Bm * decay_states[:, None], (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)               # (p, n)
    new_state = state * jnp.exp(cum[-1]) + upd
    state_scr[...] = new_state
    # every chunk writes the running state to the same output block —
    # the last (sequentially final) chunk's write is what survives
    fs_ref[0, 0] = new_state

    y_ref[0, 0] = (y_diag + y_off).astype(y_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret", "return_state"))
def ssd_scan_fwd(x, dt, A, Bm, Cm, initial_state=None, *, chunk: int = 128,
                 interpret: bool = True, return_state: bool = False):
    """x: (B,H,S,p); dt: (B,H,S) (post-softplus); A: (H,) negative;
    Bm, Cm: (B,S,n) (ngroups=1). Returns y (B,H,S,p).

    ``initial_state`` (B,H,p,n) f32 seeds the carried scan state (zeros
    when None — a fresh sequence); ``return_state=True`` additionally
    returns the final state, so a chunked prefill can resume the scan
    from exactly where the previous chunk stopped."""
    B, H, S, p = x.shape
    n = Bm.shape[-1]
    l = min(chunk, S)
    assert S % l == 0, (S, l)
    nc = S // l
    if initial_state is None:
        initial_state = jnp.zeros((B, H, p, n), jnp.float32)
    kernel = functools.partial(_ssd_kernel, chunk=l)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, l, p), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, l), lambda b, h, ic: (b, h, ic)),
            pl.BlockSpec((1,), lambda b, h, ic: (h,)),
            pl.BlockSpec((1, 1, l, n), lambda b, h, ic: (b, 0, ic, 0)),
            pl.BlockSpec((1, 1, l, n), lambda b, h, ic: (b, 0, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, p), lambda b, h, ic: (b, h, ic, 0)),
            pl.BlockSpec((1, 1, p, n), lambda b, h, ic: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, p), x.dtype),
            jax.ShapeDtypeStruct((B, H, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bm.reshape(B, 1, S, n), Cm.reshape(B, 1, S, n),
      initial_state.astype(jnp.float32))
    if return_state:
        return y, final_state
    return y
