"""Pure-jnp oracle for the SSD scan kernel: the sequential recurrence
(exact SSM semantics — the chunked algorithm must match it)."""

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm, initial_state=None, *,
                 return_state: bool = False):
    """Sequential scan: state_{t} = state_{t-1} * exp(dt_t A) + dt_t x_t B_t;
    y_t = C_t · state_t. Shapes as in ssd_scan_fwd. ``initial_state``
    (B,H,p,n) seeds the recurrence (zeros when None); ``return_state``
    additionally returns the final state — the same carried-state
    contract as the kernel, so chunked-resume tests can use the oracle
    on both sides."""
    B, H, S, p = x.shape
    n = Bm.shape[-1]

    def per_bh(xb, dtb, a, Bb, Cb, s0):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            state = state * jnp.exp(dtt * a) + dtt * xt[:, None] * bt[None, :]
            return state, state @ ct
        final, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                                 (xb.astype(jnp.float32),
                                  dtb.astype(jnp.float32),
                                  Bb.astype(jnp.float32),
                                  Cb.astype(jnp.float32)))
        return ys, final

    if initial_state is None:
        initial_state = jnp.zeros((B, H, p, n), jnp.float32)
    f = jax.vmap(jax.vmap(per_bh, in_axes=(0, 0, 0, None, None, 0)),
                 in_axes=(0, 0, None, 0, 0, 0))
    ys, final = f(x, dt, A, Bm, Cm, initial_state)
    if return_state:
        return ys.astype(x.dtype), final
    return ys.astype(x.dtype)
