"""Pure-jnp oracle for the SSD scan kernel: the sequential recurrence
(exact SSM semantics — the chunked algorithm must match it)."""

import jax
import jax.numpy as jnp


def ssd_scan_ref(x, dt, A, Bm, Cm):
    """Sequential scan: state_{t} = state_{t-1} * exp(dt_t A) + dt_t x_t B_t;
    y_t = C_t · state_t. Shapes as in ssd_scan_fwd."""
    B, H, S, p = x.shape
    n = Bm.shape[-1]

    def per_bh(xb, dtb, a, Bb, Cb):
        def step(state, inp):
            xt, dtt, bt, ct = inp
            state = state * jnp.exp(dtt * a) + dtt * xt[:, None] * bt[None, :]
            return state, state @ ct
        init = jnp.zeros((p, n), jnp.float32)
        _, ys = jax.lax.scan(step, init, (xb.astype(jnp.float32),
                                          dtb.astype(jnp.float32),
                                          Bb.astype(jnp.float32),
                                          Cb.astype(jnp.float32)))
        return ys

    f = jax.vmap(jax.vmap(per_bh, in_axes=(0, 0, 0, None, None)),
                 in_axes=(0, 0, None, 0, 0))
    return f(x, dt, A, Bm, Cm).astype(x.dtype)
