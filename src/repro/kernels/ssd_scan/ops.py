"""jit'd wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, Bm, Cm, *, chunk: int = 128, interpret: bool = True):
    """See ssd_scan_fwd. Oracle: ref.ssd_scan_ref (sequential recurrence)."""
    return ssd_scan_fwd(x, dt, A, Bm, Cm, chunk=chunk, interpret=interpret)
