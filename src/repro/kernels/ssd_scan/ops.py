"""jit'd wrapper for the SSD scan kernel."""

from __future__ import annotations

import functools

import jax

from repro.kernels.ssd_scan.ssd_scan import ssd_scan_fwd


@functools.partial(jax.jit,
                   static_argnames=("chunk", "interpret", "return_state"))
def ssd_scan(x, dt, A, Bm, Cm, initial_state=None, *, chunk: int = 128,
             interpret: bool = True, return_state: bool = False):
    """See ssd_scan_fwd. Oracle: ref.ssd_scan_ref (sequential recurrence).

    ``initial_state``/``return_state`` thread the carried scan state
    across calls — the kernel-level contract behind state-threaded
    chunked prefill (DESIGN.md §13)."""
    return ssd_scan_fwd(x, dt, A, Bm, Cm, initial_state, chunk=chunk,
                        interpret=interpret, return_state=return_state)
