"""Pure-jnp oracle for the paged-attention decode kernel.

Dense gather-then-softmax over the block table: the straightforward (and
memory-hungry) computation the Pallas kernel must reproduce exactly in
interpret mode. Also the cross-validation target for the model's
block-table decode path (``transformer._paged_attn``).
"""

import math

import jax.numpy as jnp

NEG_INF = -1e30


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = 0, softcap: float = 0.0):
    """Decode attention through per-request block tables.

    q: (B, H, hd) — one query per request, the token at absolute position
    ``lengths[b] - 1`` (its own k/v is already resident in the pages) —
    or (B, K, H, hd) — a q-block of K queries, query ``j`` at absolute
    position ``lengths[b] - K + j`` with causality inside the block.
    k_pages, v_pages: (P, bs, Hkv, hd) — the global KV block pool; block
    ``p`` of a request's table holds its tokens ``[i*bs, (i+1)*bs)`` where
    ``i`` is the table index mapping to ``p``.
    block_tables: (B, NB) int32, ``-1`` marks absent table entries.
    lengths: (B,) int32, valid resident tokens per request (>= 1).
    Returns the same rank as q.
    """
    multi = q.ndim == 4
    if not multi:
        q = q[:, None]
    B, K, H, hd = q.shape
    P, bs, Hkv, _ = k_pages.shape
    NB = block_tables.shape[1]
    if Hkv != H:
        k_pages = jnp.repeat(k_pages, H // Hkv, axis=2)
        v_pages = jnp.repeat(v_pages, H // Hkv, axis=2)
    # gather each request's pages: (B, NB, bs, H, hd) -> (B, T, H, hd)
    kg = jnp.take(k_pages, jnp.maximum(block_tables, 0).reshape(-1), axis=0)
    vg = jnp.take(v_pages, jnp.maximum(block_tables, 0).reshape(-1), axis=0)
    kg = kg.reshape(B, NB * bs, H, hd)
    vg = vg.reshape(B, NB * bs, H, hd)

    s = jnp.einsum("bqhd,bthd->bqht", q.astype(jnp.float32),
                   kg.astype(jnp.float32)) / math.sqrt(hd)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)
    tok = jnp.arange(NB * bs)[None, None, :]                 # abs position
    qpos = (lengths[:, None] - K + jnp.arange(K)[None, :])[:, :, None]
    ok = tok <= qpos                                         # causal in-block
    ok &= jnp.repeat(block_tables >= 0, bs, axis=1)[:, None, :]
    if window > 0:
        ok &= tok > qpos - window
    s = jnp.where(ok[:, :, None, :], s, NEG_INF)
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.maximum(jnp.sum(p, axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqht,bthd->bqhd", p, vg.astype(jnp.float32)
                     ).astype(q.dtype)
    return out if multi else out[:, 0]
