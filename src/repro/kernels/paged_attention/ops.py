"""jit'd public wrapper for paged attention (layout adapter + dispatch).

The serving engine holds decode queries as (B, 1, H, hd) rows and the
block pool as (P, bs, Gs, hd); the kernel wants the squeezed (B, H, hd)
query. A (B, K, H, hd) query with K > 1 is a speculative-verify q-block
(query j at absolute position ``lengths[b] - K + j``) and dispatches the
multi-query kernel, returning (B, K, H, hd). On TPU set interpret=False;
interpret=True executes the kernel body in python on CPU for validation
(this container).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.paged_attention.paged_attention import paged_attention_fwd


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_attention(q, k_pages, v_pages, block_tables, lengths, *,
                    window: int = 0, softcap: float = 0.0,
                    interpret: bool = True):
    """q: (B, H, hd), (B, 1, H, hd), or (B, K, H, hd) with K > 1 (q-block
    verify); k_pages, v_pages: (P, bs, Hkv, hd); block_tables: (B, NB)
    int32; lengths: (B,) int32 -> same rank as q."""
    squeezed = q.ndim == 4 and q.shape[1] == 1
    if squeezed:
        q = q[:, 0]
    out = paged_attention_fwd(q, k_pages, v_pages,
                              jnp.asarray(block_tables, jnp.int32),
                              jnp.asarray(lengths, jnp.int32),
                              window=window, softcap=softcap,
                              interpret=interpret)
    return out[:, None] if squeezed else out
