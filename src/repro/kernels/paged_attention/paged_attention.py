"""Paged-attention decode kernel for TPU (single-token and q-block queries).

The serving KV cache is a global pool of fixed-size blocks (``serve/
block_pool.py``); each in-flight request owns a *block table* — the list
of pool blocks holding its tokens in order. Decode attention therefore
cannot stream K/V contiguously the way ``kernels/flash_attention`` does:
the kv blocks of one request are scattered across the pool.

This kernel gathers them through the table with *scalar prefetch*
(``pltpu.PrefetchScalarGridSpec``): the block tables and lengths ride in
SMEM ahead of the grid, and the k/v BlockSpec index maps read
``tables[b, i]`` to aim the automatic HBM→VMEM pipeline at the right
pool block — the gather costs no extra copies, it *is* the pipeline.
Grid is (B, NB) with the table index minor-most, so the running
max / sum / accumulator of the online softmax live in VMEM scratch
across one request's blocks and the output is emitted on the last one
(same discipline as the flash kernel).

Because block ``i`` of a table holds the request's tokens
``[i*bs, (i+1)*bs)``, positions are structural — no per-token position
array is gathered; masking needs only ``lengths`` (and the optional
sliding window over absolute positions). GQA is free the same way as in
flash attention: kv heads are repeated only inside VMEM, never
rematerialized in HBM.

Contract: each live row has ``lengths[b] >= 1`` and a valid
``tables[b, 0]``; the query is the token at position ``lengths[b]-1``
whose own k/v is already resident. Rows with an all ``-1`` table (parked
decode rows of a serving engine) produce finite garbage that the caller
must discard — their pool writes were dropped upstream, so no live data
is at risk.

The *multi-query* variant (speculative verify, DESIGN.md §14) extends
the same pipeline to a q-block of K tokens per request: q is
``(B, K, H, hd)`` and query ``j`` of row ``b`` sits at absolute position
``lengths[b] - K + j`` (its k/v already resident — teacher-forced
verify writes the draft rows before dispatching). Causality *within*
the q-block is a per-query structural mask ``tok <= qpos`` — no mask
tensor is gathered, and the online-softmax scratch simply grows a K
axis ((H, K) running max/sum, (H, K, hd) accumulator). K = 1 reduces
to exactly the single-query reductions.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, scale: float, window: int,
                  softcap: float, block_size: int, nb: int):
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # (H, hd)
    k = k_ref[0]                                     # (bs, Hkv, hd)
    v = v_ref[0]
    H = q.shape[0]
    hkv = k.shape[1]
    if hkv != H:                                     # GQA: repeat in VMEM only
        k = jnp.repeat(k, H // hkv, axis=1)
        v = jnp.repeat(v, H // hkv, axis=1)
    s = jax.lax.dot_general(
        q, k.transpose(1, 0, 2), (((1,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale  # (H, bs)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    # token positions are structural: table entry i holds [i*bs, (i+1)*bs)
    tok = i * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (H, block_size), 1)
    length = lengths_ref[b]
    ok = (tok < length) & (tables_ref[b, i] >= 0)
    if window > 0:
        ok &= tok > (length - 1) - window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[:, None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v.transpose(1, 0, 2),
                        (((1,), (1,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(i == nb - 1)
    def _emit():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def _paged_mq_kernel(tables_ref, lengths_ref, q_ref, k_ref, v_ref, o_ref,
                     m_scr, l_scr, acc_scr, *, scale: float, window: int,
                     softcap: float, block_size: int, nb: int):
    """K-query variant: q block (1, K, H, hd), scratch carries a K axis.

    Query j of row b is the token at absolute position
    ``lengths[b] - K + j``; causality within the q-block is the same
    structural ``tok <= qpos`` test as the single-query length mask."""
    b = pl.program_id(0)
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                     # (K, H, hd)
    k = k_ref[0]                                     # (bs, Hkv, hd)
    v = v_ref[0]
    K, H, _ = q.shape
    hkv = k.shape[1]
    if hkv != H:                                     # GQA: repeat in VMEM only
        k = jnp.repeat(k, H // hkv, axis=1)
        v = jnp.repeat(v, H // hkv, axis=1)
    s = jax.lax.dot_general(
        q.transpose(1, 0, 2), k.transpose(1, 0, 2),
        (((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32) * scale  # (H, K, bs)
    if softcap > 0:
        s = softcap * jnp.tanh(s / softcap)

    # token positions are structural: table entry i holds [i*bs, (i+1)*bs);
    # query j sits at absolute position length - K + j
    tok = i * block_size + jax.lax.broadcasted_iota(
        jnp.int32, (K, block_size), 1)
    length = lengths_ref[b]
    qpos = (length - K) + jax.lax.broadcasted_iota(
        jnp.int32, (K, block_size), 0)
    ok = (tok <= qpos) & (tables_ref[b, i] >= 0)
    if window > 0:
        ok &= tok > qpos - window
    s = jnp.where(ok[None], s, NEG_INF)

    m_prev = m_scr[...]                              # (H, K)
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m_prev - m_new)
    l_new = l_prev * corr + jnp.sum(p, axis=-1)
    acc_scr[...] = (acc_scr[...] * corr[..., None]
                    + jax.lax.dot_general(
                        p.astype(v.dtype), v.transpose(1, 0, 2),
                        (((2,), (1,)), ((0,), (0,))),
                        preferred_element_type=jnp.float32))
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(i == nb - 1)
    def _emit():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)[..., None]
                    ).transpose(1, 0, 2).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "softcap", "interpret"))
def paged_attention_fwd(q, k_pages, v_pages, block_tables, lengths, *,
                        window: int = 0, softcap: float = 0.0,
                        interpret: bool = True):
    """q: (B, H, hd) single-query, or (B, K, H, hd) q-block (query j of
    row b at absolute position ``lengths[b] - K + j``); k_pages, v_pages:
    (P, bs, Hkv, hd) with H % Hkv == 0; block_tables: (B, NB) int32
    (-1 = absent); lengths: (B,) int32. Returns the same rank as q."""
    multi = q.ndim == 4
    if multi:
        B, K, H, hd = q.shape
    else:
        B, H, hd = q.shape
        K = 1
    P, bs, Hkv, _ = k_pages.shape
    assert H % Hkv == 0, (H, Hkv)
    NB = block_tables.shape[1]
    scale = 1.0 / math.sqrt(hd)

    def kv_map(b, i, tables, lengths_):
        # absent entries clamp to block 0; the kernel masks them out
        return (jnp.maximum(tables[b, i], 0), 0, 0, 0)

    if multi:
        kernel = functools.partial(
            _paged_mq_kernel, scale=scale, window=window, softcap=softcap,
            block_size=bs, nb=NB)
        q_spec = pl.BlockSpec((1, K, H, hd), lambda b, i, t, n: (b, 0, 0, 0))
        out_shape = jax.ShapeDtypeStruct((B, K, H, hd), q.dtype)
        scratch = [pltpu.VMEM((H, K), jnp.float32),
                   pltpu.VMEM((H, K), jnp.float32),
                   pltpu.VMEM((H, K, hd), jnp.float32)]
    else:
        kernel = functools.partial(
            _paged_kernel, scale=scale, window=window, softcap=softcap,
            block_size=bs, nb=NB)
        q_spec = pl.BlockSpec((1, H, hd), lambda b, i, t, n: (b, 0, 0))
        out_shape = jax.ShapeDtypeStruct((B, H, hd), q.dtype)
        scratch = [pltpu.VMEM((H,), jnp.float32),
                   pltpu.VMEM((H,), jnp.float32),
                   pltpu.VMEM((H, hd), jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, NB),
        in_specs=[
            q_spec,
            pl.BlockSpec((1, bs, Hkv, hd), kv_map),
            pl.BlockSpec((1, bs, Hkv, hd), kv_map),
        ],
        out_specs=q_spec,
        scratch_shapes=scratch,
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
    )(block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
      q, k_pages, v_pages)
