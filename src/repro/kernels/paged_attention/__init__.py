from repro.kernels.paged_attention.ops import paged_attention  # noqa: F401
from repro.kernels.paged_attention.ref import paged_attention_ref  # noqa: F401
