"""Analytical compute/memory roofline terms per workload cell.

XLA's cost_analysis counts while bodies once (see hlo_struct.py), so for
scanned models the compiled artifact under-reports FLOPs/bytes by ~L×. The
compute and memory terms reported in EXPERIMENTS.md therefore come from the
closed-form accounting below (formulas documented inline, matching what the
compiled graph actually computes — e.g. our chunked attention evaluates all
S×T block pairs, so attention FLOPs use the full S·T rectangle, not the
causal half; the gap to the causal minimum shows up as useful-flops ratio,
not hidden). HLO raw numbers are kept in the artifacts as a cross-check.

Conventions: 1 MAC = 2 FLOPs. Backward pass = 2× forward matmul FLOPs;
remat adds ~1× forward recompute (we checkpoint every block and the CE
chunks), so train ≈ 4× forward.
"""

from __future__ import annotations

from typing import Dict

from repro.config import (BLOCK_DENSE, BLOCK_HYBRID, BLOCK_MOE, BLOCK_SSM,
                          MeshConfig, ModelConfig, ShapeConfig)


def _per_token_matmul_flops(cfg: ModelConfig) -> float:
    """Forward matmul FLOPs per token, all layers + LM head."""
    d, f, L = cfg.d_model, cfg.d_ff, cfg.num_layers
    per_layer = 0.0
    if cfg.uses_attention:
        h, hkv, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        per_layer += 2 * d * (h * hd)          # wq
        per_layer += 2 * 2 * d * (hkv * hd)    # wk, wv
        per_layer += 2 * (h * hd) * d          # wo
    if cfg.block in (BLOCK_DENSE, BLOCK_HYBRID):
        gates = 2 if cfg.mlp_act in ("swiglu", "geglu") else 1
        per_layer += 2 * (gates + 1) * d * f
    if cfg.block == BLOCK_MOE:
        gates = 2 if cfg.mlp_act in ("swiglu", "geglu") else 1
        per_layer += 2 * cfg.top_k * (gates + 1) * d * f   # active experts
        per_layer += 2 * d * cfg.num_experts               # router
    if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
        di, n, h = cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_heads
        per_layer += 2 * d * (2 * di + 2 * n + h)          # in_proj
        per_layer += 2 * di * d                            # out_proj
        per_layer += 2 * cfg.ssm_conv * (di + 2 * n)       # depthwise conv
    total = per_layer * L
    total += 2 * d * cfg.padded_vocab                      # LM head matmul
    if cfg.is_encoder_decoder:
        # encoder blocks + decoder cross-attention projections (per dec tok)
        h, hd = cfg.num_heads, cfg.head_dim
        total += 2 * 2 * d * h * hd * cfg.num_layers       # x-attn q & out
        # encoder runs over encoder_seq tokens regardless of decoder length;
        # accounted separately in cell_compute (enc_tokens)
        return total
    return total


def _attention_score_flops(cfg: ModelConfig, s_q: int, s_kv: int,
                           batch: int) -> float:
    """QK^T + PV einsum FLOPs, as computed (full rectangle, incl. masked)."""
    if not cfg.uses_attention:
        return 0.0
    h, hd, L = cfg.num_heads, cfg.head_dim, cfg.num_layers
    return 2 * 2 * batch * s_q * s_kv * h * hd * L


def _ssd_flops(cfg: ModelConfig, tokens: float) -> float:
    """SSD chunked-scan einsum FLOPs per DESIGN: intra-chunk quadratic
    (l per token) + state in/out projections (n per token)."""
    if cfg.block not in (BLOCK_SSM, BLOCK_HYBRID):
        return 0.0
    h, p, n, l = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_chunk
    per_tok = 2 * h * p * l          # y_diag (attention-like within chunk)
    per_tok += 2 * h * l * n         # L/B contraction
    per_tok += 2 * 3 * h * p * n     # states build + y_off + decay apply
    return per_tok * tokens * cfg.num_layers


def cell_compute_flops(cfg: ModelConfig, shape: ShapeConfig) -> Dict:
    """Global computed FLOPs for one executed step of this cell."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        fwd = (_per_token_matmul_flops(cfg) * tokens
               + _attention_score_flops(cfg, S, S, B)
               + _ssd_flops(cfg, tokens))
        if cfg.is_encoder_decoder:
            enc_tokens = B * cfg.encoder_seq
            enc = (2 * (4 * cfg.d_model * cfg.num_heads * cfg.head_dim
                        + 2 * cfg.d_model * cfg.d_ff)
                   * cfg.num_encoder_layers) * enc_tokens
            enc += _attention_score_flops(
                cfg, cfg.encoder_seq, cfg.encoder_seq, B) \
                / cfg.num_layers * cfg.num_encoder_layers
            xattn = 2 * 2 * B * S * cfg.encoder_seq * cfg.num_heads \
                * cfg.head_dim * cfg.num_layers
            fwd += enc + xattn
        total = 4.0 * fwd          # fwd + bwd(2x) + remat recompute(1x)
        useful = 6.0 * cfg.active_param_count() * tokens
        return {"computed": total, "model_flops": useful}
    if shape.kind == "prefill":
        tokens = B * S
        fwd = (_per_token_matmul_flops(cfg) * tokens
               + _attention_score_flops(cfg, S, S, B)
               + _ssd_flops(cfg, tokens))
        return {"computed": fwd,
                "model_flops": 2.0 * cfg.active_param_count() * tokens}
    # decode: one token, attention reads the whole cache
    cache = shape.seq_len
    if cfg.swa_window > 0:
        # windowed layers only read the window; global layers the full cache
        n_glob = len(cfg.global_layers)
        eff = (n_glob * min(cache, cache)
               + (cfg.num_layers - n_glob) * min(cfg.swa_window, cache)) \
            / cfg.num_layers
        cache = eff
    fwd = (_per_token_matmul_flops(cfg) * B
           + _attention_score_flops(cfg, 1, int(cache), B)
           + _ssd_flops(cfg, B))
    return {"computed": fwd,
            "model_flops": 2.0 * cfg.active_param_count() * B}


def cell_memory_bytes(cfg: ModelConfig, shape: ShapeConfig,
                      mesh_cfg: MeshConfig, *, param_bytes: int = 2,
                      cache_len: int = None) -> Dict:
    """Per-device HBM traffic for one step (reads+writes, estimate).

    Train:  weights fwd+bwd+recompute (3 passes) + grad write + AdamW state
            (m,v,master read+write, f32) + activation traffic
            (~14 d-vectors per token-layer with remat, bf16).
    Prefill: weights once + activations + cache write.
    Decode:  weights once + full cache read + tiny activations (the classic
             memory-bound regime).
    """
    N = cfg.param_count()
    tp = mesh_cfg.tp
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model

    if shape.kind == "train":
        tokens_dev = B * S / mesh_cfg.dp
        w = 3 * N / tp / mesh_cfg.dp * param_bytes   # fsdp gathers land 3x
        grads = N / (tp * mesh_cfg.dp) * 4
        opt = 6 * N / (tp * mesh_cfg.dp) * 4         # m,v,master r+w
        act = 14 * cfg.num_layers * tokens_dev * d * 2
        total = w + grads + opt + act
        return {"bytes": total, "weights": w, "opt": opt + grads, "act": act}
    if shape.kind == "prefill":
        tokens_dev = B * S / mesh_cfg.dp
        w = N / (tp * mesh_cfg.dp) * param_bytes
        act = 6 * cfg.num_layers * tokens_dev * d * 2
        kv = 0.0
        if cfg.uses_attention:
            from repro.models.transformer import kv_store_heads
            gs = kv_store_heads(cfg, tp)
            kv = (2 * cfg.num_layers * (B / mesh_cfg.dp) * S * gs
                  * cfg.head_dim * 2 / max(1, tp if gs % tp == 0 else 1))
        total = w + act + kv
        return {"bytes": total, "weights": w, "act": act, "cache": kv}
    # decode
    w = N / (tp * mesh_cfg.dp) * param_bytes
    dp_eff = mesh_cfg.dp if B % mesh_cfg.dp == 0 else 1
    kv = 0.0
    cl = cache_len if cache_len is not None else S
    if cfg.uses_attention:
        from repro.models.transformer import kv_store_heads
        gs = kv_store_heads(cfg, tp)
        head_shard = tp if gs % tp == 0 else 1
        kv = 2 * cfg.num_layers * (B / dp_eff) * cl * gs * cfg.head_dim * 2 \
            / head_shard
    ssm = 0.0
    if cfg.block in (BLOCK_SSM, BLOCK_HYBRID):
        ssm = (cfg.num_layers * (B / dp_eff) * cfg.ssm_heads
               * cfg.ssm_head_dim * cfg.ssm_state * 4) * 2
    act = 4 * cfg.num_layers * (B / dp_eff) * d * 2
    total = w + kv + ssm + act
    return {"bytes": total, "weights": w, "cache": kv + ssm, "act": act}
