"""Roofline-term extraction from a compiled (dry-run) executable.

Three terms per (arch × shape × mesh), in seconds (per-device formulation —
equivalent to the global form since both numerator and denominator scale by
the chip count):

  compute    = computed_FLOPs_per_device / peak_FLOP/s      (analytical)
  memory     = HBM_bytes_per_device / HBM_bw                (analytical)
  collective = Σ collective operand bytes per device / link_bw
               (parsed from optimized HLO, ×while-loop trip counts)

Why analytical for compute/memory: XLA's cost_analysis counts while bodies
once, so an 80-layer lax.scan model under-reports ~80× (probe in
EXPERIMENTS.md §Dry-run). Raw cost_analysis numbers are retained in every
artifact as a cross-check. Collectives come from the HLO because the
*schedule* (which ops XLA inserted, over which groups) is exactly what we
want to observe; we correct their execution counts with the parsed trip
multipliers from hlo_struct.py.

Operand sizes: optimized HLO prints operands as %refs without shapes, so
operand bytes derive from the output shape and group size:
  all-reduce: out == operand; all-gather: operand = out/g;
  reduce-scatter: operand = out*g; all-to-all, collective-permute: out.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List

from repro.roofline.hlo_struct import (computation_multipliers,
                                       line_computation_index)
from repro.roofline.hw import HW, V5E

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(
    r"\b([a-z]+[0-9]*(?:e[0-9]+m[0-9]+(?:fn)?)?)\[([0-9,]*)\]")
_COLL_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute")
_IOTA_RG_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=")
_LIST_RG_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d.strip():
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _match_op(line: str):
    for cand in _COLL_OPS:
        if f" {cand}(" in line or f" {cand}-start(" in line:
            return cand
    return None


def parse_collectives(hlo_text: str) -> List[Dict]:
    """One record per collective op, with while-trip execution multipliers."""
    mult = computation_multipliers(hlo_text)
    out = []
    for comp, line in line_computation_index(hlo_text):
        s = line.strip()
        op = _match_op(s)
        if op is None:
            continue
        if s.startswith("ROOT "):
            s = s[5:]
        idx = s.find(f" {op}")
        lhs = s[:idx]
        rhs = s[idx:]
        out_bytes = sum(_shape_bytes(d, dd)
                        for d, dd in _SHAPE_RE.findall(lhs))
        group_size, num_groups = None, None
        m = _IOTA_RG_RE.search(rhs)
        if m:
            num_groups, group_size = int(m.group(1)), int(m.group(2))
        else:
            m = _LIST_RG_RE.search(rhs)
            if m:
                ids = [x for x in m.group(1).split(",") if x.strip()]
                group_size = len(ids)
        g = group_size or 2
        if op == "all-gather":
            opnd = out_bytes / g
        elif op == "reduce-scatter":
            opnd = out_bytes * g
        else:
            opnd = out_bytes
        # ring-model effective bytes per device
        if op == "all-reduce":
            eff = 2 * (g - 1) / g * opnd
        elif op == "all-gather":
            eff = (g - 1) * opnd
        elif op == "reduce-scatter":
            eff = (g - 1) / g * opnd
        elif op == "all-to-all":
            eff = (g - 1) / g * opnd
        else:
            eff = opnd
        k = mult.get(comp, 1)
        out.append({
            "op": op, "computation": comp, "trip_multiplier": k,
            "operand_bytes": opnd, "output_bytes": out_bytes,
            "group_size": group_size, "num_groups": num_groups,
            "total_operand_bytes": opnd * k,
            "total_effective_bytes": eff * k,
        })
    return out


def summarize_collectives(colls: List[Dict]) -> Dict:
    by_op = defaultdict(lambda: {"sites": 0, "executions": 0,
                                 "operand_bytes": 0.0,
                                 "effective_bytes": 0.0})
    for c in colls:
        rec = by_op[c["op"]]
        rec["sites"] += 1
        rec["executions"] += c["trip_multiplier"]
        rec["operand_bytes"] += c["total_operand_bytes"]
        rec["effective_bytes"] += c["total_effective_bytes"]
    total = {k: sum(r[k] for r in by_op.values())
             for k in ("sites", "executions", "operand_bytes",
                       "effective_bytes")}
    return {"by_op": {k: dict(v) for k, v in by_op.items()}, "total": total}


def analyze_compiled(compiled, *, hw: HW = V5E, model_flops: float = None,
                     hlo_text: str = None, analytic: Dict = None) -> Dict:
    """Roofline terms + bookkeeping. ``analytic``: optional dict with
    ``computed_flops_per_device`` and ``bytes_per_device`` from
    roofline.flops (preferred source for compute/memory terms)."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # old jax: one dict per device
        cost = cost[0] if cost else {}
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    colls = parse_collectives(hlo)
    summary = summarize_collectives(colls)

    mem = compiled.memory_analysis()
    mem_fields = {}
    for f in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(mem, f):
            mem_fields[f] = int(getattr(mem, f))
    live_bytes = (mem_fields.get("argument_size_in_bytes", 0)
                  + mem_fields.get("output_size_in_bytes", 0)
                  + mem_fields.get("temp_size_in_bytes", 0)
                  - mem_fields.get("alias_size_in_bytes", 0))

    flops_dev = (analytic or {}).get("computed_flops_per_device", raw_flops)
    bytes_dev = (analytic or {}).get("bytes_per_device", raw_bytes)
    t_compute = flops_dev / hw.peak_flops_bf16
    t_memory = bytes_dev / hw.hbm_bw
    t_coll = summary["total"]["operand_bytes"] / hw.ici_link_bw
    t_coll_eff = summary["total"]["effective_bytes"] / hw.ici_link_bw
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll, "collective_eff_s": t_coll_eff}
    dominant = max(("compute_s", "memory_s", "collective_s"),
                   key=lambda k: terms[k])
    bound_s = max(t_compute, t_memory, t_coll)
    result = {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "hlo_raw": {"flops": raw_flops, "bytes_accessed": raw_bytes,
                    "note": "while bodies counted once (see §Dry-run)"},
        "collectives": summary,
        "memory_analysis": mem_fields,
        "live_bytes_per_device": live_bytes,
        "fits_hbm": live_bytes <= hw.hbm_bytes,
        "terms": terms,
        "dominant": dominant,
        "roofline_bound_s": bound_s,
        "hw": hw.name,
    }
    if analytic:
        result["analytic"] = analytic
    if model_flops:
        result["model_flops_per_device"] = model_flops
        result["useful_flops_ratio"] = (model_flops / flops_dev
                                        if flops_dev else 0.0)
        result["mfu_at_bound"] = (model_flops / hw.peak_flops_bf16 / bound_s
                                  if bound_s else 0.0)
    return result
