"""Target hardware constants (TPU v5e, per assignment)."""

from dataclasses import dataclass


@dataclass(frozen=True)
class HW:
    name: str
    peak_flops_bf16: float     # FLOP/s per chip
    hbm_bw: float              # bytes/s per chip
    ici_link_bw: float         # bytes/s per ICI link
    dcn_bw: float              # bytes/s per host, inter-pod
    hbm_bytes: float           # capacity per chip
    vmem_bytes: float


V5E = HW(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bw=819e9,
    ici_link_bw=50e9,
    dcn_bw=6.25e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)
