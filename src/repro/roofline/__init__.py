from repro.roofline.hw import V5E  # noqa: F401
from repro.roofline.analysis import analyze_compiled, parse_collectives  # noqa: F401
