"""Render the roofline tables for EXPERIMENTS.md from dry-run artifacts."""

from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

ART = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "experiments", "artifacts")

_SHAPE_ORDER = {"train_4k": 0, "prefill_32k": 1, "decode_32k": 2,
                "long_500k": 3}


def _advice(rec: Dict) -> str:
    """One sentence: what would move the dominant term down."""
    a = rec["analysis"]
    m = rec["meta"]
    dom = a["dominant"]
    if dom == "compute_s":
        ratio = a.get("useful_flops_ratio", 0)
        if ratio < 0.5:
            return ("compute-bound with low useful ratio: skip masked "
                    "attention blocks (block-sparse causal schedule) and "
                    "drop the remat recompute on cheap ops")
        return ("compute-bound near the useful ceiling: larger per-step "
                "batch or int8/fp8 matmuls are the remaining levers")
    if dom == "memory_s":
        if m["kind"] == "decode":
            return ("decode is weight/cache-bandwidth bound: batch more "
                    "sequences per step, quantize KV cache to int8, or "
                    "shrink the replicated weight fraction")
        return ("memory-bound: fuse optimizer update into the backward, "
                "keep activations bf16 end-to-end, raise arithmetic "
                "intensity with larger microbatches")
    return ("collective-bound: overlap the FSDP gathers with compute "
            "(latency-hiding scheduler), move grad sync to the "
            "hierarchical threadcomm schedule, shard less over the slow "
            "axis")


def load_records(mesh_name: str) -> List[Dict]:
    out = []
    for f in sorted(glob.glob(os.path.join(ART, mesh_name, "*.json"))):
        d = json.load(open(f))
        if "analysis" in d:
            out.append(d)
    out.sort(key=lambda r: (r["meta"]["arch"],
                            _SHAPE_ORDER.get(r["meta"]["shape"], 9)))
    return out


def roofline_table(mesh_name: str, grad_sync: str = "spmd") -> str:
    rows = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | "
        "dominant | fits HBM | 6ND/HLO | MFU@bound | next lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for rec in load_records(mesh_name):
        m, a = rec["meta"], rec["analysis"]
        if m.get("grad_sync", "spmd") != grad_sync \
                or m.get("shard_mode", "2d") != "2d":
            continue
        t = a["terms"]
        ratio = a.get("useful_flops_ratio", 0.0)
        mfu = a.get("mfu_at_bound", 0.0)
        rows.append(
            f"| {m['arch']} | {m['shape']} | {t['compute_s']:.4f} | "
            f"{t['memory_s']:.4f} | {t['collective_s']:.4f} | "
            f"{a['dominant'].replace('_s', '')} | "
            f"{'yes' if a['fits_hbm'] else 'NO'} | {ratio:.2f} | "
            f"{mfu:.2f} | {_advice(rec)} |")
    return "\n".join(rows)


def dryrun_summary(mesh_name: str) -> str:
    recs = load_records(mesh_name)
    lines = [
        "| arch | shape | params | live GB/dev | coll ops (exec) | "
        "coll bytes/dev | compile s |",
        "|---|---|---|---|---|---|---|",
    ]
    for rec in recs:
        m, a = rec["meta"], rec["analysis"]
        if m.get("grad_sync", "spmd") != "spmd" \
                or m.get("shard_mode", "2d") != "2d":
            continue
        tot = a["collectives"]["total"]
        lines.append(
            f"| {m['arch']} | {m['shape']} | {m['params'] / 1e9:.1f}B | "
            f"{a['live_bytes_per_device'] / 1e9:.1f} | "
            f"{tot['executions']} | {tot['operand_bytes']:.3g} | "
            f"{rec['timings']['compile_s']:.0f} |")
    return "\n".join(lines)


def main():
    for mesh in ("single_pod", "multi_pod"):
        print(f"\n## Roofline — {mesh}\n")
        print(roofline_table(mesh))
        print(f"\n## Dry-run — {mesh}\n")
        print(dryrun_summary(mesh))


if __name__ == "__main__":
    main()
