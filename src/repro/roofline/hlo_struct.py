"""HLO structural parsing: computations, while-loop trip counts, and
trip-count multipliers per computation.

XLA's HloCostAnalysis (and our naive line scan) counts a while body ONCE,
but a jax ``lax.scan`` over 80 layers executes it 80 times — without this
correction every scanned model's roofline is off by ~L× (verified
empirically in EXPERIMENTS.md §Dry-run). We reconstruct the computation
graph from the optimized HLO text:

  * split the module into computations,
  * for every ``while`` op, bind its body/cond computations to the parent,
  * read the trip count from the cond's s32 ``constant(N)`` bound,
  * propagate multipliers entry→leaves (nested scans multiply).
"""

from __future__ import annotations

import re
from typing import Dict, List, Tuple

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_WHILE_RE = re.compile(
    r"while\((?:[^)]*)\)[^\n]*?condition=%?([\w\.\-]+)[^\n]*?body=%?([\w\.\-]+)")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(")


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """computation name -> its lines (flat split on top-level braces)."""
    comps: Dict[str, List[str]] = {}
    cur = None
    depth = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                comps[cur] = []
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(line)
    return comps


def while_edges(comps: Dict[str, List[str]]) -> List[Tuple[str, str, str]]:
    """(parent_comp, cond_comp, body_comp) for every while op."""
    edges = []
    for name, lines in comps.items():
        for line in lines:
            if " while(" in line or "= while(" in line:
                m = _WHILE_RE.search(line)
                if m:
                    edges.append((name, m.group(1), m.group(2)))
    return edges


def trip_count(cond_lines: List[str]) -> int:
    """Loop bound from the cond computation: the s32 constant it compares
    against. jax scans lower to `ivar < constant(length)`."""
    consts = []
    has_cmp = any(_COMPARE_RE.search(l) for l in cond_lines)
    for l in cond_lines:
        consts += [int(x) for x in _CONST_RE.findall(l)]
    if not consts:
        return 1
    return max(consts) if has_cmp else 1


def computation_multipliers(hlo_text: str) -> Dict[str, int]:
    """Execution-count multiplier for every computation (entry = 1; a while
    body executes parent_multiplier × trip_count times)."""
    comps = split_computations(hlo_text)
    edges = while_edges(comps)
    # entry computation: the one never referenced as body/cond; fall back to
    # the one whose name contains 'main'
    mult: Dict[str, int] = {name: 1 for name in comps}
    children: Dict[str, List[Tuple[str, int]]] = {}
    for parent, cond, body in edges:
        t = trip_count(comps.get(cond, []))
        children.setdefault(parent, []).append((body, t))
        children.setdefault(parent, []).append((cond, t + 1))
    # propagate (graph is a DAG; iterate to fixpoint, small graphs)
    for _ in range(32):
        changed = False
        for parent, kids in children.items():
            for body, t in kids:
                want = mult.get(parent, 1) * t
                if mult.get(body, 1) != want:
                    mult[body] = want
                    changed = True
        if not changed:
            break
    return mult


def line_computation_index(hlo_text: str) -> List[Tuple[str, str]]:
    """[(computation_name, line), ...] for every instruction line."""
    out = []
    cur = None
    depth = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if cur is None:
            m = _COMP_HDR.match(stripped)
            if m and stripped.endswith("{"):
                cur = m.group(1)
                depth = 1
            continue
        depth += stripped.count("{") - stripped.count("}")
        if depth <= 0:
            cur = None
            continue
        out.append((cur, line))
    return out
