"""Serving-fabric bench: the N-rank router fabric vs the single
continuous engine on the mixed 16/256 poisson trace (DESIGN.md §10).

Rows land in ``BENCH_fabric.json`` via ``run.py --only fabric --json``
(and the fabric-smoke CI job drives the same comparison through
``repro.launch.serve --fabric both``). The verified flags record that
the replicated placement is greedy token-identical to the single
engine and that the disaggregated placement completed the trace with
every prefill migrated, plus the protocol model's KV-migration pricing
and per-rank utilization.
"""

from __future__ import annotations

from typing import Iterator

from benchmarks.common import Row

TRACE = dict(requests=16, ranks=2, slots=4, prompt_len=(16, 256),
             max_new=(4, 48), arrival="poisson", rate=400.0, seed=0,
             prefill_chunk=64, max_prefill_per_step=2, block_size=16)
TRACE_FAST = dict(requests=8, ranks=2, slots=2, prompt_len=(16, 128),
                  max_new=(2, 24), arrival="poisson", rate=400.0, seed=0,
                  prefill_chunk=32, max_prefill_per_step=2, block_size=16)


def rows(fast: bool = False) -> Iterator[Row]:
    from repro.launch.serve import run_fabric
    res = run_fabric("gemma-2b", smoke=True,
                     placements=("replicated", "disagg"),
                     **(TRACE_FAST if fast else TRACE))

    for name in ("single", "fabric_replicated", "fabric_disagg"):
        m = res[name]
        us_per_tok = 1e6 / m["tok_s"]
        ttft = (f" ttft_p95_ms={m['ttft_p95_s']*1e3:.1f}"
                if "ttft_p95_s" in m else "")
        yield (f"serve_{name}_us_per_tok", us_per_tok,
               f"tok_s={m['tok_s']:.1f} p50_ms={m['latency_p50_s']*1e3:.1f} "
               f"p95_ms={m['latency_p95_s']*1e3:.1f}{ttft}")

    rep = res["fabric_replicated"]
    for p in ("replicated", "disagg"):
        spd = res[f"speedup_vs_single_{p}"]
        yield (f"serve_fabric_speedup_vs_single_{p}", spd,
               f"fabric_{p} tok_s / single-engine tok_s on the same "
               f"trace; beats_single={spd > 1.0}")
    yield ("serve_fabric_replicated_identity", 0.0,
           f"token_identical={res['fabric_token_identical_replicated']} "
           f"(N={res['ranks']} JSQ replicas vs single engine, greedy "
           f"mixed prompt_len={res['prompt_len']})")
    for row in rep["per_rank"]:
        yield (f"serve_fabric_replicated_rank{row['rank']}_util",
               row["utilization"],
               f"role={row['role']} dispatched={row['dispatched']:.0f} "
               f"tokens={row['tokens']:.0f}")

    dis = res["fabric_disagg"]
    yield ("serve_fabric_kv_migration_us_per_block",
           dis["kv_migration_us_per_block"],
           f"{dis['n_migrations']:.0f} handoffs {dis['blocks_moved']:.0f} "
           f"blocks {dis['bytes_moved']:.0f}B modeled "
           f"{dis['kv_migration_modeled_s']*1e6:.1f}us total "
           f"(protocol.kv_migration_latency)")
    for row in dis["per_rank"]:
        yield (f"serve_fabric_disagg_rank{row['rank']}_util",
               row["utilization"],
               f"role={row['role']} migrated_in={row['migrated_in']:.0f} "
               f"migrated_out={row['migrated_out']:.0f} "
               f"tokens={row['tokens']:.0f}")
    yield ("serve_fabric_disagg_identity", 0.0,
           f"token_identical={res['fabric_token_identical_disagg']} "
           f"(prefill rank streams KV block-by-block to decode rank; "
           f"migrated leases, not recompute)")
    yield ("serve_fabric_dispatch_cost_us", rep["router_dispatch_cost_us"],
           f"router cell-queue dispatch hop over "
           f"{int(rep['n'])} requests (paper §3.2 pricing)")
