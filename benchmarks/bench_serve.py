"""Serving bench: continuous batching vs static batch on a mixed-arrival,
mixed-prompt-length trace (DESIGN.md §8), plus the greedy parity check
and the chunked-vs-monolithic prefill comparison.

Rows land in ``BENCH_serve.json`` via ``run.py --only serve --json ...``;
the comparison rows carry ``verified=`` flags so the artifact records
that the continuous engine's tok/s strictly exceeded the static engine's
on the same trace, that chunked prefill beat monolithic prefill on TTFT
p95 with its compile count independent of the number of distinct prompt
lengths, and that chunked continuous decoding is token-identical to the
static baseline on a same-arrival greedy batch (multi-chunk prompts).

Runs in-process on the single CPU device (the engines are host loops over
jit'd steps; no multi-device subprocess needed), so it is part of the
``--fast`` path.
"""

from __future__ import annotations

from typing import Iterator

from benchmarks.common import Row

# mixed-arrival trace tuned so decode compute (not arrival waiting)
# dominates: static pays batch formation + decode-to-the-slowest tail.
# prompt_len (16, 256) interleaves short and long prompts — the trace
# that exposes prefill head-of-line blocking and per-length compiles
TRACE = dict(requests=16, slots=4, prompt_len=(16, 256), max_new=(4, 48),
             arrival="poisson", rate=400.0, seed=0,
             prefill_chunk=64, max_prefill_per_step=2)
# --fast: same shape of comparison, smaller trace (the bench-smoke CI job
# runs every module fast; the dedicated serve-smoke job runs the full one)
TRACE_FAST = dict(requests=8, slots=2, prompt_len=(16, 128), max_new=(2, 24),
                  arrival="poisson", rate=400.0, seed=0,
                  prefill_chunk=32, max_prefill_per_step=2)


def rows(fast: bool = False) -> Iterator[Row]:
    from repro.launch.serve import run_traffic
    res = run_traffic("gemma-2b", smoke=True, engine="both",
                      parity_check=True, **(TRACE_FAST if fast else TRACE))

    for eng in ("static", "continuous", "continuous_monolithic",
                "continuous_paged"):
        if eng not in res:
            continue
        m = res[eng]
        us_per_tok = 1e6 / m["tok_s"]
        ttft = (f" ttft_p95_ms={m['ttft_p95_s']*1e3:.1f}"
                if "ttft_p95_s" in m else "")
        yield (f"serve_{eng}_us_per_tok", us_per_tok,
               f"tok_s={m['tok_s']:.1f} p50_ms={m['latency_p50_s']*1e3:.1f} "
               f"p95_ms={m['latency_p95_s']*1e3:.1f} "
               f"makespan_s={m['makespan_s']:.3f}{ttft}")

    spd = res["speedup_tok_s"]
    yield ("serve_continuous_speedup", spd,
           f"continuous/static tok_s on {res['requests']}-req "
           f"{res['arrival']} trace; verified="
           f"{res['continuous_faster_verified']}")
    if "ttft_p95_chunked_s" in res:
        yield ("serve_chunked_ttft_p95_ms", res["ttft_p95_chunked_s"] * 1e3,
               f"vs monolithic {res['ttft_p95_monolithic_s']*1e3:.1f}ms on "
               f"prompt_len={res['prompt_len']}; verified="
               f"{res['chunked_ttft_p95_improved']}")
        yield ("serve_prefill_compiles",
               res["continuous"]["prefill_compiles_total"],
               f"chunked total (monolithic="
               f"{res['continuous_monolithic']['prefill_compiles_total']:.0f} "
               f"for {res['distinct_prompt_lens']} distinct prompt lens); "
               f"prompt_len_independent="
               f"{res['prefill_compiles_prompt_len_independent']}")
    if "paged_max_concurrency" in res:
        yield ("serve_paged_bytes_per_token",
               res["paged_bytes_per_resident_token"],
               f"slot={res['slot_bytes_per_resident_token']:.0f} B/resident-"
               f"tok at equal HBM (block={res['block_size']} tok x "
               f"{res['paged_num_blocks']} blocks); token_identical="
               f"{res['paged_token_identical_trace']}")
        yield ("serve_paged_max_concurrency", res["paged_max_concurrency"],
               f"slot={res['slot_max_concurrency']:.0f} peak concurrent at "
               f"equal HBM; verified_more_concurrent="
               f"{res['paged_more_concurrent_verified']} hbm_within_budget="
               f"{res['paged_hbm_within_budget']}")
    if "spec_tok_s" in res:
        yield ("serve_spec_tok_s", res["spec_tok_s"],
               f"speculate_k={res['speculate_k']:.0f} "
               f"draft={res['draft_arch']} vs non-spec "
               f"{res['continuous_tok_s']:.1f} tok/s; token_identical="
               f"{res['spec_token_identical_trace']}")
        yield ("serve_spec_accepted_per_dispatch",
               res["spec_accepted_per_dispatch"],
               f"tokens emitted per verify dispatch (acceptance_rate="
               f"{res['spec_acceptance_rate']:.3f}); >1 means the fused "
               f"k-token verify amortized its dispatch")
    if "prefix_hit_rate" in res:
        pfx = res["prefix"]
        yield ("serve_prefix_hit_rate", res["prefix_hit_rate"],
               f"warm token hit rate on shared_prefix_len="
               f"{pfx['shared_prefix_len']} trace ({pfx['prefix_groups']} "
               f"groups, share_ratio={pfx['share_ratio']}); "
               f"token_identical={res['prefix_token_identical']}")
        yield ("serve_prefix_tokens_saved", res["prefill_tokens_saved"],
               f"prefill tokens skipped warm (dispatches_saved="
               f"{res['prefill_dispatches_saved']:.0f} cow_clones="
               f"{pfx['warm']['prefix_cow_clones']:.0f}); "
               f"ttft_p95_improved={res['prefix_ttft_p95_improved']}")
    yield ("serve_parity_greedy", 0.0,
           f"token_identical={res['parity_token_identical']} "
           f"(chunked ContinuousEngine vs StaticEngine, same-arrival "
           f"batch, prompt_len={res.get('parity_prompt_len')})")
    sched = res["continuous"]
    yield ("serve_admission_model_us", sched["modeled_admit_cost_us"],
           f"cell-queue eager_admits={int(sched['eager_admits'])} "
           f"deferred={int(sched['deferred'])} (protocol §3.2 chunked "
           f"handoff pricing)")
