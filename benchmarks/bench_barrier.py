"""Paper Fig. 4: barrier latency — point-to-point dissemination MPI_Barrier
vs the shared-atomics reimplementation (vs OpenMP-native).

Host wall times compare the two executable implementations; the alpha-model
projects both to the production thread counts (the paper's observation:
the pt2pt barrier pays lg(N) full message-queue round trips, the atomics
barrier one fused reduction)."""

from __future__ import annotations

import math

from benchmarks.common import Row, run_mp_case

ALPHA_MSG = 2.5e-7    # per-message envelope+enqueue+match (protocol model)
ALPHA_ATOMIC = 6e-8   # one shared-atomic round


def model_rows():
    out = []
    for n in (4, 16, 64, 256, 512):
        lg = max(1, math.ceil(math.log2(n)))
        t_msg = lg * ALPHA_MSG
        t_atomic = lg * ALPHA_ATOMIC   # tree of atomics ~ lg rounds too
        out.append((f"barrier_model_pt2pt_n{n}", t_msg * 1e6,
                    f"rounds={lg}"))
        out.append((f"barrier_model_atomic_n{n}", t_atomic * 1e6,
                    f"rounds={lg}"))
    return out


def rows(fast: bool = False):
    out = model_rows()
    if not fast:
        out += run_mp_case("barrier", ndev=8)
    return out
