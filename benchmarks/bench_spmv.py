"""Paper Fig. 6: PETSc MatMult (27-point stencil SpMV, 128³ cube) over the
threadcomm vs MPI-everywhere.

Host wall times over 1/2/4/8 unified ranks (correctness-checked against the
single-device oracle inside the case). The derived column for the model
rows reports the communication:compute byte ratio that makes the stencil
scale (one halo plane vs nz_local planes per rank)."""

from __future__ import annotations

from benchmarks.common import Row, run_mp_case


def model_rows():
    out = []
    n = 128
    for ranks in (1, 2, 4, 8, 16, 64, 256):
        nz = n // ranks if n % ranks == 0 else None
        if nz is None:
            continue
        halo_bytes = 2 * n * n * 4
        compute_flops = 27 * 2 * nz * n * n
        t_compute = compute_flops / 197e12
        t_halo = halo_bytes / 50e9
        out.append((f"spmv_model_ranks{ranks}_128cube",
                    (t_compute + t_halo) * 1e6,
                    f"halo/compute={t_halo / max(t_compute, 1e-12):.3f}"))
    return out


def rows(fast: bool = False):
    out = model_rows()
    if not fast:
        out += run_mp_case("spmv", ndev=8, args=(64,))
    return out
