"""Paper Fig. 5: array reduction — MPI_Reduce (binomial tree over the
threadcomm) vs the OpenMP-reduction-clause analogue (fused native psum).

The paper's result: with payload, the messaging abstraction matches or
beats the language construct because the tree moves each element lg(N)
times with full pipelining. We report host wall times for both executable
schedules plus the alpha-beta model across sizes."""

from __future__ import annotations

import math

from benchmarks.common import Row, run_mp_case
from repro.core.schedules import allreduce_cost


def model_rows():
    out = []
    for nbytes in (64, 1024, 16384, 262144):
        for n in (16, 256):
            t_tree = allreduce_cost(n, nbytes, alpha=2.5e-7,
                                    beta=1 / 12e9,
                                    schedule="reduce_bcast") / 2
            out.append((f"reduce_model_binomial_{nbytes}B_n{n}",
                        t_tree * 1e6, f"lg={math.ceil(math.log2(n))}"))
    return out


def rows(fast: bool = False):
    out = model_rows()
    if not fast:
        out += run_mp_case("reduce", ndev=8)
        out += run_mp_case("allreduce_schedules", ndev=8)
    return out
