"""Multi-device benchmark cases (run in a subprocess with N host devices).

Each case prints ``ROW,<name>,<us_per_call>,<derived>`` lines. Wall times
are CPU-host relative numbers (algorithmic comparison, not TPU latencies);
the TPU-projected numbers come from the alpha-beta models in the parent
bench modules.
"""

import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax
import jax.numpy as jnp
import numpy as np
from functools import partial
from jax.sharding import PartitionSpec as P

from benchmarks.common import time_fn
from repro.core.compat import make_mesh, shard_map


def _mesh(n):
    return make_mesh((n,), ("ranks",))


def case_barrier():
    """Fig. 4: barrier latency — dissemination-msg vs fused-atomic psum."""
    from repro.core import collectives as coll
    n = jax.device_count()
    mesh = _mesh(n)
    tok = jnp.arange(float(n))
    for mode in ("msg", "atomic"):
        fn = jax.jit(shard_map(
            lambda v: coll.barrier(v[0], "ranks", mode=mode)[None],
            mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
        us = time_fn(fn, tok, iters=20)
        print(f"ROW,barrier_{mode}_n{n},{us:.3f},host-wall")


def case_reduce():
    """Fig. 5: array reduce — binomial-tree schedule vs fused psum."""
    from repro.core import collectives as coll
    n = jax.device_count()
    mesh = _mesh(n)
    for nelem in (16, 256, 4096, 65536):
        x = jnp.arange(float(n * nelem)).reshape(n, nelem)
        for sched in ("binomial", "psum"):
            if sched == "binomial":
                f = lambda v: coll.reduce(v, "ranks", root=0,
                                          schedule="binomial")
            else:
                f = lambda v: coll.reduce(v, "ranks", schedule="psum")
            fn = jax.jit(shard_map(f, mesh=mesh, in_specs=P("ranks"),
                                       out_specs=P("ranks")))
            us = time_fn(fn, x, iters=10)
            print(f"ROW,reduce_{sched}_{nelem * 4}B_n{n},{us:.3f},host-wall")


def case_allreduce_schedules():
    """Allreduce schedule comparison (ring / recursive-doubling / psum /
    hierarchical over a 2x4 process-x-thread mesh)."""
    from repro.core import collectives as coll
    n = jax.device_count()
    mesh = _mesh(n)
    hmesh = make_mesh((2, n // 2), ("proc", "thread"))
    for nelem in (1024, 1 << 16):
        x = jnp.arange(float(n * nelem)).reshape(n, nelem)
        for sched in ("psum", "ring", "recursive_doubling"):
            fn = jax.jit(shard_map(
                lambda v, s=sched: coll.allreduce(v, "ranks", schedule=s),
                mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
            us = time_fn(fn, x, iters=10)
            print(f"ROW,allreduce_{sched}_{nelem * 4}B_n{n},{us:.3f},host-wall")
        xh = x.reshape(2, n // 2, nelem)
        fnh = jax.jit(shard_map(
            lambda v: coll.hierarchical_allreduce(
                v, process_axes=("proc",), thread_axes=("thread",)),
            mesh=hmesh, in_specs=P(("proc", "thread")),
            out_specs=P(("proc", "thread")), check_vma=False))
        us = time_fn(fnh, x, iters=10)
        print(f"ROW,allreduce_hierarchical_{nelem * 4}B_n{n},{us:.3f},host-wall")


def case_spmv():
    """Fig. 6: 27-point stencil MatMult scaling over threadcomm ranks."""
    from repro.apps.spmv import make_distributed_matmult, stencil_matmult_ref
    n_cube = int(sys.argv[2]) if len(sys.argv) > 2 else 64
    ndev = jax.device_count()
    x = jax.random.normal(jax.random.PRNGKey(0), (n_cube,) * 3)

    ref_fn = jax.jit(stencil_matmult_ref)
    us_ref = time_fn(ref_fn, x, iters=5)
    print(f"ROW,spmv_matmult_ranks1_{n_cube}cube,{us_ref:.3f},host-wall")

    for n_ranks in (2, 4, 8):
        if n_ranks > ndev or n_cube % n_ranks:
            continue
        mesh = _mesh(n_ranks)
        mm = make_distributed_matmult("ranks", n_ranks)
        fn = jax.jit(shard_map(mm, mesh=mesh, in_specs=P("ranks"),
                                   out_specs=P("ranks")))
        # correctness vs oracle, then timing
        y = fn(x)
        y_ref = ref_fn(x)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)
        us = time_fn(fn, x, iters=5)
        print(f"ROW,spmv_matmult_ranks{n_ranks}_{n_cube}cube,{us:.3f},"
              f"host-wall;verified")


def case_comm_schedules():
    """Unified Comm API: hierarchical allreduce as a sub-comm composition
    (reduce_scatter/allreduce/allgather and reduce/allreduce/bcast) vs the
    flat root-comm allreduce, plus the stream-ordered nonblocking
    pipeline — wall time AND numerics parity on every variant."""
    from repro.core.comm import threadcomm_init
    n = jax.device_count()
    mesh = make_mesh((2, n // 2), ("proc", "thread"))
    comm = threadcomm_init(mesh, process_axes=("proc",),
                           thread_axes=("thread",))
    comm.start()
    tcomm, pcomm = comm.thread_comm(), comm.process_comm()
    for nelem in (1024, 1 << 16):
        x = jnp.arange(float(n * nelem)).reshape(n, nelem)
        want = np.tile(np.asarray(x).sum(0), (n, 1))

        def bench(tag, fn):
            jf = jax.jit(shard_map(
                fn, mesh=mesh, in_specs=P(("proc", "thread")),
                out_specs=P(("proc", "thread")), check_vma=False))
            got = np.asarray(jf(x)).reshape(n, nelem)
            np.testing.assert_allclose(got, want, rtol=1e-5)
            us = time_fn(jf, x, iters=10)
            print(f"ROW,comm_{tag}_{nelem * 4}B_n{n},{us:.3f},"
                  f"host-wall;verified")

        bench("flat", lambda v: comm.allreduce(v))
        bench("hier", lambda v: comm.allreduce(v, schedule="hierarchical"))
        bench("hier_tree",
              lambda v: comm.allreduce(v, schedule="hierarchical_tree"))

        def stream_pipeline(v):
            flat = v.reshape(-1)
            with comm.stream("bench"):
                r1 = tcomm.ireduce_scatter(flat)
                r2 = pcomm.iallreduce(r1.wait())
                out = tcomm.iallgather(r2.wait()).wait()
            return out.reshape(v.shape)
        bench("istream_hier", stream_pipeline)
    comm.finish()
    comm.free()


def case_p2p_wall():
    """Fig. 3 (relative): ring sendrecv wall time, eager vs 1-copy padding."""
    from repro.core import p2p
    n = jax.device_count()
    mesh = _mesh(n)
    pairs = [(i, (i + 1) % n) for i in range(n)]
    for nbytes in (256, 4096, 65536, 1 << 20):
        nelem = max(1, nbytes // 4)
        x = jnp.arange(float(n * nelem)).reshape(n, nelem)
        for proto in ("eager", "one_copy"):
            fn = jax.jit(shard_map(
                lambda v, p=proto: p2p.send_recv(v, "ranks", pairs,
                                                 force_protocol=p)[0],
                mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks")))
            us = time_fn(fn, x, iters=10)
            print(f"ROW,p2p_{proto}_{nbytes}B_n{n},{us:.3f},host-wall")


CASES = {k[5:]: v for k, v in list(globals().items())
         if k.startswith("case_")}

if __name__ == "__main__":
    CASES[sys.argv[1]]()
