"""Beyond-paper: hierarchical (threadcomm) vs flat gradient sync on the
production multi-pod mesh — the paper's §4.2 insight generalized to the
pod/DCN hierarchy — now exercised through the unified ``Comm`` API
(sub-comm compositions + stream-ordered nonblocking pipeline).

Reports the alpha-beta model at production scale (2 pods × 256 chips),
the measured HLO slow-axis bytes ratio from the dry-run artifacts when the
grad-sync variants have been lowered (launch/dryrun.py --grad-sync), and
(without --fast) verified wall times of every Comm allreduce composition
from a multi-device subprocess."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import ROOT, Row, run_mp_case
from repro.core.schedules import (flat_allreduce_cost,
                                  hierarchical_allreduce_cost)


def model_rows():
    out = []
    # hymba-1.5b gradient sync: 1.5B f32 grads = 6.2GB
    for name, nbytes in (("hymba_grads", int(1.5e9 * 4)),
                         ("gemma_grads", int(2.5e9 * 4)),
                         ("step_metrics", 4096)):
        hier = hierarchical_allreduce_cost(
            2, 256, nbytes, alpha_fast=1e-6, beta_fast=1 / 50e9,
            alpha_slow=5e-6, beta_slow=1 / 6.25e9)
        flat = flat_allreduce_cost(512, nbytes, alpha_slow=5e-6,
                                   beta_slow=1 / 6.25e9)
        out.append((f"gradsync_model_hierarchical_{name}", hier * 1e6,
                    f"speedup_vs_flat={flat / hier:.1f}x"))
        out.append((f"gradsync_model_flat_{name}", flat * 1e6, ""))
    return out


def artifact_rows():
    """Measured collective bytes from lowered grad-sync variants."""
    out = []
    pat = os.path.join(ROOT, "experiments", "artifacts", "multi_pod",
                       "*train_4k*.json")
    for f in sorted(glob.glob(pat)):
        d = json.load(open(f))
        if "analysis" not in d:
            continue
        tot = d["analysis"]["collectives"]["total"]
        tag = os.path.basename(f).replace(".json", "")
        out.append((f"gradsync_hlo_{tag}",
                    d["analysis"]["terms"]["collective_s"] * 1e6,
                    f"coll_bytes={tot['operand_bytes']:.3g};"
                    f"ops={tot['executions']}"))
    return out


def rows(fast: bool = False):
    out = model_rows() + artifact_rows()
    if not fast:
        # Comm-API schedule comparison: flat vs hierarchical (sub-comm
        # composed) vs hierarchical_tree vs the iallreduce stream pipeline
        out += run_mp_case("comm_schedules", ndev=8)
    return out
