"""Paper Fig. 3: point-to-point latency / bandwidth — interthread
(threadcomm) eager + 1-copy vs interprocess (MPI-everywhere) eager + rndv.

Three layers of evidence:
  1. the calibrated protocol model (core/protocol.py) — reproduces the
     crossover structure of Fig. 3 (latency win for small eager messages,
     ~2× bandwidth win for large 1-copy messages);
  2. msgq Pallas kernel byte accounting (eager moves 2× the bytes);
  3. host wall time of the ppermute sendrecv per protocol (subprocess).
"""

from __future__ import annotations

from benchmarks.common import Row, run_mp_case
from repro.core import protocol
from repro.kernels.msgq.ops import copy_accounting

SIZES = [64, 256, 1024, 4096, 16384, 65536, 1 << 20, 1 << 22]


def rows(fast: bool = False):
    out = []
    for nbytes in SIZES:
        t_thread = protocol.interthread_latency(nbytes)
        t_proc = protocol.interprocess_latency(nbytes)
        proto = protocol.select_protocol(nbytes)
        bw_t = nbytes / t_thread / 1e9
        bw_p = nbytes / t_proc / 1e9
        out.append((f"p2p_model_interthread_{nbytes}B", t_thread * 1e6,
                    f"proto={proto};bw={bw_t:.2f}GB/s"))
        out.append((f"p2p_model_interprocess_{nbytes}B", t_proc * 1e6,
                    f"proto={protocol.select_protocol(nbytes, False)};"
                    f"bw={bw_p:.2f}GB/s"))
    # request-object overhead of the nonblocking API (Comm.isend): the
    # eager fast path skips request allocation entirely (paper §3.2)
    for nbytes in (64, 4096, 65536):
        ovh = protocol.request_overhead(nbytes)
        out.append((f"p2p_request_overhead_{nbytes}B", ovh * 1e6,
                    f"proto={protocol.select_protocol(nbytes)};"
                    f"skipped={ovh == 0.0}"))
    # kernel byte accounting (the mechanism behind the bandwidth gap)
    for nbytes in (4096, 1 << 20):
        e = copy_accounting(nbytes, "eager")
        o = copy_accounting(nbytes, "one_copy")
        out.append((f"msgq_bytes_eager_{nbytes}B", 0.0,
                    f"bytes_moved={e['bytes_moved']:.0f}"))
        out.append((f"msgq_bytes_one_copy_{nbytes}B", 0.0,
                    f"bytes_moved={o['bytes_moved']:.0f}"))
    if not fast:
        out += run_mp_case("p2p_wall", ndev=8)
    return out
