"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section comments). Pass
``--fast`` to skip the multi-device subprocess measurements (models and
artifact-derived rows only); pass ``--json PATH`` to also emit the rows as
a machine-readable artifact (e.g. ``BENCH_collectives.json``) so the perf
trajectory accumulates across commits (the CI workflow uploads it)."""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import traceback

# runnable both as `python -m benchmarks.run` and `python benchmarks/run.py`
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _ROOT not in sys.path:
    sys.path.insert(0, _ROOT)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess wall-time measurements")
    ap.add_argument("--only", default=None,
                    help="run a single bench module (p2p|barrier|reduce|"
                         "spmv|collectives|serve|fabric)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as JSON (e.g. "
                         "BENCH_collectives.json)")
    args = ap.parse_args()

    from benchmarks import (bench_barrier, bench_collectives, bench_fabric,
                            bench_p2p, bench_reduce, bench_serve, bench_spmv)
    modules = {
        "p2p": (bench_p2p, "paper Fig.3: p2p latency/bandwidth"),
        "barrier": (bench_barrier, "paper Fig.4: barrier latency"),
        "reduce": (bench_reduce, "paper Fig.5: reduce latency"),
        "spmv": (bench_spmv, "paper Fig.6: PETSc MatMult (27pt stencil)"),
        "collectives": (bench_collectives,
                        "beyond-paper: hierarchical vs flat grad sync, "
                        "Comm-API schedules"),
        "serve": (bench_serve,
                  "beyond-paper: continuous vs static serving on a "
                  "mixed-arrival trace (DESIGN.md §8)"),
        "fabric": (bench_fabric,
                   "beyond-paper: multi-rank serving fabric (replicated "
                   "vs disaggregated placement, KV-block migration — "
                   "DESIGN.md §10)"),
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    sections = {}
    for key, (mod, desc) in modules.items():
        print(f"# --- {key}: {desc} ---")
        try:
            rows = list(mod.rows(fast=args.fast))
        except Exception:
            failures += 1
            traceback.print_exc()
            continue
        sections[key] = [
            {"name": name, "us_per_call": us, "derived": derived}
            for name, us, derived in rows]
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived}")

    if args.json:
        payload = {
            "schema": "repro-bench-v1",
            "fast": args.fast,
            "platform": {"python": platform.python_version(),
                         "machine": platform.machine()},
            "sections": sections,
            "failures": failures,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} "
              f"({sum(len(v) for v in sections.values())} rows)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
