"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section comments). Pass
``--fast`` to skip the multi-device subprocess measurements (models and
artifact-derived rows only)."""

from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="skip subprocess wall-time measurements")
    ap.add_argument("--only", default=None,
                    help="run a single bench module (p2p|barrier|reduce|"
                         "spmv|collectives)")
    args = ap.parse_args()

    from benchmarks import (bench_barrier, bench_collectives, bench_p2p,
                            bench_reduce, bench_spmv)
    modules = {
        "p2p": (bench_p2p, "paper Fig.3: p2p latency/bandwidth"),
        "barrier": (bench_barrier, "paper Fig.4: barrier latency"),
        "reduce": (bench_reduce, "paper Fig.5: reduce latency"),
        "spmv": (bench_spmv, "paper Fig.6: PETSc MatMult (27pt stencil)"),
        "collectives": (bench_collectives,
                        "beyond-paper: hierarchical vs flat grad sync"),
    }
    if args.only:
        modules = {args.only: modules[args.only]}

    print("name,us_per_call,derived")
    failures = 0
    for key, (mod, desc) in modules.items():
        print(f"# --- {key}: {desc} ---")
        try:
            for name, us, derived in mod.rows(fast=args.fast):
                print(f"{name},{us:.3f},{derived}")
        except Exception:
            failures += 1
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
