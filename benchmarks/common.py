"""Benchmark helpers: wall timing + multi-device subprocess execution.

The main bench process keeps the single real CPU device (per the dry-run
isolation rule); collective benchmarks run named cases from
benchmarks/mp_bench.py in a subprocess with N host devices and emit
``ROW,<name>,<us>,<derived>`` lines that the parent collects.
"""

from __future__ import annotations

import os
import subprocess
import sys
import time
from typing import List, Tuple

import jax

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

Row = Tuple[str, float, str]


def time_fn(fn, *args, warmup: int = 2, iters: int = 5) -> float:
    """Median wall time (us) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def run_mp_case(case: str, ndev: int = 8, timeout: int = 900,
                args=()) -> List[Row]:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.mp_bench", case, *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"bench case {case} failed:\n{proc.stdout}\n"
                           f"{proc.stderr}")
    rows = []
    for line in proc.stdout.splitlines():
        if line.startswith("ROW,"):
            _, name, us, derived = line.split(",", 3)
            rows.append((name, float(us), derived))
    return rows


def emit(rows: List[Row]):
    for name, us, derived in rows:
        print(f"{name},{us:.3f},{derived}")
