"""Collectives through the unified ``Comm`` API: the paper's §4.2
comparisons plus the split/dup + nonblocking surface this repo adds.

Shows: derived sub-communicators (split by color, dup), collectives as
comm METHODS (dissemination vs atomic barrier, binomial reduce/bcast, ring
/ recursive-doubling allreduce), the hierarchical allreduce as an explicit
sub-comm composition (thread.reduce -> process.allreduce -> thread.bcast),
and request-based nonblocking overlap on a CommStream.

Run:  PYTHONPATH=src python examples/collectives_demo.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core import threadcomm_init
from repro.core.compat import make_mesh


def main():
    mesh = make_mesh((2, 4), ("proc", "thread"))
    root = threadcomm_init(mesh, process_axes=("proc",),
                           thread_axes=("thread",))
    n = root.size
    x = jnp.arange(float(n)) + 1.0

    with root.start():
        print(f"== comm: {root.num_processes} processes x "
              f"{root.threads_per_process} threads = {n} unified ranks ==")

        # ---- collectives are methods on the comm ----
        for mode in ("msg", "atomic"):
            tok = root.run(lambda v, m=mode: root.barrier(v[0], mode=m)[None],
                           x)
            print(f"barrier[{mode:6s}]  -> token {np.asarray(tok)[0]:.0f} "
                  f"(max over ranks = {n})")

        r = root.run(lambda v: root.reduce(v, root=0, schedule='binomial'), x)
        print(f"reduce(binomial) -> root holds {np.asarray(r)[0]:.0f} "
              f"(sum = {n * (n + 1) // 2})")

        b = root.run(lambda v: root.bcast(v, root=5), x)
        print(f"bcast(root=5)    -> all ranks hold "
              f"{set(np.asarray(b).tolist())}")

        for sched in ("psum", "ring", "recursive_doubling",
                      "hierarchical", "hierarchical_tree"):
            out = root.run(lambda v, s=sched: root.allreduce(v, schedule=s), x)
            ok = np.allclose(np.asarray(out), n * (n + 1) / 2)
            print(f"allreduce[{sched:18s}] -> {'OK' if ok else 'MISMATCH'}")

        # ---- derived sub-comms are load-bearing ----
        # split by process color: per-process thread comms (fast domain)
        tcomm = root.split([rr // 4 for rr in range(n)])
        pcomm = root.process_comm()
        per_proc = root.run(lambda v: tcomm.allreduce(v), x)
        print("split(thread).allreduce -> per-process sums",
              sorted(set(np.asarray(per_proc).tolist())))
        # the hierarchical schedule, spelled out as the composition
        comp = root.run(
            lambda v: tcomm.bcast(pcomm.allreduce(
                tcomm.reduce(v, root=0)), root=0), x)
        print("thread.reduce -> process.allreduce -> thread.bcast:",
              float(np.asarray(comp)[0]), f"(= flat {n * (n + 1) // 2})")
        # a non-grid split still works (generic merged-ring path)
        parity = root.split([rr % 2 for rr in range(n)])
        pp = root.run(lambda v: parity.allreduce(v), x)
        print("split(parity).allreduce ->",
              sorted(set(np.asarray(pp).tolist())), "(odd/even rank sums)")

        # ---- nonblocking requests on a stream ----
        def overlapped(v):
            with root.stream("s0"):
                r1 = tcomm.iallreduce(v)       # fast domain, in flight
                r2 = pcomm.iallreduce(r1.wait())   # slow domain, ordered
            return r2.wait()
        nb = root.run(overlapped, x)
        print("stream-ordered iallreduce pipeline ->",
              float(np.asarray(nb)[0]), f"(= flat {n * (n + 1) // 2})")

        # one unified barrier spans processes AND threads (the paper's
        # point: MPI+Threads needs omp-barrier + MPI_Barrier + omp-barrier)
        root.run(lambda v: root.barrier(v[0], mode="msg")[None], x)
        print("single unified barrier across processes AND threads: OK")
    root.free()


if __name__ == "__main__":
    main()
