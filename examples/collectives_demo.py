"""Collectives over a threadcomm: the paper's §4.2 comparisons, executable.

Shows: dissemination barrier (pt2pt) vs fused-atomic barrier, binomial
MPI_Reduce, binomial bcast, ring / recursive-doubling / hierarchical
allreduce — all over the unified N×M rank space, all verified against the
fused result.

Run:  PYTHONPATH=src python examples/collectives_demo.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import collectives as coll
from repro.core import threadcomm_init


def main():
    mesh = jax.make_mesh((2, 4), ("proc", "thread"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
    tc = threadcomm_init(mesh, process_axes=("proc",),
                         thread_axes=("thread",))
    n = tc.size
    x = jnp.arange(float(n)) + 1.0

    with tc.start():
        print(f"== threadcomm: {tc.num_processes} processes x "
              f"{tc.threads_per_process} threads = {n} ranks ==")

        for mode in ("msg", "atomic"):
            tok = tc.run(lambda v, m=mode: tc.barrier(v[0], mode=m)[None], x)
            print(f"barrier[{mode:6s}]  -> token {np.asarray(tok)[0]:.0f} "
                  f"(max over ranks = {n})")

        r = tc.run(lambda v: tc.reduce(v, root=0, schedule='binomial'), x)
        print(f"reduce(binomial) -> root holds {np.asarray(r)[0]:.0f} "
              f"(sum = {n * (n + 1) // 2})")

        b = tc.run(lambda v: tc.bcast(v, root=5), x)
        print(f"bcast(root=5)    -> all ranks hold "
              f"{set(np.asarray(b).tolist())}")

        for sched in ("psum", "ring", "recursive_doubling", "hierarchical"):
            out = tc.run(lambda v, s=sched: tc.allreduce(v, schedule=s), x)
            ok = np.allclose(np.asarray(out), n * (n + 1) / 2)
            print(f"allreduce[{sched:18s}] -> {'OK' if ok else 'MISMATCH'}")

        # the paper's global-barrier point: ONE call spans both levels
        # (MPI+Threads needs omp-barrier + MPI_Barrier + omp-barrier)
        tok = tc.run(lambda v: tc.barrier(v[0], mode="msg")[None], x)
        print("single unified barrier across processes AND threads: OK")
    tc.free()


if __name__ == "__main__":
    main()
