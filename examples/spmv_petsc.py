"""PETSc case study (paper §4.3): distributed MatMult + CG inside a
threadcomm "parallel region".

Mirrors the paper's Listing 5: init the threadcomm outside the region,
create the distributed operator inside it, run parallel MatMult + a few CG
iterations (dot products = threadcomm allreduces, halo exchange = p2p),
verify against the single-device oracle, and tear down in order (objects
die before finish — the threadcomm lifetime rule).

Run:  PYTHONPATH=src python examples/spmv_petsc.py [--n 64] [--iters 10]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.apps.spmv import (cg_solve_ref, make_distributed_matmult,
                             stencil_matmult_ref)
from repro.core import threadcomm_init
from repro.core.compat import make_mesh, shard_map


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=64)
    ap.add_argument("--iters", type=int, default=10)
    args = ap.parse_args()
    n = args.n

    mesh = make_mesh((2, 4), ("proc", "thread"))
    tc = threadcomm_init(mesh, process_axes=("proc",),
                         thread_axes=("thread",))
    axes = tc.unified_axes
    ranks = tc.size
    assert n % ranks == 0

    b = jax.random.normal(jax.random.PRNGKey(0), (n, n, n))

    with tc.start():                          # the "parallel region"
        matmult = make_distributed_matmult(axes, ranks)

        def cg(b_local):
            """Distributed CG: MatMult with halo p2p; dots via allreduce."""
            def dot(u, v):
                return lax.psum(jnp.vdot(u, v), axes)

            x = jnp.zeros_like(b_local)
            r = b_local - matmult(x)
            p = r
            rs = dot(r, r)

            def body(carry, _):
                x, r, p, rs = carry
                ap_ = matmult(p)
                alpha = rs / dot(p, ap_)
                x = x + alpha * p
                r = r - alpha * ap_
                rs_new = dot(r, r)
                p = r + (rs_new / rs) * p
                return (x, r, p, rs_new), rs_new

            (x, r, p, rs), hist = lax.scan(body, (x, r, p, rs), None,
                                           length=args.iters)
            return x, hist

        run = jax.jit(shard_map(cg, mesh=mesh,
                                    in_specs=P(axes),
                                    out_specs=(P(axes), P()),
                                    check_vma=False))
        t0 = time.perf_counter()
        x, hist = run(b)
        x.block_until_ready()
        dt = time.perf_counter() - t0
        print(f"CG({args.iters}) over {ranks} unified ranks on "
              f"{n}^3 cube: {dt * 1e3:.1f} ms")
        print("residual history:",
              [f"{float(v):.3e}" for v in np.asarray(hist)[:5]], "...")

        x_ref = cg_solve_ref(b, iters=args.iters)
        err = float(jnp.max(jnp.abs(x - x_ref)))
        print(f"max |x - x_ref| = {err:.3e}",
              "(OK)" if err < 1e-3 else "(MISMATCH)")

        y = jax.jit(shard_map(matmult, mesh=mesh, in_specs=P(axes),
                                  out_specs=P(axes)))(b)
        err_mm = float(jnp.max(jnp.abs(y - stencil_matmult_ref(b))))
        print(f"MatMult max err vs oracle = {err_mm:.3e}",
              "(OK)" if err_mm < 1e-3 else "(MISMATCH)")
    tc.free()


if __name__ == "__main__":
    main()
