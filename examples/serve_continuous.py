"""Continuous-batching serving demo against the threadcomm substrate.

Requests stream in on a Poisson trace with mixed prompt lengths; the
cell-queue scheduler admits them against the paper's bounded cell pool
(eager buffering for small prompts, rendezvous deferral for large ones),
prompts *stream into their cache in fixed-size chunks* interleaved with
decode micro-steps (rendezvous-style chunked prefill — long prompts
never stall in-flight decodes, and the chunk jit never recompiles for a
new prompt length), the KV cache is *paged*: fixed-size blocks leased
from one global pool through per-request block tables, admission gated
on free blocks (DESIGN.md §9), and prefill/decode micro-steps are
ordered on two distinct ``CommStream``s of a root threadcomm — the
serving substrate of DESIGN.md §8–§9 in ~60 lines.

Run:  PYTHONPATH=src python examples/serve_continuous.py
"""

import jax
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import threadcomm_init
from repro.core.compat import make_mesh
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import (CellQueueScheduler, ContinuousEngine, ServeRequest,
                         StaticEngine, make_trace)

SLOTS, PROMPTS, REQUESTS, CHUNK = 4, (16, 48), 12, 16


def main():
    cfg = get_smoke_config("gemma-2b")
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       remat=False, loss_chunk=64, attn_chunk_threshold=4096)
    model = build_model(cfg, tcfg, ServeConfig(), tp=1)
    params = model.init(jax.random.PRNGKey(0))

    # serving threadcomm: prefill and decode get their own MPIX streams
    mesh = make_mesh((1,), ("ranks",))
    root = threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))
    root.start()

    eng = ContinuousEngine(model, params, cache_len=80, num_slots=SLOTS,
                           comm=root, prefill_chunk=CHUNK,
                           max_prefill_per_step=2,
                           kv_layout="paged", block_size=16,
                           scheduler=CellQueueScheduler(
                               num_cells=8, prefill_chunk_bytes=4 * CHUNK,
                               block_bytes=4 * 16))
    trace = make_trace(REQUESTS, prompt_len=PROMPTS, max_new=(4, 24), seed=0)
    reqs = []
    for rid, entry in enumerate(trace):
        batch = make_synthetic_batch(cfg, 1, entry.prompt_len,
                                     seed=100 + rid, compute_dtype="float32")
        req = ServeRequest(rid=rid, batch={"tokens": np.asarray(batch["tokens"])},
                           max_new_tokens=entry.max_new,
                           arrival=entry.arrival)
        reqs.append(req)
        where = eng.submit(req, now=entry.arrival)
        print(f" req {rid:2d} arrive {entry.arrival * 1e3:6.1f}ms "
              f"prompt={entry.prompt_len:3d} "
              f"max_new={entry.max_new:2d} -> {where}")

    steps = 0
    while not eng.idle:
        done = eng.step(now=float(steps))
        steps += 1
        for r in done:
            print(f"   finished req {r.rid:2d} after {r.generated:2d} "
                  f"tokens, {r.prefill_chunks} prefill chunks "
                  f"(micro-step {steps}, live={eng.num_active}, "
                  f"prefilling={eng.num_prefilling}, "
                  f"free_blocks={eng.kv.num_free_blocks})")
    print(f" drained {len(reqs)} requests in {steps} micro-steps over "
          f"{eng.kv.pool.num_blocks} KV blocks / {SLOTS} rows "
          f"(peak {eng.peak_live} concurrent, {eng.prefill_compiles} "
          f"prefill compile(s) for {len(set(PROMPTS))} prompt lengths)")

    # greedy parity against the static baseline (same-arrival batch of
    # the LONG prompts: a multi-chunk deposit, still token-identical)
    batch = make_synthetic_batch(cfg, SLOTS, max(PROMPTS),
                                 compute_dtype="float32")
    prompt = {"tokens": np.asarray(batch["tokens"])}
    static = StaticEngine(model, params, cache_len=80).generate(prompt, 8)
    cont = ContinuousEngine(model, params, cache_len=80, num_slots=SLOTS,
                            prefill_chunk=CHUNK).generate(prompt, 8)
    paged = ContinuousEngine(model, params, cache_len=80, num_slots=SLOTS,
                             prefill_chunk=CHUNK, kv_layout="paged",
                             block_size=16).generate(prompt, 8)
    print(" parity vs StaticEngine:", bool(np.array_equal(static, cont)),
          "paged:", bool(np.array_equal(static, paged)))

    root.finish()
    root.free()
    print("done.")


if __name__ == "__main__":
    main()
