"""Multi-rank serving fabric demo (DESIGN.md §10): the same mixed
short/long greedy trace through a single paged ContinuousEngine, a
2-rank replicated fabric (join-shortest-queue data parallelism), and a
prefill/decode-disaggregated fabric whose finished prompts migrate
block-by-block over the request-based KV transport.

Run on CPU:
  PYTHONPATH=src python examples/serve_fabric.py
"""

import jax
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import (ContinuousEngine, ServeRequest, ServingFabric,
                         make_trace)


def requests_for(cfg, trace, seed=0):
    out = []
    for rid, e in enumerate(trace):
        b = make_synthetic_batch(cfg, 1, e.prompt_len, seed=seed + rid,
                                 compute_dtype="float32")
        out.append(ServeRequest(rid=rid,
                                batch={"tokens": np.asarray(b["tokens"])},
                                max_new_tokens=e.max_new,
                                arrival=e.arrival, seed=seed))
    return out


def drain(target, reqs):
    for r in reqs:
        target.submit(r, 0.0)
    steps = 0
    while not target.idle:
        target.step(0.0)
        steps += 1
    return steps


def main():
    cfg = get_smoke_config("gemma-2b")
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       remat=False, loss_chunk=64)
    model = build_model(cfg, tcfg, ServeConfig(), tp=1)
    params = model.init(jax.random.PRNGKey(0))
    cache_len = 64 + 16

    trace = make_trace(8, prompt_len=(16, 64), max_new=(4, 16),
                       arrival="all", seed=0)

    single = ContinuousEngine(model, params, cache_len=cache_len,
                              num_slots=4, prefill_chunk=16,
                              kv_layout="paged", block_size=8)
    base = requests_for(cfg, trace)
    print(f"single engine: drained in {drain(single, base)} steps")

    for placement in ("replicated", "disagg"):
        fab = ServingFabric(model, params, ranks=2, placement=placement,
                            cache_len=cache_len, slots_per_rank=4,
                            prefill_chunk=16, block_size=8)
        reqs = requests_for(cfg, trace)
        steps = drain(fab, reqs)
        ident = all(np.array_equal(a.output[:a.generated],
                                   b.output[:b.generated])
                    for a, b in zip(base, reqs))
        st = fab.stats()
        print(f"{placement:>10}: {steps} fabric steps, "
              f"token_identical={ident}")
        for row in st["per_rank"]:
            print(f"            rank {row['rank']} [{row['role']}] "
                  f"util={row['utilization']:.2f} "
                  f"tokens={row['tokens']:.0f}")
        if "n_migrations" in st:
            print(f"            kv_migration: {st['n_migrations']:.0f} "
                  f"handoffs, {st['blocks_moved']:.0f} blocks, "
                  f"{st['kv_migration_modeled_s']*1e6:.1f}us modeled")
        fab.close()


if __name__ == "__main__":
    main()
