"""End-to-end training driver: data pipeline → model → explicit-threadcomm
or spmd trainer → checkpoints → resume.

Presets:
  demo (default): ~13M-param llama-style LM, a few hundred steps on CPU in
                  minutes — loss visibly decreases on the structured
                  synthetic stream.
  100m:           ~124M params (the assignment's e2e scale; hours on this
                  single-core CPU container, minutes on real hardware).

Run:  PYTHONPATH=src python examples/train_lm.py [--preset demo]
          [--steps 200] [--grad-sync threadcomm|flat|spmd] [--resume]
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding

from repro.config import MeshConfig, ModelConfig, TrainConfig, ServeConfig
from repro.data import SyntheticPipeline
from repro.core.compat import make_mesh
from repro.dist.sharding import batch_pspec
from repro.models.registry import build_model
from repro.train import checkpoint as ckpt
from repro.train.trainer import init_train_state, make_train_step
from repro.train.explicit import init_explicit_state

PRESETS = {
    "demo": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                 head_dim=64, d_ff=1024, vocab_size=4096,
                 batch=8, seq=128),
    "100m": dict(num_layers=12, d_model=640, num_heads=10, num_kv_heads=5,
                 head_dim=64, d_ff=2560, vocab_size=32000,
                 batch=16, seq=512),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="demo", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--grad-sync", default="threadcomm",
                    choices=["spmd", "threadcomm", "flat"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    p = PRESETS[args.preset]
    cfg = ModelConfig(
        name=f"llama-{args.preset}", family="dense", block="dense",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"])
    print(f"model: {cfg.name}  params={cfg.param_count() / 1e6:.1f}M")

    mesh_cfg = MeshConfig(shape=(2, 2, 2),
                          axis_names=("pod", "data", "model"),
                          process_axes=("pod",))
    mesh = make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       learning_rate=3e-3, warmup_steps=20,
                       total_steps=max(args.steps, 100), grad_sync=args.grad_sync,
                       remat=False, loss_chunk=64, attn_chunk_threshold=256)
    model = build_model(cfg, tcfg, ServeConfig(), tp=2)
    pipe = SyntheticPipeline(cfg, batch=p["batch"], seq_len=p["seq"], seed=0)
    b_shard = NamedSharding(mesh, batch_pspec(mesh_cfg))

    if args.grad_sync == "spmd":
        state = init_train_state(model, jax.random.PRNGKey(0))
        step_fn = jax.jit(make_train_step(model, mesh_cfg, tcfg))
    else:
        state = init_explicit_state(model, jax.random.PRNGKey(0),
                                    dp=mesh_cfg.dp)
        step_fn = make_train_step(model, mesh_cfg, tcfg, mesh=mesh)

    start = 0
    if args.resume and ckpt.latest_step(args.ckpt_dir) is not None:
        state, start, extra = ckpt.restore(args.ckpt_dir, state)
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jax.device_put(jnp.asarray(v), b_shard)
                 for k, v in pipe.get_batch(i).items()}
        state, metrics = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"gnorm {float(metrics['grad_norm']):.3f}  "
                  f"({(time.time() - t0):.1f}s)")
        if (i + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, i + 1, state,
                      extra=pipe.state_dict(i + 1), keep=2)
    print("final loss:", float(metrics["loss"]))


if __name__ == "__main__":
    main()
