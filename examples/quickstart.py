"""Quickstart: the paper's Listing 1/2 in JAX.

The paper launches 2 MPI processes × 4 OpenMP threads and lets every thread
print its unified threadcomm rank (Rank i / 8). Here: 2 "process" mesh rows
× 4 "thread" mesh columns of host devices.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import threadcomm_init

NT = 4  # threads per process (paper's #define NT 4)


def main():
    mesh = jax.make_mesh((2, NT), ("proc", "thread"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)

    # MPIX_Threadcomm_init(MPI_COMM_WORLD, NT, &threadcomm)
    tc = threadcomm_init(mesh, process_axes=("proc",),
                         thread_axes=("thread",), num_threads=NT)

    with tc.start():                       # MPIX_Threadcomm_start
        ranks = tc.run(
            lambda x: x + tc.device_rank().astype(jnp.float32),
            jnp.zeros(tc.size))
        for r in np.asarray(ranks, dtype=int):
            print(f" Rank {r} / {tc.size}")

        # MPI operations over the threadcomm: a unified allreduce
        total = tc.run(lambda v: tc.allreduce(v, schedule="psum"),
                       jnp.arange(float(tc.size)))
        print(f" Allreduce over {tc.size} unified ranks:",
              float(np.asarray(total)[0]), "(expected",
              sum(range(tc.size)), ")")
    # MPIX_Threadcomm_finish at context exit
    tc.free()                              # MPIX_Threadcomm_free
    print("done.")


if __name__ == "__main__":
    main()
