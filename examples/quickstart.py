"""Quickstart: the unified ``Comm`` API in 40 lines.

The paper fuses 2 MPI processes × 4 OpenMP threads into one communicator of
8 unified ranks. Here the "processes" are 2 mesh rows and the "threads" 4
mesh columns of host devices — and the modern surface is one ``Comm``
object you derive sub-communicators from and issue nonblocking requests on:

    root.split / root.dup / root.thread_comm / root.process_comm
    req = comm.iallreduce(x);  ... overlap ...  ;  req.wait()

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax.numpy as jnp
import numpy as np

from repro.core import threadcomm_init
from repro.core.compat import make_mesh

NT = 4  # threads per process (paper's #define NT 4)


def main():
    mesh = make_mesh((2, NT), ("proc", "thread"))

    # MPIX_Threadcomm_init(MPI_COMM_WORLD, NT, &threadcomm)
    root = threadcomm_init(mesh, process_axes=("proc",),
                           thread_axes=("thread",), num_threads=NT)

    with root.start():                     # MPIX_Threadcomm_start
        ranks = root.run(
            lambda x: x + root.device_rank().astype(jnp.float32),
            jnp.zeros(root.size))
        for r in np.asarray(ranks, dtype=int):
            print(f" Rank {r} / {root.size}")

        # derive sub-communicators: the fast (intra-process) domain via
        # split — color = process index — and the slow domain for free
        tcomm = root.split([r // NT for r in range(root.size)])
        pcomm = root.process_comm()
        print(f" split -> {tcomm.size}-rank thread comms "
              f"x{len(tcomm.families())}, {pcomm.size}-rank process comms")
        print(f" rank 2 of process-1's thread comm is unified rank "
              f"{tcomm.translate(2, family=1)}")

        # nonblocking allreduce: a Request you overlap compute with
        def overlapped(v):
            with root.stream("grad"):
                req = root.iallreduce(v)   # issued on the "grad" stream
            local = v * 2.0                # overlaps the collective
            return req.wait() + 0.0 * local
        total = root.run(overlapped, jnp.arange(float(root.size)))
        print(f" iallreduce over {root.size} unified ranks:",
              float(np.asarray(total)[0]), "(expected",
              sum(range(root.size)), ")")

        # the two-level hierarchical schedule IS a sub-comm composition:
        # thread.reduce_scatter -> process.allreduce -> thread.allgather
        h = root.run(lambda v: root.allreduce(v, schedule="hierarchical"),
                     jnp.arange(float(root.size)))
        print(" hierarchical (sub-comm composed) allreduce:",
              float(np.asarray(h)[0]))
    # MPIX_Threadcomm_finish at context exit — every derived comm/request
    # above is now invalid (activation-window rule, paper §2)
    root.free()                            # MPIX_Threadcomm_free
    print("done.")


if __name__ == "__main__":
    main()
