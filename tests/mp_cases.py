"""Multi-device test cases, executed in a subprocess with
XLA_FLAGS=--xla_force_host_platform_device_count=N (see tests/helpers.py).

Each case asserts internally and prints CASE-OK on success.
"""

import os
import sys

if __name__ == "__main__" and "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
from functools import partial

from repro.core.compat import make_mesh, shard_map


def _flat_mesh(n=8):
    return make_mesh((n,), ("ranks",))


def _hier_mesh(n=2, m=4):
    return make_mesh((n, m), ("proc", "thread"))


# ---------------------------------------------------------------------------

def case_collectives_flat():
    from repro.core import collectives as coll
    n = 8
    mesh = _flat_mesh(n)
    x = jnp.arange(n, dtype=jnp.float32) + 1.0          # rank r holds r+1

    def run(fn, inp=x, out_specs=P("ranks")):
        return shard_map(fn, mesh=mesh, in_specs=P("ranks"),
                             out_specs=out_specs)(inp)

    # barrier (msg): output token must be max over all ranks
    tok = run(lambda v: coll.barrier(v[0], "ranks", mode="msg")[None])
    assert np.allclose(np.asarray(tok), n), tok
    tok = run(lambda v: coll.barrier(v[0], "ranks", mode="atomic")[None])
    assert np.allclose(np.asarray(tok), n), tok

    # reduce (binomial) to root 0 and root 3
    total = float(n * (n + 1) / 2)
    for root in (0, 3):
        r = run(lambda v: coll.reduce(v, "ranks", root=root,
                                      schedule="binomial"))
        assert np.asarray(r)[root] == total, (root, r)

    # bcast from root 5: everyone ends with 6.0
    b = run(lambda v: coll.bcast(v, "ranks", root=5))
    assert np.allclose(np.asarray(b), 6.0), b

    # allreduce schedules agree with psum
    for schedule in ("psum", "recursive_doubling", "ring", "reduce_bcast"):
        big = jnp.arange(n * 24, dtype=jnp.float32).reshape(n, 24)
        out = shard_map(
            lambda v: coll.allreduce(v, "ranks", schedule=schedule),
            mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"))(big)
        want = np.tile(np.asarray(big).reshape(n, 24).sum(0), (n, 1))
        got = np.asarray(out).reshape(n, 24)
        assert np.allclose(got, want, rtol=1e-5), (schedule, got[:, :4])

    # allgather / reduce_scatter round trip == psum
    vec = jnp.arange(n * 4, dtype=jnp.float32)
    rs_ag = shard_map(
        lambda v: coll.allgather(coll.reduce_scatter(v, "ranks"), "ranks"),
        mesh=mesh, in_specs=P(None), out_specs=P(None), check_vma=False)(vec)
    assert np.allclose(np.asarray(rs_ag), np.asarray(vec) * n)

    # alltoall: transpose of rank/chunk grid
    mat = jnp.arange(n * n, dtype=jnp.float32).reshape(n, n)
    a2a = shard_map(
        lambda v: coll.alltoall(v.reshape(n, 1), "ranks").reshape(1, n),
        mesh=mesh, in_specs=P("ranks"), out_specs=P("ranks"))(mat)
    assert np.allclose(np.asarray(a2a), np.asarray(mat).T)

    # sendrecv: explicit pairs (ring shift by 2)
    pairs = [(i, (i + 2) % n) for i in range(n)]
    sr = shard_map(lambda v: coll.sendrecv(v, "ranks", pairs),
                       mesh=mesh, in_specs=P("ranks"),
                       out_specs=P("ranks"))(x)
    want = np.roll(np.asarray(x), 2)
    assert np.allclose(np.asarray(sr), want), sr
    print("CASE-OK")


def case_threadcomm_unified():
    from repro.core import threadcomm_init, ThreadCommError
    from repro.core import collectives as coll
    n_proc, m_thread = 2, 4
    mesh = _hier_mesh(n_proc, m_thread)
    tc = threadcomm_init(mesh, process_axes=("proc",),
                         thread_axes=("thread",), num_threads=m_thread)
    assert tc.size == n_proc * m_thread
    assert tc.num_processes == n_proc and tc.threads_per_process == m_thread

    # process-major rank ordering (paper §2): rank = proc*M + thread
    assert tc.rank_of({"proc": 1, "thread": 2}) == 6
    assert tc.coords_of(6) == {"thread": 2, "proc": 1}
    assert tc.process_of(5) == 1

    # inactive comm refuses to communicate
    try:
        tc.allreduce(jnp.ones(4))
        raise SystemExit("inactive comm should have raised")
    except ThreadCommError:
        pass

    with tc.start():
        # Listing 1/2 reproduction: every device reports rank/size
        ranks = tc.run(lambda x: x + tc.device_rank().astype(jnp.float32),
                       jnp.zeros(tc.size))
        assert np.allclose(np.sort(np.asarray(ranks)), np.arange(tc.size))

        # unified flat allreduce == psum over all axes
        x = jnp.arange(tc.size, dtype=jnp.float32)
        out = tc.run(lambda v: tc.allreduce(v, schedule="recursive_doubling"),
                     x)
        assert np.allclose(np.asarray(out), np.asarray(x).sum())

        # hierarchical == flat (numerics), vector length coprime to M
        vec = jnp.arange(tc.size * 13, dtype=jnp.float32).reshape(tc.size, 13)
        h = tc.run(lambda v: tc.allreduce(v, schedule="hierarchical"), vec)
        f = tc.run(lambda v: tc.allreduce(v, schedule="psum"), vec)
        assert np.allclose(np.asarray(h), np.asarray(f), rtol=1e-5)

        g = tc.group(list(range(4)))
        assert g.size == 4 and g.translate(2) == 2
        tc.set_attr("petsc", 42)
        assert tc.get_attr("petsc") == 42

    # derived objects die at finish (paper lifetime rule)
    with tc.start():
        try:
            g.size
            raise SystemExit("stale group should have raised")
        except ThreadCommError:
            pass
        assert tc.get_attr("petsc") is None

    # nested start forbidden; free-while-active forbidden
    with tc.start():
        try:
            tc.start().__enter__()
            raise SystemExit("nested start should have raised")
        except ThreadCommError:
            pass
    tc.free()
    try:
        tc.allreduce(jnp.ones(3))
        raise SystemExit("freed comm should have raised")
    except ThreadCommError:
        pass
    print("CASE-OK")


def case_p2p_protocols():
    from repro.core import p2p
    n = 8
    mesh = _flat_mesh(n)
    pairs = [(i, (i + 1) % n) for i in range(n)]

    for elems, want_proto in ((64, "eager_fast"), (1024, "eager_fast"),
                              (1 << 16, "one_copy")):
        x = jnp.arange(n * elems, dtype=jnp.float32).reshape(n, elems)

        def f(v):
            recv, _ = p2p.send_recv(v, "ranks", pairs)
            return recv

        out = shard_map(f, mesh=mesh, in_specs=P("ranks"),
                            out_specs=P("ranks"))(x)
        want = np.roll(np.asarray(x), 1, axis=0)
        assert np.allclose(np.asarray(out), want), elems
        from repro.core import protocol
        assert protocol.select_protocol(elems * 4) == want_proto, elems

    # halo exchange
    x = jnp.arange(n * 4, dtype=jnp.float32).reshape(n, 4)

    def g(v):
        fl, fr = p2p.halo_exchange_1d(v, "ranks", n)
        return jnp.concatenate([fl, fr], 0)

    out = shard_map(g, mesh=mesh, in_specs=P("ranks"),
                        out_specs=P("ranks"))(x)
    out = np.asarray(out).reshape(n, 2, 4)
    xs = np.asarray(x).reshape(n, 1, 4)
    for i in range(n):
        assert np.allclose(out[i, 0], xs[(i - 1) % n, -1])  # from left
        assert np.allclose(out[i, 1], xs[(i + 1) % n, 0])   # from right
    print("CASE-OK")


def case_hierarchical_collective_bytes():
    """Hierarchical allreduce must emit smaller inter-process (slow-axis)
    collectives than flat: check the lowered HLO collective structure."""
    from repro.core import collectives as coll
    mesh = _hier_mesh(2, 4)
    nbytes = 4 * 1024
    x = jnp.zeros(8 * nbytes // 4, jnp.float32)

    def flat(v):
        return lax.psum(v, ("proc", "thread"))

    def hier(v):
        return coll.hierarchical_allreduce(v, process_axes=("proc",),
                                           thread_axes=("thread",))

    def hlo(fn):
        return jax.jit(shard_map(fn, mesh=mesh, in_specs=P(None),
                                     out_specs=P(None), check_vma=False)
                       ).lower(x).compile().as_text()

    flat_txt, hier_txt = hlo(flat), hlo(hier)
    assert "all-reduce" in flat_txt
    assert "reduce-scatter" in hier_txt and "all-gather" in hier_txt
    print("CASE-OK")


def case_grad_sync_parity():
    """spmd / threadcomm / flat grad-sync modes must produce the same
    training trajectory (they differ only in collective schedule)."""
    from repro.config import MeshConfig, TrainConfig, ServeConfig
    from repro.configs import get_smoke_config
    from repro.data import SyntheticPipeline
    from repro.models.registry import build_model
    from repro.train.trainer import init_train_state, make_train_step
    from repro.dist.sharding import batch_pspec
    from jax.sharding import NamedSharding

    cfg = get_smoke_config("yi-9b")
    mesh_cfg = MeshConfig(shape=(2, 2, 2),
                          axis_names=("pod", "data", "model"),
                          process_axes=("pod",))
    mesh = make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
    pipe = SyntheticPipeline(cfg, batch=8, seq_len=16, seed=0)
    b_shard = NamedSharding(mesh, batch_pspec(mesh_cfg))

    from repro.train.explicit import init_explicit_state

    losses = {}
    for mode in ("spmd", "threadcomm", "flat"):
        tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                           loss_chunk=16, attn_chunk_threshold=64,
                           remat=False, grad_sync=mode, learning_rate=1e-2,
                           warmup_steps=1, total_steps=10)
        model = build_model(cfg, tcfg, ServeConfig(), tp=2)
        if mode == "spmd":
            state = init_train_state(model, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(model, mesh_cfg, tcfg))
        else:
            state = init_explicit_state(model, jax.random.PRNGKey(0), dp=4)
            step = make_train_step(model, mesh_cfg, tcfg, mesh=mesh)
        ls = []
        for i in range(3):
            batch = {k: jax.device_put(jnp.asarray(v), b_shard)
                     for k, v in pipe.get_batch(i).items()}
            state, metrics = step(state, batch)
            ls.append(float(metrics["loss"]))
        losses[mode] = ls
    for mode in ("threadcomm", "flat"):
        assert np.allclose(losses[mode], losses["spmd"],
                           rtol=1e-4, atol=1e-4), losses
    print("losses:", losses)
    print("CASE-OK")


def case_elastic_remesh():
    """Checkpoint written under one mesh restores onto a different mesh
    shape with identical values (elastic re-mesh)."""
    import tempfile
    from repro.config import MeshConfig, TrainConfig, ServeConfig
    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.train import checkpoint as ckpt
    from repro.train.trainer import init_train_state
    from repro.dist.sharding import param_pspecs, named_sharding

    cfg = get_smoke_config("qwen3-14b")
    tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                       remat=False)
    model = build_model(cfg, tcfg, ServeConfig(), tp=4)
    state = init_train_state(model, jax.random.PRNGKey(0))

    mesh_a_cfg = MeshConfig(shape=(2, 4), axis_names=("data", "model"))
    mesh_b_cfg = MeshConfig(shape=(4, 2), axis_names=("data", "model"))
    mesh_a = make_mesh(mesh_a_cfg.shape, mesh_a_cfg.axis_names)
    mesh_b = make_mesh(mesh_b_cfg.shape, mesh_b_cfg.axis_names)

    spec_a = param_pspecs(cfg, mesh_a_cfg, state.params)
    params_a = jax.device_put(state.params,
                              named_sharding(mesh_a, spec_a))
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 5, params_a, extra={"mesh": list(mesh_a_cfg.shape)})
        spec_b = param_pspecs(cfg, mesh_b_cfg, state.params)
        restored, step, extra = ckpt.restore(
            d, state.params, shardings=named_sharding(mesh_b, spec_b))
        assert step == 5 and extra["mesh"] == [2, 4]
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_allclose(
                np.asarray(a), np.asarray(b)), state.params, restored)
        # restored arrays live on the NEW mesh
        leaf = jax.tree_util.tree_leaves(restored)[0]
        assert leaf.sharding.mesh.shape == dict(data=4, model=2)
    print("CASE-OK")


def case_spmv_distributed():
    """Slab-decomposed 27pt stencil MatMult over 8 unified ranks == oracle,
    for several cube sizes (halo exchange via threadcomm p2p)."""
    from repro.apps.spmv import make_distributed_matmult, stencil_matmult_ref
    for n in (8, 16, 24):
        mesh = _flat_mesh(8)
        x = jax.random.normal(jax.random.PRNGKey(n), (n, n, n))
        mm = make_distributed_matmult("ranks", 8)
        y = jax.jit(shard_map(mm, mesh=mesh, in_specs=P("ranks"),
                                  out_specs=P("ranks")))(x)
        ref = stencil_matmult_ref(x)
        assert np.allclose(np.asarray(y), np.asarray(ref), atol=1e-4), n
    # hierarchical mesh too: (2 proc x 4 thread) unified ranks
    from repro.core import threadcomm_init
    mesh = _hier_mesh(2, 4)
    tc = threadcomm_init(mesh, process_axes=("proc",),
                         thread_axes=("thread",))
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 16, 16))
    with tc.start():
        mm = make_distributed_matmult(tc.unified_axes, tc.size)
        y = tc.run(mm, x)
    assert np.allclose(np.asarray(y), np.asarray(stencil_matmult_ref(x)),
                       atol=1e-4)
    print("CASE-OK")


def case_grad_compression_parity():
    """bf16 inter-pod gradient wire (threadcomm, §Perf cell A iter.2) must
    track the f32 trajectory within bf16 tolerance."""
    from repro.config import MeshConfig, TrainConfig, ServeConfig
    from repro.configs import get_smoke_config
    from repro.data import SyntheticPipeline
    from repro.models.registry import build_model
    from repro.train.trainer import make_train_step
    from repro.train.explicit import init_explicit_state
    from repro.dist.sharding import batch_pspec
    from jax.sharding import NamedSharding

    cfg = get_smoke_config("yi-9b")
    mesh_cfg = MeshConfig(shape=(2, 2, 2),
                          axis_names=("pod", "data", "model"),
                          process_axes=("pod",))
    mesh = make_mesh(mesh_cfg.shape, mesh_cfg.axis_names)
    pipe = SyntheticPipeline(cfg, batch=8, seq_len=16, seed=0)
    b_shard = NamedSharding(mesh, batch_pspec(mesh_cfg))
    losses = {}
    for wire in ("float32", "bfloat16"):
        tcfg = TrainConfig(param_dtype="float32", compute_dtype="float32",
                           loss_chunk=16, attn_chunk_threshold=64,
                           remat=False, grad_sync="threadcomm",
                           grad_comm_dtype=wire, learning_rate=1e-2,
                           warmup_steps=1, total_steps=10)
        model = build_model(cfg, tcfg, ServeConfig(), tp=2)
        state = init_explicit_state(model, jax.random.PRNGKey(0), dp=4)
        step = make_train_step(model, mesh_cfg, tcfg, mesh=mesh)
        ls = []
        for i in range(3):
            batch = {k: jax.device_put(jnp.asarray(v), b_shard)
                     for k, v in pipe.get_batch(i).items()}
            state, metrics = step(state, batch)
            ls.append(float(metrics["loss"]))
        losses[wire] = ls
    assert np.allclose(losses["bfloat16"], losses["float32"],
                       rtol=2e-2, atol=2e-2), losses
    print("losses:", losses)
    print("CASE-OK")


def case_comm_split_dup():
    """Unified Comm API: split/dup derivation and rank translation over a
    2-axis (process × thread) mesh."""
    from repro.core.comm import (AxisComm, GroupComm, ThreadCommError,
                                 threadcomm_init)
    n_proc, m_thread = 2, 4
    mesh = _hier_mesh(n_proc, m_thread)
    tc = threadcomm_init(mesh, process_axes=("proc",), thread_axes=("thread",))
    with tc.start():
        # canonical derivations
        tcm, pcm = tc.thread_comm(), tc.process_comm()
        assert tcm.size == m_thread and pcm.size == n_proc
        # thread_comm families: one per process, local rank == thread index
        assert tcm.families() == [[0, 1, 2, 3], [4, 5, 6, 7]]
        assert pcm.families() == [[0, 4], [1, 5], [2, 6], [3, 7]]
        assert tcm.translate(2, family=1) == 6
        assert pcm.translate(1, family=3) == 7

        # split by process color == thread_comm (axis-aligned fast path)
        s = tc.split([r // m_thread for r in range(tc.size)])
        assert isinstance(s, AxisComm) and s.axes == ("thread",), s
        # split by thread color == process_comm
        s2 = tc.split([r % m_thread for r in range(tc.size)])
        assert isinstance(s2, AxisComm) and s2.axes == ("proc",)
        # color=constant == dup of the whole comm
        s3 = tc.split([0] * tc.size)
        assert isinstance(s3, AxisComm) and set(s3.axes) == {"proc", "thread"}
        # non-grid split (parity classes) takes the generic path
        g = tc.split([r % 2 for r in range(tc.size)])
        assert isinstance(g, GroupComm)
        assert g.groups == ((0, 2, 4, 6), (1, 3, 5, 7))
        assert g.translate(1, family=1) == 3
        # key reorders local ranks within a class
        gk = tc.split([0] * tc.size, key=list(range(tc.size))[::-1])
        assert gk.families()[0] == list(range(tc.size))[::-1]
        # MPI_UNDEFINED: negative color joins no class
        gu = tc.split([0, 0, 0, 0, -1, -1, -1, -1])
        assert gu.families() == [[0, 1, 2, 3]]
        # dup: same group, fresh context
        d = tc.dup()
        assert d.size == tc.size and d is not tc
        assert tcm.dup().families() == tcm.families()
        # bad color vector length
        try:
            tc.split([0])
            raise SystemExit("short color vector should have raised")
        except ThreadCommError:
            pass
    tc.free()
    print("CASE-OK")


def case_comm_subcomm_collectives():
    """Derived sub-comm collectives on a ≥2-axis mesh: axis-aligned split
    classes reduce independently; generic (non-grid) classes agree with a
    per-class oracle; hierarchical allreduce is the sub-comm composition
    and matches flat psum."""
    from repro.core.comm import AxisComm, GroupComm, threadcomm_init
    n_proc, m_thread = 2, 4
    mesh = _hier_mesh(n_proc, m_thread)
    tc = threadcomm_init(mesh, process_axes=("proc",), thread_axes=("thread",))
    x = jnp.arange(float(tc.size)) + 1.0          # rank r holds r+1
    with tc.start():
        # per-process sums via the split-derived thread comm
        sub = tc.split([r // m_thread for r in range(tc.size)])
        out = tc.run(lambda v: sub.allreduce(v), x)
        want = np.array([sum(range(1, 5))] * 4 + [sum(range(5, 9))] * 4, float)
        assert np.allclose(np.asarray(out), want), out

        # generic split: parity classes, ring path
        g = tc.split([r % 2 for r in range(tc.size)])
        out = tc.run(lambda v: g.allreduce(v), x)
        want = np.zeros(tc.size)
        for grp in g.groups:
            s = sum(r + 1.0 for r in grp)
            for r in grp:
                want[r] = s
        assert np.allclose(np.asarray(out), want), (out, want)
        # bcast from class-local root 0
        outb = tc.run(lambda v: g.bcast(v, root=0), x)
        wantb = np.zeros(tc.size)
        for grp in g.groups:
            for r in grp:
                wantb[r] = grp[0] + 1.0
        assert np.allclose(np.asarray(outb), wantb), (outb, wantb)
        # allgather (uniform classes): every rank sees its class's vector;
        # tiled (interface default) and stacked agree
        outg = tc.run(lambda v: g.allgather(v[0], tiled=False)[None].sum(1),
                      x[:, None])
        assert np.allclose(np.asarray(outg).ravel(), want), outg
        outt = tc.run(lambda v: g.allgather(v)[:1] * 0
                      + g.allgather(v).sum(), x[:, None])
        assert np.allclose(np.asarray(outt).ravel(), want), outt

        # hierarchical allreduce == flat psum, both compositions
        vec = jnp.arange(tc.size * 13, dtype=jnp.float32).reshape(tc.size, 13)
        flat = tc.run(lambda v: tc.allreduce(v, schedule="psum"), vec)
        for sched in ("hierarchical", "hierarchical_tree"):
            h = tc.run(lambda v, s=sched: tc.allreduce(v, schedule=s), vec)
            assert np.allclose(np.asarray(h), np.asarray(flat),
                               rtol=1e-5), sched
        # sub-comm p2p: ring shift within each process via thread_comm
        tcm = tc.thread_comm()
        pairs = [(i, (i + 1) % m_thread) for i in range(m_thread)]
        sr = tc.run(lambda v: tcm.send_recv(v, pairs), x)
        want = np.concatenate([np.roll(np.asarray(x)[:4], 1),
                               np.roll(np.asarray(x)[4:], 1)])
        assert np.allclose(np.asarray(sr), want), sr
    tc.free()
    print("CASE-OK")


def case_comm_requests():
    """Request-based nonblocking ops: iallreduce == blocking allreduce,
    wait/test protocol, stream-ordered issue, isend/irecv protocol cost."""
    from repro.core import protocol
    from repro.core.comm import threadcomm_init, waitall
    n_proc, m_thread = 2, 4
    mesh = _hier_mesh(n_proc, m_thread)
    tc = threadcomm_init(mesh, process_axes=("proc",), thread_axes=("thread",))
    x = jnp.arange(float(tc.size))
    with tc.start():
        blocking = tc.run(lambda v: tc.allreduce(v), x)

        def nonblocking(v):
            req = tc.iallreduce(v)
            done, _ = req.test()     # under trace the op is scheduled
            assert done
            return req.wait()
        got = tc.run(nonblocking, x)
        assert np.allclose(np.asarray(got), np.asarray(blocking))

        # stream-ordered pipeline: two dependent requests on one stream
        tcm, pcm = tc.thread_comm(), tc.process_comm()

        def pipeline(v):
            flat = v.reshape(-1)                 # (8,) per rank
            with tc.stream("grad") as s:
                r1 = tcm.ireduce_scatter(flat)   # (2,) shard, fast domain
                r2 = pcm.iallreduce(r1.wait())   # slow domain on 1/M bytes
                full = tcm.iallgather(r2.wait()).wait()
                assert len(s._requests) == 3
            return full.reshape(v.shape)
        payload = jnp.tile(x[:, None], (1, 8))
        out = tc.run(pipeline, payload)
        flat = tc.run(lambda v: tc.allreduce(v), payload)
        assert np.allclose(np.asarray(out), np.asarray(flat))

        # waitall preserves order
        def many(v):
            reqs = [tc.iallreduce(v), tc.iallreduce(2 * v)]
            a, b = waitall(reqs)
            return a + b
        out = tc.run(many, x)
        assert np.allclose(np.asarray(out), 3 * np.asarray(x).sum())

        # isend: small INTERTHREAD payloads ride the request-free eager
        # fast path; the root comm crosses processes, so its messages
        # always pay the request object (the fast path is §3.2's
        # interthread-only optimization)
        tpairs = [(i, (i + 1) % m_thread) for i in range(m_thread)]
        def ring_thread(v):
            req = tcm.isend(v, tpairs)
            assert req.model_overhead_s == 0.0       # eager_fast
            return req.wait()
        out = tc.run(ring_thread, x)
        want = np.concatenate([np.roll(np.asarray(x)[:4], 1),
                               np.roll(np.asarray(x)[4:], 1)])
        assert np.allclose(np.asarray(out), want)
        pairs = [(i, (i + 1) % tc.size) for i in range(tc.size)]
        def ring_root(v):
            req = tc.isend(v, pairs)
            assert req.model_overhead_s > 0.0        # cross-process
            return req.wait()
        out = tc.run(ring_root, x)
        assert np.allclose(np.asarray(out), np.roll(np.asarray(x), 1))
        big = jnp.zeros((tc.size, 1 << 12), jnp.float32)
        def ring_big(v):
            req = tcm.isend(v, tpairs)
            assert req.model_overhead_s > 0.0        # one_copy: real request
            return req.wait()
        tc.run(ring_big, big)
        assert protocol.request_overhead(64) == 0.0
        assert protocol.request_overhead(1 << 20) > 0.0
    tc.free()
    print("CASE-OK")


def case_comm_epoch_invalidation():
    """Activation-window semantics extend to derived comms and requests:
    anything issued inside a window dies at finish() (paper §2)."""
    from repro.core.comm import ThreadCommError, threadcomm_init
    mesh = _hier_mesh(2, 4)
    tc = threadcomm_init(mesh, process_axes=("proc",), thread_axes=("thread",))
    x = jnp.arange(8.0)

    captured = {}
    with tc.start():
        captured["sub"] = tc.thread_comm()
        captured["dup"] = tc.dup()
        captured["split"] = tc.split([r % 2 for r in range(8)])

        def issue(v):
            captured["req"] = tc.iallreduce(v)
            return captured["req"].wait()        # valid inside the window
        out = tc.run(issue, x)
        assert np.allclose(np.asarray(out), np.asarray(x).sum())
        req2 = captured["req"]
        assert req2.test()[0]                    # still inside the window

    # window closed: every derived object must refuse to operate
    with tc.start():
        for name in ("sub", "dup", "split"):
            try:
                captured[name].dup()
                raise SystemExit(f"stale {name} comm should have raised")
            except ThreadCommError:
                pass
        try:
            captured["req"].wait()
            raise SystemExit("stale request should have raised")
        except ThreadCommError:
            pass
        try:
            captured["req"].test()
            raise SystemExit("stale request test() should have raised")
        except ThreadCommError:
            pass
        # a fresh window issues fresh derived objects that DO work
        fresh = tc.thread_comm()
        out = tc.run(lambda v: fresh.allreduce(v), x)
        assert np.allclose(
            np.asarray(out),
            np.concatenate([np.full(4, np.asarray(x)[:4].sum()),
                            np.full(4, np.asarray(x)[4:].sum())]))
    tc.free()
    print("CASE-OK")


def case_dryrun_smoke():
    """Reduced-config dry-run cells lower+compile on the production meshes
    (the full configs run via launch/dryrun.py --all)."""
    import tempfile
    os.environ["REPRO_ARTIFACT_DIR"] = tempfile.mkdtemp()
    from repro.launch.dryrun import run_cell
    for arch, shape, mesh in (("gemma-2b", "train_4k", "single_pod"),
                              ("mamba2-370m", "decode_32k", "multi_pod"),
                              ("olmoe-1b-7b", "train_4k", "multi_pod")):
        res = run_cell(arch, shape, mesh, smoke=True, verbose=False)
        assert "analysis" in res, (arch, shape, mesh)
        assert res["analysis"]["terms"]["compute_s"] > 0
    print("CASE-OK")


def case_serve_replica_fanout():
    """Serving replica fan-out (DESIGN.md §8): data-parallel replicas are
    ``Comm.split`` families over the unified rank space; each replica
    serves its round-robin ``shard_trace`` slice, and replica-internal
    collectives (token-budget allreduce) stay confined to the family."""
    from repro.core import threadcomm_init
    from repro.serve import make_trace, shard_trace

    n, n_rep = 8, 2
    mesh = _flat_mesh(n)
    root = threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))
    root.start()

    trace = make_trace(12, prompt_len=8, max_new=(2, 6), seed=3)
    shards = [shard_trace(trace, i, n_rep) for i in range(n_rep)]
    # the fan-out partitions the traffic: disjoint, exhaustive, balanced
    assert sum(len(s) for s in shards) == len(trace)
    assert not {id(e) for e in shards[0]} & {id(e) for e in shards[1]}
    assert abs(len(shards[0]) - len(shards[1])) <= 1

    # replicas = contiguous half-blocks of the flat 8-rank axis: not an
    # axis-aligned sub-grid, so split takes the merged-ring GroupComm path
    color = [r * n_rep // n for r in range(n)]
    rep = root.split(color)
    assert len(rep.families()) == n_rep and rep.size == n // n_rep

    # replica-internal token-budget allreduce: every rank of replica i must
    # see replica i's total, with no leakage from the other replica
    toks = [float(sum(e.max_new for e in s)) for s in shards]
    per_rank = jnp.asarray([toks[color[r]] for r in range(n)],
                           dtype=jnp.float32)
    out = shard_map(lambda v: rep.allreduce(v), mesh=mesh,
                    in_specs=P("ranks"), out_specs=P("ranks"))(per_rank)
    expect = np.array([toks[color[r]] * (n // n_rep) for r in range(n)])
    assert np.allclose(np.asarray(out), expect), (out, expect)

    root.finish()
    root.free()
    print("CASE-OK")


def case_comm_waitall_mixed():
    """``waitall`` over MIXED send/recv requests on a split sub-comm —
    the fabric's KV-handoff pattern (DESIGN.md §10): a source rank
    streams payload pieces to its partner over a dedicated stream while
    an independent allreduce request rides alongside, and the single
    ``waitall`` completion point covers them all in issue order."""
    from repro.core.comm import threadcomm_init, testall, waitall

    n = 8
    mesh = _flat_mesh(n)
    tc = threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))
    with tc.start():
        # two split families of 4 ranks each (contiguous halves — the
        # merged-ring GroupComm path, like the fabric's engine comms)
        color = [r // 4 for r in range(n)]
        sub = tc.split(color)
        assert len(sub.families()) == 2 and sub.size == 4

        x = jnp.arange(float(n)) + 1.0
        # local-rank pairs: 0->1, 1->0 (the prefill->decode hop and the
        # decode rank's ack), applied in each family concurrently
        pairs = [(0, 1), (1, 0)]

        def handoff(v):
            with sub.stream("kv-migrate") as s:
                reqs = []
                # "blocks": three chunked isends of growing payloads —
                # forced one_copy, the rendezvous-class a KV block rides
                for piece in (v, 2 * v, 3 * v):
                    reqs.append(sub.isend(piece, pairs,
                                          force_protocol="one_copy"))
                # a recv handle for the same round (SPMD: the matching
                # receive of the fused permute) + an unrelated collective
                reqs.append(sub.irecv(4 * v, pairs))
                reqs.append(sub.iallreduce(v))
                assert len(s._requests) == 5
                assert testall(reqs)       # traced: all scheduled
                out = waitall(reqs)        # one completion point, in order
            # every one_copy message paid its request object (§3.2: the
            # request-free path is eager_fast only)
            assert all(r.model_overhead_s > 0.0 for r in reqs[:3])
            return sum(out[:4]) + out[4]
        got = tc.run(handoff, x)

        xs = np.asarray(x)
        want = np.zeros(n)
        for fam in sub.families():
            fam_sum = xs[list(fam)].sum()
            for src, dst in pairs:
                # pieces 1x,2x,3x,4x of the src rank land on dst
                want[fam[dst]] += 10 * xs[fam[src]]
            for r in fam:
                want[r] += fam_sum                    # the allreduce ride
        assert np.allclose(np.asarray(got), want), (got, want)

    # derived comm dies with the activation window (the fabric's close())
    survived = False
    try:
        tc.run(lambda v: sub.isend(v, pairs).wait(), x)
        survived = True
    except Exception:
        pass
    assert not survived, "stale sub-comm survived finish"
    tc.free()
    print("CASE-OK")


CASES = {k[5:]: v for k, v in list(globals().items())
         if k.startswith("case_")}


def main():
    name = sys.argv[1]
    CASES[name]()


if __name__ == "__main__":
    main()
