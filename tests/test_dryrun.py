"""Dry-run integration (reduced configs, production meshes, subprocess with
512 host devices) + roofline analysis unit tests on synthetic HLO."""

import numpy as np

from repro.roofline.analysis import parse_collectives, summarize_collectives
from repro.roofline.hlo_struct import computation_multipliers
from tests.helpers import run_case

FAKE_HLO = """
HloModule test

%while_cond.1 (p: s32[]) -> pred[] {
  %p = s32[] parameter(0)
  %c = s32[] constant(48)
  ROOT %cmp = pred[] compare(%p, %c), direction=LT
}

%while_body.1 (p: f32[128]) -> f32[128] {
  %p2 = f32[128] parameter(0)
  %ag = f32[256]{0} all-gather(%p2), replica_groups=[16,16]<=[256], dimensions={0}
  %ar = f32[128]{0} all-reduce(%p2), replica_groups={{0,1,2,3}}, to_apply=%add
  ROOT %out = f32[128]{0} add(%p2, %ar)
}

ENTRY %main (x: f32[128]) -> f32[128] {
  %x = f32[128] parameter(0)
  %w = f32[128]{0} while(%x), condition=%while_cond.1, body=%while_body.1
  %cp = f32[64]{0} collective-permute(%x), source_target_pairs={{0,1}}
  ROOT %r = f32[128]{0} add(%w, %x)
}
"""


def test_while_trip_multipliers():
    mult = computation_multipliers(FAKE_HLO)
    assert mult["while_body.1"] == 48
    assert mult.get("main", 1) == 1


def test_collective_parsing_with_trips():
    colls = parse_collectives(FAKE_HLO)
    by_op = {c["op"]: c for c in colls}
    # all-gather: operand = out/g = 256*4/16 = 64 bytes; 48 executions
    ag = by_op["all-gather"]
    assert ag["group_size"] == 16 and ag["trip_multiplier"] == 48
    np.testing.assert_allclose(ag["operand_bytes"], 256 * 4 / 16)
    np.testing.assert_allclose(ag["total_operand_bytes"], 64 * 48)
    ar = by_op["all-reduce"]
    assert ar["group_size"] == 4
    np.testing.assert_allclose(ar["total_operand_bytes"], 128 * 4 * 48)
    cp = by_op["collective-permute"]
    assert cp["trip_multiplier"] == 1
    s = summarize_collectives(colls)
    assert s["total"]["sites"] == 3
    assert s["total"]["executions"] == 48 + 48 + 1


def test_dryrun_smoke_cells():
    run_case("dryrun_smoke", ndev=512, timeout=900)


def test_analytic_flops_sane():
    """6ND sanity: analytical computed FLOPs within ~1.2-10x of 6ND for a
    dense train cell (remat + dense-computed attention overhead)."""
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.roofline.flops import cell_compute_flops
    cfg = get_config("yi-9b")
    out = cell_compute_flops(cfg, SHAPES["train_4k"])
    ratio = out["computed"] / out["model_flops"]
    assert 1.0 < ratio < 10.0, ratio


def test_memory_bytes_decode_dominated_by_weights_or_cache():
    from repro.config import SHAPES
    from repro.configs import get_config
    from repro.config import MULTI_POD
    from repro.roofline.flops import cell_memory_bytes
    cfg = get_config("yi-9b")
    d = cell_memory_bytes(cfg, SHAPES["decode_32k"], MULTI_POD)
    assert d["weights"] + d["cache"] > 0.8 * d["bytes"]
