"""ThreadComm lifecycle + collective correctness (multi-device cases run in
subprocesses; host-side rank arithmetic tested inline)."""

import pytest

from tests.helpers import run_case


def test_collectives_flat():
    run_case("collectives_flat", ndev=8)


def test_threadcomm_unified():
    run_case("threadcomm_unified", ndev=8)


def test_p2p_protocols():
    run_case("p2p_protocols", ndev=8)


def test_hierarchical_collective_bytes():
    run_case("hierarchical_collective_bytes", ndev=8)
