import os
import sys

# NOTE: no XLA_FLAGS device-count override here — smoke tests and benches run
# on the single real CPU device. Multi-device tests spawn subprocesses (see
# tests/helpers.py) so jax's device-count lock never constrains the suite.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
