"""Telemetry subsystem (DESIGN.md §15): tracer span nesting and rank
attribution under pool threads, ring-buffer overflow semantics, Chrome
``trace_event`` export validity, the residual ledger + serialization-
stall detector, the metrics registry, trial-flush wiring on engine
reset / fabric close, and the inert-when-disabled contract (mirroring
``test_sanitizer.py``: instrumented code pays one ``None`` check and
nothing else when telemetry is off)."""

import json
import time
from concurrent.futures import ThreadPoolExecutor

import jax
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.obs import metrics as M
from repro.obs import residuals as R
from repro.obs import trace as T
from repro.serve import ContinuousEngine, ServingFabric


@pytest.fixture
def tracer():
    tr = T.install(capacity=4096)
    M.install()
    yield tr
    T.uninstall()
    M.uninstall()


@pytest.fixture
def off():
    """Force the disabled state (REPRO_TRACE=1 in the environment
    auto-installs at import)."""
    T.uninstall()
    M.uninstall()
    yield


@pytest.fixture(scope="module")
def bundle():
    cfg = get_smoke_config("gemma-2b")
    train = TrainConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=16, attn_chunk_threshold=64,
                        attn_chunk=16, remat=False)
    model = build_model(cfg, train, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# span nesting and rank attribution
# ---------------------------------------------------------------------------

def test_span_nesting_parent_recorded(tracer):
    with tracer.span("outer", cat="test"):
        with tracer.span("inner", cat="test", k=1):
            pass
    by_name = {e["name"]: e for e in tracer.events()}
    assert by_name["inner"]["args"]["parent"] == "outer"
    assert "parent" not in by_name["outer"]["args"]
    assert by_name["inner"]["ph"] == "X"
    assert by_name["inner"]["dur"] >= 0.0
    assert tracer.unbalanced == 0


def test_complete_inherits_open_parent(tracer):
    with tracer.span("outer"):
        t0 = time.perf_counter()
        tracer.complete("hot", t0, time.perf_counter())
    ev = [e for e in tracer.events() if e["name"] == "hot"][0]
    assert ev["args"]["parent"] == "outer"


def test_manual_end_is_idempotent(tracer):
    sp = tracer.span("once")
    sp.end()
    sp.end()
    assert len([e for e in tracer.events() if e["name"] == "once"]) == 1
    assert tracer.unbalanced == 0


def test_out_of_order_end_counted_unbalanced(tracer):
    a = tracer.span("a")
    b = tracer.span("b")
    a.end()              # LIFO violation: b is still open
    b.end()
    assert tracer.unbalanced == 1
    assert len(tracer.events()) == 2


def test_rank_attribution_under_pool_threads(tracer):
    """Fabric shape: a ThreadPoolExecutor re-assigns threads to ranks
    arbitrarily per step; rank_scope must pin every event to the rank,
    and the thread-local stacks must never cross-corrupt."""
    def one_step(rank, step):
        with tracer.rank_scope(rank):
            with tracer.span(f"step:{rank}", step=step):
                with tracer.span(f"sub:{rank}"):
                    time.sleep(0.0005)

    with ThreadPoolExecutor(max_workers=3,
                            thread_name_prefix="fabric-rank") as ex:
        futs = [ex.submit(one_step, rank, step)
                for step in range(8) for rank in range(4)]
        for f in futs:
            f.result()
    assert tracer.unbalanced == 0
    for ev in tracer.events():
        kind, _, rank = ev["name"].partition(":")
        assert ev["tid"] == int(rank)        # lane == rank, not thread
        if kind == "sub":
            assert ev["args"]["parent"] == f"step:{rank}"
    # 4 ranks x 8 steps x 2 spans
    assert len(tracer.events()) == 64


def test_driver_lane_outside_rank_scope(tracer):
    tracer.instant("driver_event")
    ev = tracer.events()[0]
    assert ev["tid"] >= T.DRIVER_TID
    lanes = tracer.chrome_trace()["traceEvents"]
    names = {m["tid"]: m["args"]["name"] for m in lanes
             if m.get("ph") == "M" and m["name"] == "thread_name"}
    assert ev["tid"] in names                # lane carries a thread name


# ---------------------------------------------------------------------------
# ring buffer
# ---------------------------------------------------------------------------

def test_ring_overflow_drops_oldest_first():
    tr = T.Tracer(capacity=8)
    for i in range(12):
        tr.instant(f"ev{i}")
    names = [e["name"] for e in tr.events()]
    assert names == [f"ev{i}" for i in range(4, 12)]
    assert tr.dropped == 4
    assert tr.chrome_trace()["metadata"]["dropped_events"] == 4


def test_ring_capacity_validated():
    with pytest.raises(ValueError):
        T.Tracer(capacity=0)


# ---------------------------------------------------------------------------
# Chrome trace_event export
# ---------------------------------------------------------------------------

def test_chrome_trace_valid_json(tracer, tmp_path):
    with tracer.rank_scope(1):
        with tracer.span("rank_step", cat="fabric"):
            t0 = time.perf_counter()
            tracer.complete("decode", t0, time.perf_counter(), rows=2)
        tracer.counter("block_pool", free=3, live=5)
    tracer.instant("admit", cat="sched", rid=0)
    path = tmp_path / "trace.json"
    tracer.write_chrome(str(path))
    doc = json.loads(path.read_text())
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    assert {"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
            "args": {"name": "repro-serve"}} in evs
    # rank lane named and sorted
    assert any(m.get("ph") == "M" and m["name"] == "thread_name"
               and m["tid"] == 1 and m["args"]["name"] == "rank 1"
               for m in evs)
    data = [e for e in evs if e.get("ph") != "M"]
    assert [e["ts"] for e in data] == sorted(e["ts"] for e in data)
    for e in data:
        assert {"name", "ph", "ts", "pid", "tid"} <= set(e)
        if e["ph"] == "X":
            assert e["dur"] >= 0.0


def test_hop_emits_span_and_residual(tracer):
    t0 = time.perf_counter()
    time.sleep(0.001)
    with tracer.rank_scope(2):
        tracer.hop("migration", 0.5e-3, t0, time.perf_counter(), rid=7)
    ev = [e for e in tracer.events() if e["name"] == "hop:migration"][0]
    assert ev["cat"] == "residual"
    assert ev["args"]["modeled_s"] == pytest.approx(0.5e-3)
    assert ev["args"]["measured_s"] > 0.0
    assert ev["args"]["residual_ratio"] == pytest.approx(
        ev["args"]["measured_s"] / 0.5e-3)
    assert ev["tid"] == 2
    rep = tracer.residuals.report()
    assert rep["hops"]["migration"]["n"] == 1


# ---------------------------------------------------------------------------
# residual ledger + serialization-stall detector
# ---------------------------------------------------------------------------

def test_residual_report_flags_over_factor():
    led = R.ResidualLedger()
    led.record("admission", 1e-3, 1.1e-3)         # on-model
    led.record("migration", 1e-3, 5e-3, rank=1)   # 5x over
    rep = led.report(factor=2.0)
    assert rep["hops"]["admission"]["ratio"] == pytest.approx(1.1)
    assert rep["hops"]["migration"]["ratio"] == pytest.approx(5.0)
    assert rep["flagged"] == ["migration"]
    assert rep["hops"]["migration"]["n_off"] == 1
    assert rep["hops"]["migration"]["worst_over"] == pytest.approx(5.0)


def test_residual_unmodeled_hop_is_inf():
    led = R.ResidualLedger()
    led.record("router_dispatch", 0.0, 1e-4)
    rep = led.report()
    assert rep["hops"]["router_dispatch"]["ratio"] == float("inf")
    assert "router_dispatch" in rep["flagged"]


def test_residual_under_factor_flagged_too():
    led = R.ResidualLedger()
    led.record("spec_verify", 1e-2, 1e-3)         # 10x under
    assert led.report()["flagged"] == ["spec_verify"]


def test_stall_detector_gated_on_runnable(tracer):
    t0 = time.perf_counter()
    t1 = t0 + 2e-3
    tracer.on_wait("allreduce", t0, t1)           # no runnable hint: idle
    assert tracer.residuals.report()["serialization_stall_s"] == 0.0
    tracer.set_runnable(3)
    tracer.on_wait("allreduce", t0, t1)           # blocked while runnable
    rep = tracer.residuals.report()
    assert rep["serialization_stall_s"] == pytest.approx(2e-3)
    assert rep["stall_events"] == 1
    waits = [e for e in tracer.events() if e["name"] == "wait:allreduce"]
    assert len(waits) == 2 and waits[1]["args"]["runnable"] == 3


def test_merge_reports_recombines_sums():
    a, b = R.ResidualLedger(), R.ResidualLedger()
    a.record("admission", 1e-3, 2e-3)
    a.stall(1e-3, rank=0)
    b.record("admission", 1e-3, 4e-3)
    b.record("migration", 2e-3, 2e-3, rank=1)
    b.stall(2e-3, rank=0)
    merged = R.merge_reports([a.report(), b.report(), {}])
    assert merged["hops"]["admission"]["n"] == 2
    assert merged["hops"]["admission"]["ratio"] == pytest.approx(3.0)
    assert merged["hops"]["migration"]["ratio"] == pytest.approx(1.0)
    assert merged["flagged"] == ["admission"]
    assert merged["serialization_stall_s"] == pytest.approx(3e-3)
    assert merged["stall_by_rank"]["0"] == pytest.approx(3e-3)


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram(tracer):
    reg = M.active()
    reg.counter("sched.admitted").inc(3)
    reg.counter("sched.admitted").inc()
    reg.gauge("sched.queue_depth").set(7)
    for v in (1.0, 2.0, 3.0, 4.0):
        reg.histogram("latency_s").observe(v)
    snap = reg.snapshot()
    assert snap["counters"]["sched.admitted"] == 4.0
    assert snap["gauges"]["sched.queue_depth"] == 7.0
    h = snap["histograms"]["latency_s"]
    assert h["count"] == 4.0 and h["mean"] == pytest.approx(2.5)
    assert h["min"] == 1.0 and h["max"] == 4.0
    reg.reset()
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_snapshot_merges_registry_and_extra(tracer):
    M.active().counter("tokens_out").inc(5)
    out = M.snapshot(extra={"tok_s": 12.0})
    assert out["tok_s"] == 12.0
    assert out["metrics"]["counters"]["tokens_out"] == 5.0


# ---------------------------------------------------------------------------
# trial-flush wiring (the PR 5 req_log aliasing class)
# ---------------------------------------------------------------------------

def test_engine_reset_flushes_trial(tracer, bundle):
    cfg, model, params = bundle
    eng = ContinuousEngine(model, params, cache_len=32, num_slots=2,
                           prefill_chunk=16, kv_layout="paged",
                           block_size=8)
    tracer.residuals.record("admission", 1e-3, 5e-3)   # warm-up pollution
    M.active().counter("tokens_out").inc(9)
    eng.reset()
    assert tracer.residuals.counts() == {}
    assert M.active().snapshot()["counters"] == {}


def test_engine_reset_preserve_prefix_flushes_too(tracer, bundle):
    cfg, model, params = bundle
    eng = ContinuousEngine(model, params, cache_len=32, num_slots=2,
                           prefill_chunk=16, kv_layout="paged",
                           block_size=8, prefix_cache=True)
    tracer.residuals.record("prefix_hit", 1e-3, 1e-3)
    eng.reset(preserve_prefix=True)
    assert tracer.residuals.counts() == {}


def test_fabric_close_flushes_trial(tracer, bundle):
    cfg, model, params = bundle
    fab = ServingFabric(model, params, ranks=2, placement="replicated",
                        cache_len=32, slots_per_rank=2, prefill_chunk=16,
                        block_size=8)
    tracer.residuals.record("router_dispatch", 1e-4, 1e-4)
    fab.close()
    assert tracer.residuals.counts() == {}
    assert fab.scheduler.req_log == {}
    assert fab.total_steps == 0


def test_fabric_speculate_requires_replicated(bundle):
    cfg, model, params = bundle
    with pytest.raises(ValueError, match="disaggregated"):
        ServingFabric(model, params, ranks=2, placement="disagg",
                      cache_len=32, slots_per_rank=2, prefill_chunk=16,
                      block_size=8, speculate=2)


# ---------------------------------------------------------------------------
# inert when disabled (the <2% overhead contract, structurally)
# ---------------------------------------------------------------------------

def test_disabled_hooks_inert(off):
    assert T.active() is None
    assert M.active() is None
    T.flush_trial()                     # no-ops, no error
    M.flush_trial()


def test_disabled_guard_is_one_global_read(off):
    """The instrumented-site pattern when telemetry is off: one module-
    global read plus a None check. Bound it generously — the point is
    that nothing allocates or reads the clock on the disabled path."""
    n = 200_000
    t0 = time.perf_counter()
    for _ in range(n):
        tr = T.active()
        if tr is not None:              # pragma: no cover
            tr.instant("never")
    dt = time.perf_counter() - t0
    assert dt / n < 5e-6                # < 5us per guard, vastly above cost


def test_install_is_fresh_each_time():
    tr1 = T.install(capacity=16)
    tr1.instant("stale")
    tr2 = T.install(capacity=16)
    try:
        assert T.active() is tr2
        assert tr2.n_events == 0
    finally:
        T.uninstall()
