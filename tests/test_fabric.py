"""Serving fabric (DESIGN.md §10): router dispatch + multi-rank engine
workers over the comm substrate — replicated JSQ placement greedy
token-identical to the single engine, disaggregated prefill/decode with
request-based KV-block migration, transport correctness, dispatch-hop
backpressure, pricing, and reset hygiene across back-to-back trials."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import protocol
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import (ContinuousEngine, ServeRequest, ServingFabric,
                         make_trace)
from repro.serve.fabric.placement import make_placement
from repro.serve.fabric.transport import KVBlockTransport

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)

CACHE_LEN = 48 + 8          # longest prompt + max_new ceiling
CHUNK = 16
BLOCK = 8


@pytest.fixture(scope="module")
def bundle():
    cfg = get_smoke_config("gemma-2b")
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(0))


def _trace_requests(cfg, n=6, seed=0, prompt_len=(16, 48), max_new=(3, 8)):
    trace = make_trace(n, prompt_len=prompt_len, max_new=max_new,
                       arrival="all", seed=seed)
    reqs = []
    for rid, e in enumerate(trace):
        b = make_synthetic_batch(cfg, 1, e.prompt_len, seed=seed + 1000 + rid,
                                 compute_dtype="float32")
        reqs.append(ServeRequest(rid=rid,
                                 batch={"tokens": np.asarray(b["tokens"])},
                                 max_new_tokens=e.max_new, temperature=0.0,
                                 seed=seed, arrival=e.arrival))
    return reqs


def _drain(driveable, reqs, limit=4000):
    for r in reqs:
        driveable.submit(r, 0.0)
    steps = 0
    while not driveable.idle:
        driveable.step(0.0)
        steps += 1
        assert steps < limit, "failed to drain"
    return steps


def _single_engine(model, params, **kw):
    return ContinuousEngine(model, params, cache_len=CACHE_LEN, num_slots=4,
                            prefill_chunk=CHUNK, max_prefill_per_step=2,
                            kv_layout="paged", block_size=BLOCK, **kw)


def _fabric(model, params, placement, **kw):
    return ServingFabric(model, params, ranks=2, placement=placement,
                         cache_len=CACHE_LEN, slots_per_rank=4,
                         prefill_chunk=CHUNK, max_prefill_per_step=2,
                         block_size=BLOCK, **kw)


def _outputs(reqs):
    return [r.output[:r.generated].copy() for r in reqs]


@pytest.fixture(scope="module")
def baseline(bundle):
    cfg, model, params = bundle
    reqs = _trace_requests(cfg)
    _drain(_single_engine(model, params), reqs)
    return _outputs(reqs)


# ---------------------------------------------------------------------------
# placement policies
# ---------------------------------------------------------------------------

def test_placement_roles_and_validation():
    assert make_placement("replicated").roles(3) == ["full"] * 3
    assert make_placement("disagg").roles(3) == ["prefill", "decode",
                                                 "decode"]
    assert make_placement("disagg", n_prefill=2).roles(4) == \
        ["prefill", "prefill", "decode", "decode"]
    with pytest.raises(ValueError):
        make_placement("disagg").roles(1)       # no decode rank left
    with pytest.raises(ValueError):
        make_placement("disagg", n_prefill=2).roles(2)
    with pytest.raises(ValueError):
        make_placement("ring")                  # unknown policy


# ---------------------------------------------------------------------------
# replicated fabric: JSQ data parallelism, token identity
# ---------------------------------------------------------------------------

def test_replicated_token_identity_and_balance(bundle, baseline):
    cfg, model, params = bundle
    fab = _fabric(model, params, "replicated")
    try:
        reqs = _trace_requests(cfg)
        _drain(fab, reqs)
        for want, r in zip(baseline, reqs):
            assert np.array_equal(want, r.output[:r.generated]), r.rid
        # JSQ actually spread the trace over both ranks
        assert sorted({r.rank for r in reqs}) == [0, 1]
        util = fab.stats()["per_rank"]
        assert all(row["role"] == "full" for row in util)
        assert all(row["steps"] > 0 for row in util)
    finally:
        fab.close()


def test_jsq_balances_predicted_cost_not_count(bundle):
    """The JSQ load metric is predicted *work* (protocol-model seconds),
    not request count. On an alternating 16/256-token trace, count-JSQ
    deals strictly alternately — one rank ends up with every long
    prompt; cost-JSQ splits the long prompts across ranks because a
    256-token deposit weighs ~an order of magnitude more than a
    16-token one."""
    import warnings
    cfg, model, params = bundle
    fab = ServingFabric(model, params, ranks=2, placement="replicated",
                        cache_len=320, slots_per_rank=4,
                        prefill_chunk=CHUNK, block_size=16)
    try:
        reqs = []
        for rid in range(8):
            plen = 16 if rid % 2 == 0 else 256
            b = make_synthetic_batch(cfg, 1, plen, seed=3000 + rid,
                                     compute_dtype="float32")
            reqs.append(ServeRequest(
                rid=rid, batch={"tokens": np.asarray(b["tokens"])},
                max_new_tokens=2))
        for r in reqs:
            fab.submit(r, 0.0)
        fab._dispatch(0.0)
        assert all(r.rank >= 0 for r in reqs)       # window 8: all dealt
        w0, w1 = fab.workers
        # load is modeled seconds now; queue_depth keeps the old count
        assert isinstance(w0.load, float)
        assert w0.queue_depth + w1.queue_depth == 8
        # a long deposit costs much more than a short one, decode equal
        heavy = w0.predicted_cost_s(reqs[1])
        light = w0.predicted_cost_s(reqs[0])
        assert heavy > 3 * light
        toks = {w.rank: sum(r.prompt_len for r in reqs if r.rank == w.rank)
                for w in fab.workers}
        heavies = {w.rank: sum(1 for r in reqs
                               if r.rank == w.rank and r.prompt_len == 256)
                   for w in fab.workers}
        # count-JSQ's failure mode: all four 256s on one rank (1024 vs
        # 64 tokens). Cost-JSQ must split them, and each rank's share
        # of prompt work stays within the weight of one long prompt.
        assert min(heavies.values()) >= 1, (heavies, toks)
        assert max(toks.values()) - min(toks.values()) <= 256, toks
        # greedy bound: final modeled loads differ by at most one
        # request's cost
        assert abs(w0.load - w1.load) <= heavy + 1e-12
    finally:
        with warnings.catch_warnings():
            warnings.simplefilter("ignore")   # dispatch-only: in flight
            fab.close()


def test_dispatch_window_backpressure(bundle):
    cfg, model, params = bundle
    fab = _fabric(model, params, "replicated", dispatch_window=1)
    try:
        reqs = _trace_requests(cfg, n=6)
        for r in reqs:
            fab.submit(r, 0.0)
        fab._dispatch(0.0)
        # window 1 per rank: at most 2 dispatched, the rest wait at the
        # router (the bounded-buffer discipline, one hop up)
        assert fab.scheduler.num_waiting >= 4
        _drain(fab, [])                      # still drains to completion
        assert all(r.output is not None for r in reqs)
    finally:
        fab.close()


# ---------------------------------------------------------------------------
# disaggregated fabric: prefill/decode split + KV-block migration
# ---------------------------------------------------------------------------

def test_disagg_token_identity_and_migration(bundle, baseline):
    cfg, model, params = bundle
    fab = _fabric(model, params, "disagg")
    try:
        reqs = _trace_requests(cfg)
        _drain(fab, reqs)
        for want, r in zip(baseline, reqs):
            assert np.array_equal(want, r.output[:r.generated]), r.rid
        # every request prefilled on rank 0, decoded on rank 1, with its
        # migration priced by the protocol model
        assert all(r.rank == 0 for r in reqs)
        assert all(r.decode_rank == 1 for r in reqs)
        assert all(r.kv_blocks_moved >= 1 for r in reqs)
        assert all(r.kv_migration_s > 0.0 for r in reqs)
        st = fab.stats()
        assert st["n_migrations"] == len(reqs)
        assert st["blocks_moved"] == sum(r.kv_blocks_moved for r in reqs)
        assert st["kv_migration_modeled_s"] > 0.0
        # the prefill rank never compiled (or ran) a decode dispatch,
        # and every token was produced on the decode rank
        prefill_w, decode_w = fab.workers
        assert prefill_w.engine.decode_compiles == 0
        assert prefill_w.tokens_out == 0
        assert decode_w.tokens_out == sum(r.generated for r in reqs)
        # leases migrated, not leaked: both pools fully free after drain
        assert prefill_w.engine.kv.pool.num_free == \
            prefill_w.engine.kv.pool.num_blocks
        assert decode_w.engine.kv.pool.num_free == \
            decode_w.engine.kv.pool.num_blocks
    finally:
        fab.close()


@pytest.mark.parametrize("placement,role", [("disagg", "decode"),
                                            ("replicated", "full")])
def test_fabric_rejects_unservable_budget(bundle, placement, role):
    """An unservable budget fails at router submit (either placement) —
    not mid-step after the dispatch hop already popped the request."""
    cfg, model, params = bundle
    fab = _fabric(model, params, placement)
    try:
        batch = {"tokens": np.zeros((1, 16), np.int32)}
        req = ServeRequest(rid=0, batch=batch,
                           max_new_tokens=10 * CACHE_LEN)
        with pytest.raises(ValueError, match=f"{role}-rank capacity"):
            fab.submit(req, 0.0)
        assert fab.scheduler.num_waiting == 0    # nothing half-queued
    finally:
        fab.close()


def test_fabric_reset_back_to_back_trials(bundle):
    """Satellite: back-to-back fabric runs must not leak stats — the
    scheduler's rid-keyed arrival/accounting maps are cleared by
    reset(), so trial 2's percentiles cover exactly trial 2."""
    cfg, model, params = bundle
    fab = _fabric(model, params, "disagg")
    try:
        reqs1 = _trace_requests(cfg, n=4, seed=1)
        _drain(fab, reqs1)
        assert fab.stats()["n"] == 4
        assert len(fab.scheduler.req_log) == 4
        fab.reset()
        assert fab.scheduler.req_log == {}
        assert fab.stats().get("n", 0.0) == 0.0
        assert all(w.total_steps == 0 for w in fab.workers)
        # same rids again (every trial restarts at rid 0)
        reqs2 = _trace_requests(cfg, n=4, seed=2)
        _drain(fab, reqs2)
        st = fab.stats()
        assert st["n"] == 4
        assert st["n_migrations"] == 4
        assert sorted(fab.scheduler.req_log) == [0, 1, 2, 3]
        assert all(fab.scheduler.req_log[r.rid] is r for r in reqs2)
    finally:
        fab.close()


# ---------------------------------------------------------------------------
# transport + engine role plumbing
# ---------------------------------------------------------------------------

class _StubModel:
    @staticmethod
    def init_paged_cache(num_blocks, block_size, num_rows=0):
        shape = (2, num_blocks, block_size, 1, 2)
        return {"k": jnp.zeros(shape, jnp.float32),
                "v": jnp.zeros(shape, jnp.float32)}


def test_transport_moves_exact_blocks():
    from repro.core.comm import threadcomm_init
    from repro.core.compat import make_mesh
    from repro.serve.block_pool import PagedKVCache

    mesh = make_mesh((1,), ("serve",))
    comm = threadcomm_init(mesh, process_axes=(), thread_axes=("serve",))
    comm.start()
    try:
        src = PagedKVCache(_StubModel, num_blocks=6, block_size=4,
                           num_slots=2, max_blocks_per_req=4)
        dst = PagedKVCache(_StubModel, num_blocks=6, block_size=4,
                           num_slots=2, max_blocks_per_req=4)
        # fill the src pool with distinguishable block contents
        marks = jnp.arange(6, dtype=jnp.float32)[None, :, None, None, None]
        src.swap_buffers({"k": jnp.broadcast_to(
            marks, src.buffers["k"].shape).astype(jnp.float32) + 1.0,
            "v": jnp.broadcast_to(
            marks, src.buffers["v"].shape).astype(jnp.float32) + 100.0})
        tp = KVBlockTransport(comm)
        cost = tp.migrate(src, dst, [4, 1], [0, 3])
        out = np.asarray(dst.buffers["k"])
        assert np.all(out[:, 0] == 5.0)          # src block 4 -> dst 0
        assert np.all(out[:, 3] == 2.0)          # src block 1 -> dst 3
        assert np.all(out[:, 1] == 0.0)          # untouched
        assert np.all(np.asarray(dst.buffers["v"])[:, 0] == 104.0)
        assert cost > 0.0
        assert tp.n_blocks_moved == 2 and tp.n_migrations == 1
        assert tp.bytes_moved == 2 * tp.block_nbytes(src)
        with pytest.raises(ValueError, match="disagree"):
            tp.migrate(src, dst, [0, 1], [2])
    finally:
        comm.finish()
        comm.free()


def test_engine_role_validation(bundle):
    cfg, model, params = bundle
    with pytest.raises(ValueError, match="role"):
        _single_engine(model, params, role="router")
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(model, params, cache_len=CACHE_LEN, num_slots=2,
                         kv_layout="slot", role="prefill")


def test_prefill_role_leases_prompt_only(bundle):
    """A prefill-rank engine leases blocks for the prompt alone (the
    generated tokens' KV lands on the decode rank), so its pool admits
    far more concurrent prefills than a full engine could."""
    cfg, model, params = bundle
    eng = _single_engine(model, params, role="prefill")
    batch = {"tokens": np.zeros((1, 16), np.int32)}
    req = ServeRequest(rid=7, batch=batch, max_new_tokens=32)
    assert eng._token_budget(req) == 16
    eng.submit(req, 0.0)
    steps = 0
    while not eng.ready_handoffs:
        eng.step(0.0)
        steps += 1
        assert steps < 50
    h = eng.ready_handoffs[0]
    assert h.req is req and req.state == "migrating"
    assert h.length == 16
    assert len(h.blocks) == -(-16 // BLOCK)      # prompt blocks only
    assert req.generated == 1
    # the migrating decode state is coherent: next position is the
    # prompt end, and the device-held next-input token is the first
    # sampled token recorded in the output buffer
    state = eng.handoff_state(h.slot)
    assert int(np.asarray(state["pos"])) == 16
    assert int(np.asarray(state["tok"]).ravel()[0]) == int(h.out[0])
    assert eng.num_decoding == 0                 # never enters decode here
    # release returns the lease
    taken = eng.take_handoffs()
    assert taken == [h] and not eng.ready_handoffs
    eng.release_handoff(h.slot)
    assert eng.kv.pool.num_free == eng.kv.pool.num_blocks


# ---------------------------------------------------------------------------
# protocol pricing
# ---------------------------------------------------------------------------

def test_kv_migration_latency_pricing():
    m = protocol.HostModel()
    one = protocol.kv_migration_latency(8192, 8192, m)
    assert one == pytest.approx(
        m.t_handshake + protocol.interthread_latency(8192, m))
    four = protocol.kv_migration_latency(4 * 8192, 8192, m)
    assert four == pytest.approx(
        m.t_handshake + 4 * protocol.interthread_latency(8192, m))
    # a partial tail block is priced at its own (smaller) payload
    tail = protocol.kv_migration_latency(8192 + 100, 8192, m)
    assert one < tail < protocol.kv_migration_latency(2 * 8192, 8192, m)
    with pytest.raises(ValueError):
        protocol.kv_migration_latency(8192, 0, m)
