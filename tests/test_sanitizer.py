"""Runtime threadcomm sanitizer (DESIGN.md §11): seeded-violation unit
tests for every detector, the matching clean-path negatives, and the
permanent (sanitizer-independent) leak checks on the pools.

Each detector is demonstrated the way CI would hit it: a deliberately
wrong program is run with the sanitizer installed and must produce
exactly the expected finding; the corrected program must stay silent.
The ``uninstalled`` tests prove the hooks are inert when the sanitizer
is off — instrumented code pays one ``None`` check and nothing else.
"""

import warnings

import jax.numpy as jnp
import pytest

from repro.analysis import sanitizer as S
from repro.core.comm import Request, threadcomm_init
from repro.core.compat import make_mesh
from repro.serve.block_pool import BlockPool
from repro.serve.kv_cache import (LeaseLeakError, LeaseLeakWarning,
                                  SlotError)


@pytest.fixture
def san():
    s = S.install()
    yield s
    S.uninstall()


@pytest.fixture(scope="module")
def tc():
    mesh = make_mesh((1,), ("ranks",))
    comm = threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))
    yield comm
    if comm._active:
        comm.finish()
    comm.free()


def _window(tc):
    if not tc._active:
        tc.start()


# ---------------------------------------------------------------------------
# unmatched requests
# ---------------------------------------------------------------------------

def test_unmatched_request_at_finish(san, tc):
    _window(tc)
    Request(tc, "isend", jnp.zeros((2,)))
    tc.finish()
    hits = san.findings_of("unmatched-request")
    assert len(hits) == 1
    assert "isend" in hits[0].message
    assert "finish()" in hits[0].message
    assert "test_sanitizer" in hits[0].site   # caller, not comm.py


def test_waited_request_is_matched(san, tc):
    _window(tc)
    Request(tc, "isend", jnp.zeros((2,))).wait()
    tc.finish()
    assert san.findings == []


def test_tested_request_is_matched(san, tc):
    _window(tc)
    r = Request(tc, "isend", jnp.zeros((2,)))
    done, _ = r.test()
    assert done
    tc.finish()
    assert san.findings == []


def test_strict_raises_at_finish(tc):
    S.install(strict=True)
    try:
        _window(tc)
        Request(tc, "isend", jnp.zeros((2,)))
        with pytest.raises(S.SanitizerError, match="unmatched-request"):
            tc.finish()
    finally:
        S.uninstall()
        # strict raised before finish() could flip the window; close it
        if tc._active:
            tc.finish()


def test_assert_clean_reports_pending(san, tc):
    _window(tc)
    r = Request(tc, "isend", jnp.zeros((2,)))
    with pytest.raises(S.SanitizerError, match="never completed"):
        san.assert_clean()
    r.wait()
    tc.finish()
    san.assert_clean()


# ---------------------------------------------------------------------------
# accidental-serialization hazards (paper §2)
# ---------------------------------------------------------------------------

def test_cross_stream_hazard_same_comm(san, tc):
    _window(tc)
    sub = tc.dup()

    def body(x):
        with tc.stream("hz-a"):
            r1 = sub.iallreduce(x)
        with tc.stream("hz-b"):
            r2 = sub.iallreduce(x)
        r1.wait()
        r2.wait()
        return x

    tc.run(body, jnp.zeros((1,)))
    hits = san.findings_of("serialization-hazard")
    assert len(hits) == 1
    assert "dup()" in hits[0].message
    tc.finish()


def test_no_hazard_on_dup_comms(san, tc):
    _window(tc)
    sa, sb = tc.dup(), tc.dup()

    def body(x):
        with tc.stream("dp-a"):
            r1 = sa.iallreduce(x)
        with tc.stream("dp-b"):
            r2 = sb.iallreduce(x)
        r1.wait()
        r2.wait()
        return x

    tc.run(body, jnp.zeros((1,)))
    assert san.findings_of("serialization-hazard") == []
    tc.finish()


def test_no_hazard_when_wait_orders_streams(san, tc):
    _window(tc)
    sub = tc.dup()

    def body(x):
        with tc.stream("or-a"):
            r1 = sub.iallreduce(x)
        r1.wait()          # HB edge: completion flows into what follows
        with tc.stream("or-b"):
            r2 = sub.iallreduce(x)
        r2.wait()
        return x

    tc.run(body, jnp.zeros((1,)))
    assert san.findings_of("serialization-hazard") == []
    tc.finish()


def test_no_hazard_within_one_stream(san, tc):
    _window(tc)
    sub = tc.dup()

    def body(x):
        with tc.stream("sq"):
            r1 = sub.iallreduce(x)
            r2 = sub.iallreduce(x)
        r1.wait()
        r2.wait()
        return x

    tc.run(body, jnp.zeros((1,)))
    assert san.findings_of("serialization-hazard") == []
    tc.finish()


# ---------------------------------------------------------------------------
# lease ledger: double free with provenance, leaks at reset
# ---------------------------------------------------------------------------

def test_double_free_provenance(san):
    pool = BlockPool(8, 4)
    blocks = pool.alloc(2, "req-7")
    pool.free(blocks)
    with pytest.raises(SlotError) as ei:
        pool.free(blocks)
    # the permanent error now carries the ledger's provenance
    assert "allocated at" in str(ei.value)
    assert "first freed at" in str(ei.value)
    assert "test_sanitizer" in str(ei.value)
    hits = san.findings_of("double-free")
    assert len(hits) == 1
    assert "req-7" in hits[0].message


def test_lease_leak_at_reset(san):
    pool = BlockPool(8, 4)
    pool.alloc(3, "leaker")
    with pytest.warns(LeaseLeakWarning, match="leaker"):
        pool.reset()
    hits = san.findings_of("lease-leak")
    assert len(hits) == 3
    assert all("allocated at" in h.message for h in hits)


def test_clean_reset_no_findings(san):
    pool = BlockPool(8, 4)
    pool.free(pool.alloc(3, "tidy"))
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pool.reset()
    assert san.findings == []


# ---------------------------------------------------------------------------
# shared refcounts (prefix caching): N-way provenance end to end
# ---------------------------------------------------------------------------

def test_shared_ref_double_free_provenance(san):
    """A double free on a prefix-shared block reports the WHOLE chain:
    allocation, every ref() (who and where), every shared (non-final)
    free, and the final free — not just the allocator."""
    pool = BlockPool(8, 4)
    [b] = pool.alloc(1, "req-a")
    pool.ref(b, owner="prefix-cache")       # shared lease
    pool.free([b])                          # req-a done (non-final drop)
    pool.free([b])                          # cache evicts (final)
    with pytest.raises(SlotError) as ei:
        pool.free([b])                      # the bug under test
    msg = str(ei.value)
    assert "shared 2-way" in msg
    assert "ref'd at" in msg and "'prefix-cache'" in msg
    assert "allocated at" in msg and "first freed at" in msg
    assert "shared refs freed at" in msg
    assert "test_sanitizer" in msg          # caller sites, not pool code
    assert len(san.findings_of("double-free")) == 1


def test_trie_parked_leak_named_at_reset(san):
    """A bare pool.reset() while the prefix cache still holds parked
    blocks names the cache's shared reference in each leak finding —
    the trie's +1 is a lease like any other."""
    from repro.serve.prefix_cache import PrefixCache
    pool = BlockPool(8, 4)
    cache = PrefixCache(pool)
    blocks = pool.alloc(2, "req-0")
    cache.insert(list(range(8)), blocks)    # trie refs both blocks
    pool.free(blocks)                       # request done -> parked
    with pytest.warns(LeaseLeakWarning):
        pool.reset()
    hits = san.findings_of("lease-leak")
    assert len(hits) == 2
    assert all("prefix-cache" in h.message for h in hits)
    assert all("allocated at" in h.message for h in hits)
    assert all("shared 2-way" in h.message for h in hits)
    # the pool told the cache to drop its index (without re-freeing)
    assert cache.num_cached == 0 and pool.num_free == 8


def test_shared_lifecycle_clean(san):
    """The balanced negative: insert -> park -> warm lease -> park ->
    clear leaves the pool fully free and the sanitizer silent."""
    from repro.serve.prefix_cache import PrefixCache
    pool = BlockPool(8, 4)
    cache = PrefixCache(pool)
    toks = list(range(8))
    blocks = pool.alloc(2, "req-0")
    cache.insert(toks, blocks)
    pool.free(blocks)                        # parked under the trie
    hit = cache.lookup(toks + [9], limit=8)
    assert hit.tokens == 8
    cache.lease(hit, "req-1")                # warm reuse
    pool.free(hit.blocks)                    # req-1 done -> parked again
    cache.clear()                            # cache drops its own refs
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        pool.reset()
    assert san.findings == []


# ---------------------------------------------------------------------------
# permanent pool checks (sanitizer NOT installed)
# ---------------------------------------------------------------------------

@pytest.fixture
def no_san():
    """Force the uninstalled state (REPRO_SANITIZE=1 in the environment
    auto-installs at import; these tests prove the permanent checks
    stand on their own)."""
    S.uninstall()
    yield
    S.uninstall()


def test_reset_warns_without_sanitizer(no_san):
    assert S.active() is None
    pool = BlockPool(8, 4)
    pool.alloc(1, "bare")
    with pytest.warns(LeaseLeakWarning, match="bare"):
        pool.reset()


def test_reset_strict_raises_without_sanitizer(no_san):
    assert S.active() is None
    pool = BlockPool(8, 4)
    pool.alloc(1, "bare")
    with pytest.raises(LeaseLeakError, match="bare"):
        pool.reset(strict=True)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", LeaseLeakWarning)
        pool.reset()


def test_double_free_message_without_sanitizer(no_san):
    assert S.active() is None
    pool = BlockPool(8, 4)
    blocks = pool.alloc(1, "bare")
    pool.free(blocks)
    with pytest.raises(SlotError, match="last owner 'bare'"):
        pool.free(blocks)


# ---------------------------------------------------------------------------
# migration completeness
# ---------------------------------------------------------------------------

class _StubModel:
    @staticmethod
    def init_paged_cache(num_blocks, block_size, num_rows=0):
        shape = (2, num_blocks, block_size, 1, 2)
        return {"k": jnp.zeros(shape, jnp.float32),
                "v": jnp.zeros(shape, jnp.float32)}


def _paged_pair():
    from repro.serve.block_pool import PagedKVCache
    mk = lambda: PagedKVCache(_StubModel, num_blocks=6, block_size=4,
                              num_slots=2, max_blocks_per_req=4)
    return mk(), mk()


def test_complete_migration_is_clean(san, tc):
    from repro.serve.fabric.transport import KVBlockTransport
    _window(tc)
    src, dst = _paged_pair()
    tp = KVBlockTransport(tc)
    tp.migrate(src, dst, [0, 1], [2, 3])
    tc.finish()
    assert san.findings == []
    san.assert_clean()


def test_interrupted_migration_reported(san, tc):
    from repro.serve.fabric.transport import KVBlockTransport
    _window(tc)
    src, dst = _paged_pair()
    tp = KVBlockTransport(tc)
    real_copy, calls = tp._copy, [0]

    def bomb(*a):
        calls[0] += 1
        if calls[0] == 2:
            raise RuntimeError("simulated device loss")
        return real_copy(*a)

    tp._copy = bomb
    with pytest.raises(RuntimeError, match="device loss"):
        tp.migrate(src, dst, [0, 1, 4], [2, 3, 5])
    tc.finish()
    # the finally-block waitall completed the issued prefix, so no
    # request leaks — but the migration itself never reached its
    # completion point and must be reported
    assert san.findings_of("unmatched-request") == []
    hits = san.findings_of("migration-incomplete")
    assert len(hits) == 1
    assert "3 blocks" in hits[0].message


# ---------------------------------------------------------------------------
# hooks are inert when uninstalled
# ---------------------------------------------------------------------------

def test_uninstalled_comm_hooks_inert(no_san, tc):
    assert S.active() is None
    _window(tc)
    Request(tc, "isend", jnp.zeros((2,)))   # leaked on purpose
    tc.finish()                             # must not raise or record


def test_install_is_fresh_each_time(san, tc):
    _window(tc)
    Request(tc, "isend", jnp.zeros((2,)))
    tc.finish()
    assert len(san.findings) == 1
    fresh = S.install()
    assert fresh.findings == []
    S.uninstall()
