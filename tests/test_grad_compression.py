"""bf16 inter-pod gradient compression (threadcomm trainer) parity."""

from tests.helpers import run_case


def test_grad_compression_parity():
    run_case("grad_compression_parity", ndev=8, timeout=600)
