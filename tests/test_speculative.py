"""Speculative decoding on the paged engine (DESIGN.md §14): greedy
token identity against the non-speculative paged baseline (the
acceptance bar — speculation must be an optimization, never a sampler),
acceptance accounting, structural rollback of rejected draft KV, the
capability/composition gates, and the per-dispatch pricing the fabric
router consumes."""

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import protocol
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import ContinuousEngine, ServeRequest
from repro.serve.fabric.worker import EngineWorker

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)


def _bundle(arch="gemma-2b", seed=0):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


def _prompt(cfg, B=4, S=8):
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    return {"tokens": batch["tokens"]}


def _paged(model, params, **kw):
    kw.setdefault("cache_len", 24)
    kw.setdefault("num_slots", 4)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("kv_layout", "paged")
    kw.setdefault("block_size", 8)
    return ContinuousEngine(model, params, **kw)


# ---------------------------------------------------------------------------
# token identity (the acceptance bar)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 2, 3])
def test_spec_greedy_token_identity(k):
    """Self-drafted speculation at every k emits exactly the tokens the
    non-speculative paged engine emits — acceptance is an optimization,
    not a sampler."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    base = _paged(model, params).generate(prompt, 12)
    spec = _paged(model, params, speculate=k).generate(prompt, 12)
    assert np.array_equal(base, spec)


def test_spec_distinct_drafter_token_identity():
    """A drafter with DIFFERENT weights (separately initialized same
    arch) disagrees with the target almost everywhere — near-zero
    acceptance — yet the output must still be token-identical: every
    emitted token is the target's own argmax, and rejected draft KV rows
    roll back structurally through the block tables."""
    cfg, model, params = _bundle()
    _, dmodel, dparams = _bundle(seed=1)
    prompt = _prompt(cfg, B=3, S=8)
    base = _paged(model, params).generate(prompt, 10)
    eng = _paged(model, params, speculate=3,
                 draft_model=dmodel, draft_params=dparams)
    assert np.array_equal(base, eng.generate(prompt, 10))
    st = eng.spec_stats()
    # every dispatch still emits >= 1 token (the target's own)
    assert st["accepted_per_dispatch"] >= 1.0


def test_spec_multi_chunk_prompts_identity():
    """Prompts spanning several chunks and blocks (drafter pool deposits
    in lockstep with the target's chunked prefill)."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=3, S=21)
    base = _paged(model, params, cache_len=32, num_slots=3,
                  prefill_chunk=6, block_size=4).generate(prompt, 8)
    spec = _paged(model, params, cache_len=32, num_slots=3,
                  prefill_chunk=6, block_size=4,
                  speculate=2).generate(prompt, 8)
    assert np.array_equal(base, spec)


def test_spec_eos_truncation_identity_and_lease_release():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    ref = _paged(model, params, cache_len=40, num_slots=2).generate(
        prompt, 16)
    eos = int(ref[0, 3])               # force an early EOS for row 0
    base = _paged(model, params, cache_len=40, num_slots=2,
                  eos_id=eos).generate(prompt, 16)
    eng = _paged(model, params, cache_len=40, num_slots=2, eos_id=eos,
                 speculate=3)
    out = eng.generate(prompt, 16)
    assert np.array_equal(base, out)
    # both pools fully released (drafter leases freed with the target's)
    assert eng.kv.num_live == 0
    assert eng.kv.num_free_blocks == eng.kv.pool.num_blocks
    assert eng.draft_kv.num_live == 0


def test_spec_k_exceeds_remaining_budget():
    """k larger than max_new_tokens: the per-round draft width clamps to
    the remaining budget (never overruns the lease or output buffer)."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    base = _paged(model, params).generate(prompt, 2)
    spec = _paged(model, params, speculate=4).generate(prompt, 2)
    assert np.array_equal(base, spec)


def test_spec_block_recycling_identity():
    """More requests than the pools hold at once: both pools recycle
    blocks across requests in lockstep and stale draft pages must not
    leak into verification."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    base = _paged(model, params, num_slots=2, num_blocks=6).generate(
        prompt, 10)
    spec = _paged(model, params, num_slots=2, num_blocks=6,
                  speculate=2).generate(prompt, 10)
    assert np.array_equal(base, spec)


def test_spec_engine_reset_reusable():
    cfg, model, params = _bundle()
    eng = _paged(model, params, speculate=2)
    eng.generate(_prompt(cfg, B=2, S=8), 4)
    eng.reset()
    assert eng.kv.num_live == 0 and eng.draft_kv.num_live == 0
    assert eng.scheduler.n_spec_dispatches == 0     # counters cleared
    out = eng.generate(_prompt(cfg, B=2, S=8), 4)
    assert out.shape == (2, 4)


# ---------------------------------------------------------------------------
# acceptance accounting
# ---------------------------------------------------------------------------

def test_spec_self_draft_accepts_more_than_one_per_dispatch():
    """Self-speculation accepts (nearly) everything: mean accepted
    tokens per verify dispatch must exceed 1 — the whole point of the
    fused k-token dispatch."""
    cfg, model, params = _bundle()
    eng = _paged(model, params, cache_len=40, num_slots=4, speculate=3)
    eng.generate(_prompt(cfg, B=4, S=8), 16)
    st = eng.spec_stats()
    assert st["speculate_k"] == 3.0
    assert st["spec_dispatches"] > 0
    assert st["accepted_per_dispatch"] > 1.0
    assert st["acceptance_rate"] == pytest.approx(1.0)
    assert st["spec_modeled_cost_us"] > 0.0
    # observed yield feeds the router's per-dispatch pricing
    assert eng.decode_tokens_per_dispatch == pytest.approx(
        st["accepted_per_dispatch"])


def test_spec_stats_empty_when_off():
    cfg, model, params = _bundle()
    eng = _paged(model, params)
    assert eng.spec_stats() == {}
    assert eng.decode_tokens_per_dispatch == 1.0


# ---------------------------------------------------------------------------
# gates (capability, composition, sampling)
# ---------------------------------------------------------------------------

def test_spec_requires_paged_layout():
    cfg, model, params = _bundle()
    with pytest.raises(ValueError, match="paged"):
        ContinuousEngine(model, params, cache_len=24, num_slots=2,
                         prefill_chunk=4, speculate=2)


def test_spec_carried_state_family_raises_naming_capability():
    """SSM families carry recurrent state per emitted token — a k-token
    verify cannot roll state back — so the gate raises at construction,
    naming the missing capability."""
    cfg, model, params = _bundle("mamba2-370m")
    with pytest.raises(ValueError, match="speculative"):
        ContinuousEngine(model, params, cache_len=24, num_slots=2,
                         prefill_chunk=8, kv_layout="paged", block_size=4,
                         speculate=2)


def test_spec_prefix_cache_composition_rejected():
    cfg, model, params = _bundle()
    with pytest.raises(ValueError, match="prefix"):
        _paged(model, params, speculate=2, prefix_cache=True)


def test_spec_temperature_rejected_at_submit():
    cfg, model, params = _bundle()
    eng = _paged(model, params, speculate=2)
    batch = make_synthetic_batch(cfg, 1, 8, compute_dtype="float32")
    req = ServeRequest(rid=0, batch={"tokens": np.asarray(batch["tokens"])},
                       max_new_tokens=4, temperature=0.7)
    with pytest.raises(ValueError, match="temperature"):
        eng.submit(req)
    assert eng.scheduler.num_waiting == 0


def test_spec_negative_k_rejected():
    cfg, model, params = _bundle()
    with pytest.raises(ValueError, match="speculate"):
        _paged(model, params, speculate=-1)


def test_spec_drafter_without_params_rejected():
    cfg, model, params = _bundle()
    with pytest.raises(ValueError, match="draft_params"):
        _paged(model, params, speculate=2, draft_model=model)


# ---------------------------------------------------------------------------
# pricing (protocol model + fabric router)
# ---------------------------------------------------------------------------

def test_protocol_speculative_verify_latency_monotone():
    lats = [protocol.speculative_verify_latency(k) for k in (1, 2, 4, 8)]
    assert all(b > a for a, b in zip(lats, lats[1:]))
    # sublinear in k: the stream-claim handshake amortizes over the
    # fused dispatch, so doubling k less than doubles the round price —
    # the messaging the fusion saves vs per-token dispatches
    assert lats[3] < 2 * lats[2] and lats[2] < 2 * lats[1]
    with pytest.raises(ValueError, match="k"):
        protocol.speculative_verify_latency(0)


def test_worker_predicted_cost_prices_per_dispatch():
    """The JSQ load model divides decode work by the engine's per-
    dispatch token yield: a speculative rank predicts FEWER dispatches
    for the same max_new_tokens (the old hardcoded one-token-per-
    dispatch assumption overpriced speculative ranks)."""
    cfg, model, params = _bundle()
    plain = EngineWorker(0, "full", _paged(model, params))
    spec = EngineWorker(1, "full", _paged(model, params, speculate=3))
    batch = make_synthetic_batch(cfg, 1, 8, compute_dtype="float32")
    req = ServeRequest(rid=0, batch={"tokens": np.asarray(batch["tokens"])},
                       max_new_tokens=12)
    c_plain = plain.predicted_cost_s(req, decode_only=True)
    c_spec = spec.predicted_cost_s(req, decode_only=True)
    # prior yield (k+2)/2 = 2.5 -> ceil(12/2.5) = 5 verify rounds priced
    # at the round latency, vs 12 single-token handoffs
    assert c_spec == pytest.approx(
        5 * protocol.speculative_verify_latency(3, 4))
    assert c_plain == pytest.approx(12 * protocol.interthread_latency(4))
    # the yield parameterization is live: a better-accepting engine
    # (higher per-dispatch tokens) predicts proportionally fewer rounds
    spec.engine.scheduler.record_spec_dispatch(4, 3, 3, 0.0)
    assert spec.engine.decode_tokens_per_dispatch == pytest.approx(4.0)
    assert spec.predicted_cost_s(req, decode_only=True) == pytest.approx(
        3 * protocol.speculative_verify_latency(3, 4))
