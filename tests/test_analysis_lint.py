"""Static invariant lint (DESIGN.md §11): per-rule positive and negative
fixtures, pragma suppression, rule selection, and the gate the CI
``analysis`` job enforces — the repo's own ``src/`` tree lints clean.

Every rule is exercised both ways: the positive fixture must be flagged
(and must STOP being flagged when the rule is disabled via ``rules=`` —
the proof the finding comes from that rule and not a neighbour), and the
negative fixture — the idiomatic correct form — must stay clean.
"""

import os
import textwrap

import pytest

from repro.analysis.lint import lint_paths, lint_source, main
from repro.analysis.rules import ALL_RULES, RULES_BY_NAME

REPO_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _lint(snippet, rules=None):
    return lint_source(textwrap.dedent(snippet), "<fixture>", rules=rules)


def _rules_hit(snippet, rules=None):
    return sorted({f.rule for f in _lint(snippet, rules=rules)})


def _other_rules(name):
    return [r.name for r in ALL_RULES if r.name != name]


# ---------------------------------------------------------------------------
# scatter-drop
# ---------------------------------------------------------------------------

SCATTER_BAD = """
    def _admit(state, slot, tok):
        return state["tok"].at[slot].set(tok)
"""

SCATTER_GOOD = """
    def _admit(state, slot, tok):
        return state["tok"].at[slot].set(tok, mode="drop")
"""

SCATTER_UNRELATED_INDEX = """
    def shift(x, i):
        return x.at[i].set(0.0)
"""


def test_scatter_drop_positive():
    assert _rules_hit(SCATTER_BAD) == ["scatter-drop"]


def test_scatter_drop_negative():
    assert _rules_hit(SCATTER_GOOD) == []


def test_scatter_drop_ignores_unrelated_index_names():
    # only slot/block-table/park-derived indices are in scope
    assert _rules_hit(SCATTER_UNRELATED_INDEX) == []


def test_scatter_drop_disabled():
    assert _rules_hit(SCATTER_BAD, rules=_other_rules("scatter-drop")) == []


# ---------------------------------------------------------------------------
# state-thread
# ---------------------------------------------------------------------------

# the index is innocuously named ("idx"), so scatter-drop does NOT see
# it — the carried-state TARGET (conv/ssm leaves) is what puts the
# write in scope for state-thread (DESIGN.md §13)
STATE_BAD = """
    def scatter_state(cache, idx, new_conv):
        return cache["conv"].at[idx].set(new_conv)
"""

STATE_BAD_ATTR = """
    def scatter_state(state, idx, v):
        return state.ssm.at[idx].add(v)
"""

STATE_GOOD = """
    def scatter_state(cache, idx, new_conv):
        return cache["conv"].at[idx].set(new_conv, mode="drop")
"""

STATE_UNRELATED_TARGET = """
    def scatter(x, idx, v):
        return x.at[idx].set(v)
"""


def test_state_thread_positive_dict_leaf():
    assert _rules_hit(STATE_BAD) == ["state-thread"]


def test_state_thread_positive_attribute_leaf():
    assert _rules_hit(STATE_BAD_ATTR) == ["state-thread"]


def test_state_thread_negative_drop_mode():
    assert _rules_hit(STATE_GOOD) == []


def test_state_thread_ignores_unrelated_targets():
    assert _rules_hit(STATE_UNRELATED_TARGET) == []


def test_state_thread_disabled():
    assert _rules_hit(STATE_BAD, rules=_other_rules("state-thread")) == []


def test_state_thread_and_scatter_drop_complement():
    # a state leaf scattered through a slot-named index trips BOTH
    # rules without drop mode, and neither with it
    src = """
    def scatter(cache, slots, v):
        return cache["ssm"].at[slots].set(v)
    """
    assert _rules_hit(src) == ["scatter-drop", "state-thread"]
    fixed = src.replace(".set(v)", '.set(v, mode="drop")')
    assert _rules_hit(fixed) == []


# ---------------------------------------------------------------------------
# donated-use
# ---------------------------------------------------------------------------

DONATED_BAD = """
    import jax

    step = jax.jit(_step_impl, donate_argnums=(0,))

    def drive(state, x):
        new = step(state, x)
        return new, state["tok"]
"""

DONATED_GOOD = """
    import jax

    step = jax.jit(_step_impl, donate_argnums=(0,))

    def drive(state, x):
        new = step(state, x)
        return new, new["tok"]
"""

DONATED_REBIND = """
    import jax

    step = jax.jit(_step_impl, donate_argnums=(0,))

    def drive(state, x):
        state = step(state, x)
        return state["tok"]
"""


def test_donated_use_positive():
    hits = _lint(DONATED_BAD)
    assert [f.rule for f in hits] == ["donated-use"]
    assert "state" in hits[0].message


def test_donated_use_negative():
    assert _rules_hit(DONATED_GOOD) == []


def test_donated_use_rebind_revives():
    # the idiomatic fix: rebind the name to the jit output
    assert _rules_hit(DONATED_REBIND) == []


def test_donated_use_disabled():
    assert _rules_hit(DONATED_BAD, rules=_other_rules("donated-use")) == []


# ---------------------------------------------------------------------------
# request-leak
# ---------------------------------------------------------------------------

REQUEST_BAD = """
    def exchange(comm, x):
        r = comm.iallreduce(x)
        return x
"""

REQUEST_GOOD = """
    def exchange(comm, x):
        r = comm.iallreduce(x)
        return r.wait()
"""

REQUEST_WAITALL = """
    def exchange(comm, xs):
        reqs = []
        for x in xs:
            reqs.append(comm.iallreduce(x))
        waitall(reqs)
"""

REQUEST_EXC_PATH = """
    def migrate(comm, xs):
        reqs = []
        try:
            for x in xs:
                reqs.append(comm.isend(x, pairs))
            waitall(reqs)
        finally:
            cleanup()
"""

REQUEST_EXC_GOOD = """
    def migrate(comm, xs):
        reqs = []
        try:
            for x in xs:
                reqs.append(comm.isend(x, pairs))
        finally:
            waitall(reqs)
"""


def test_request_leak_positive():
    assert _rules_hit(REQUEST_BAD) == ["request-leak"]


def test_request_leak_negative():
    assert _rules_hit(REQUEST_GOOD) == []


def test_request_leak_waitall_completes():
    assert _rules_hit(REQUEST_WAITALL) == []


def test_request_leak_exception_path():
    # completion inside the try body does not cover the exception path
    hits = _lint(REQUEST_EXC_PATH)
    assert [f.rule for f in hits] == ["request-leak"]
    assert "finally" in hits[0].message


def test_request_leak_exception_path_fixed():
    assert _rules_hit(REQUEST_EXC_GOOD) == []


def test_request_leak_disabled():
    assert _rules_hit(REQUEST_BAD, rules=_other_rules("request-leak")) == []


# ---------------------------------------------------------------------------
# span-leak
# ---------------------------------------------------------------------------

SPAN_BAD = """
    def rank_step(tr, engine):
        sp = tr.span("rank_step")
        return engine.step()
"""

SPAN_GOOD_END = """
    def rank_step(tr, engine):
        sp = tr.span("rank_step")
        out = engine.step()
        sp.end()
        return out
"""

SPAN_GOOD_WITH = """
    def rank_step(tr, engine):
        with tr.span("rank_step"):
            return engine.step()
"""

SPAN_GOOD_ATTR = """
    def enter(self, tr):
        self._obs_span = tr.span("stream")
"""

SPAN_DISCARDED = """
    def rank_step(tr, engine):
        tr.span("rank_step")
        return engine.step()
"""

SPAN_EXC_PATH = """
    def rank_step(tr, engine):
        try:
            sp = tr.span("rank_step")
            out = engine.step()
            sp.end()
        finally:
            cleanup()
        return out
"""

SPAN_EXC_GOOD = """
    def rank_step(tr, engine):
        try:
            sp = tr.span("rank_step")
            out = engine.step()
        finally:
            sp.end()
        return out
"""


def test_span_leak_positive():
    assert _rules_hit(SPAN_BAD) == ["span-leak"]


def test_span_leak_discarded_at_call_site():
    assert _rules_hit(SPAN_DISCARDED) == ["span-leak"]


def test_span_leak_end_completes():
    assert _rules_hit(SPAN_GOOD_END) == []


def test_span_leak_with_form_safe():
    assert _rules_hit(SPAN_GOOD_WITH) == []


def test_span_leak_attribute_escape_safe():
    # stored on self: the owner (e.g. CommStream.__exit__) ends it
    assert _rules_hit(SPAN_GOOD_ATTR) == []


def test_span_leak_exception_path():
    # end() inside the try body does not cover the exception path
    hits = _lint(SPAN_EXC_PATH)
    assert [f.rule for f in hits] == ["span-leak"]
    assert "finally" in hits[0].message


def test_span_leak_exception_path_fixed():
    assert _rules_hit(SPAN_EXC_GOOD) == []


def test_span_leak_pragma():
    src = SPAN_BAD.replace('tr.span("rank_step")',
                           'tr.span("rank_step")  # lint: ok[span-leak]')
    assert _rules_hit(src) == []


def test_span_leak_disabled():
    assert _rules_hit(SPAN_BAD, rules=_other_rules("span-leak")) == []


# ---------------------------------------------------------------------------
# stream-order
# ---------------------------------------------------------------------------

STREAM_BAD = """
    def overlap(comm, x):
        with comm.stream("s") as s:
            y = comm.allreduce(x)
        return y
"""

STREAM_GOOD = """
    def overlap(comm, x):
        with comm.stream("s") as s:
            r = comm.iallreduce(x)
        return r.wait()
"""

USE_AFTER_FINISH = """
    def teardown(comm, x):
        comm.finish()
        return comm.allreduce(x)
"""

RESTART_OK = """
    def teardown(comm, x):
        comm.finish()
        comm.start()
        return comm.allreduce(x)
"""


def test_stream_order_blocking_in_stream():
    assert _rules_hit(STREAM_BAD) == ["stream-order"]


def test_stream_order_nonblocking_ok():
    assert _rules_hit(STREAM_GOOD) == []


def test_stream_order_use_after_finish():
    assert _rules_hit(USE_AFTER_FINISH) == ["stream-order"]


def test_stream_order_restart_reopens():
    assert _rules_hit(RESTART_OK) == []


def test_stream_order_disabled():
    assert _rules_hit(STREAM_BAD, rules=_other_rules("stream-order")) == []


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------

HOST_SYNC_BAD = """
    import jax

    def _decode_micro_step_impl(state, x):
        n = state["pos"].item()
        return state

    step = jax.jit(_decode_micro_step_impl, donate_argnums=(0,))
"""

HOST_SYNC_GOOD = """
    import jax

    def _decode_micro_step_impl(state, x):
        n = state["pos"] + 1
        return state

    step = jax.jit(_decode_micro_step_impl, donate_argnums=(0,))

    def host_driver(state):
        return state["pos"].item()
"""


def test_host_sync_positive():
    assert _rules_hit(HOST_SYNC_BAD) == ["host-sync"]


def test_host_sync_negative():
    # .item() outside the jit region is the host driver's business
    assert _rules_hit(HOST_SYNC_GOOD) == []


def test_host_sync_disabled():
    assert _rules_hit(HOST_SYNC_BAD, rules=_other_rules("host-sync")) == []


# ---------------------------------------------------------------------------
# pragmas, selection, syntax errors
# ---------------------------------------------------------------------------

def test_pragma_suppresses_named_rule():
    src = SCATTER_BAD.replace(".set(tok)", '.set(tok)  # lint: ok[scatter-drop]')
    assert _rules_hit(src) == []


def test_pragma_on_preceding_line():
    src = """
    def _admit(state, slot, tok):
        # lint: ok
        return state["tok"].at[slot].set(tok)
"""
    assert _rules_hit(src) == []


def test_pragma_wrong_rule_does_not_suppress():
    src = SCATTER_BAD.replace(".set(tok)", '.set(tok)  # lint: ok[host-sync]')
    assert _rules_hit(src) == ["scatter-drop"]


def test_unknown_rule_selection_rejected():
    with pytest.raises(ValueError):
        lint_source("x = 1", rules=["no-such-rule"])


def test_syntax_error_is_a_finding():
    hits = lint_source("def broken(:\n    pass")
    assert [f.rule for f in hits] == ["syntax"]


def test_rule_registry_complete():
    assert set(RULES_BY_NAME) == {"scatter-drop", "state-thread",
                                  "donated-use", "request-leak",
                                  "span-leak", "stream-order", "host-sync"}


# ---------------------------------------------------------------------------
# the gate: the repo's own tree lints clean
# ---------------------------------------------------------------------------

def test_repo_src_tree_is_clean():
    findings = lint_paths([REPO_SRC])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_cli_clean_exit(capsys):
    assert main([REPO_SRC]) == 0
    assert "clean:" in capsys.readouterr().out


def test_cli_violation_exit(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text(textwrap.dedent(SCATTER_BAD))
    assert main([str(tmp_path)]) == 1
    assert "scatter-drop" in capsys.readouterr().out
