"""Layer-level properties: chunked-vs-dense attention equivalence, chunked
cross-entropy vs direct, RoPE invariants, MoE routing invariants —
hypothesis-driven where shapes permit."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.models import layers as L


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@given(st.sampled_from([16, 32, 48, 64]), st.sampled_from([8, 16, 32]),
       st.sampled_from([None, 8, 24]), st.booleans())
@settings(max_examples=12, deadline=None)
def test_chunked_equals_full_attention(S, chunk, window, causal):
    ks = jax.random.split(jax.random.PRNGKey(S + chunk), 3)
    q = jax.random.normal(ks[0], (2, S, 3, 8))
    k = jax.random.normal(ks[1], (2, S, 3, 8))
    v = jax.random.normal(ks[2], (2, S, 3, 8))
    pos = jnp.arange(S)
    # window without causality can fully mask early rows; keep causal then
    if not causal and window is not None:
        causal = True
    full = L.full_attention(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                            window=window)
    chk = L.chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=causal,
                              window=window, chunk_q=chunk, chunk_k=chunk)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               atol=1e-5, rtol=1e-5)


def test_chunked_attention_cross_lengths():
    """Sq != Sk (prefill continuation) and non-divisible chunking."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 24, 2, 8))
    k = jax.random.normal(ks[1], (1, 56, 2, 8))
    v = jax.random.normal(ks[2], (1, 56, 2, 8))
    q_pos = jnp.arange(32, 56)
    k_pos = jnp.arange(56)
    full = L.full_attention(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=True)
    chk = L.chunked_attention(q, k, v, q_pos=q_pos, k_pos=k_pos, causal=True,
                              chunk_q=16, chunk_k=16)
    np.testing.assert_allclose(np.asarray(full), np.asarray(chk),
                               atol=1e-5, rtol=1e-5)


def test_attention_grads_finite_through_chunks():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 32, 2, 8))
    k = jax.random.normal(ks[1], (1, 32, 2, 8))
    v = jax.random.normal(ks[2], (1, 32, 2, 8))
    pos = jnp.arange(32)

    def f(q):
        return jnp.sum(L.chunked_attention(q, k, v, q_pos=pos, k_pos=pos,
                                           causal=True, chunk_q=8,
                                           chunk_k=8) ** 2)

    g = jax.grad(f)(q)
    assert jnp.all(jnp.isfinite(g))
    assert jnp.any(g != 0)


# ---------------------------------------------------------------------------
# chunked cross-entropy
# ---------------------------------------------------------------------------

@given(st.sampled_from([8, 24, 32]), st.sampled_from([4, 8, 16]),
       st.sampled_from([50, 64]))
@settings(max_examples=10, deadline=None)
def test_chunked_ce_equals_direct(S, chunk, V):
    key = jax.random.PRNGKey(S * chunk)
    ks = jax.random.split(key, 3)
    B, d, Vp = 2, 16, 64
    h = jax.random.normal(ks[0], (B, S, d))
    w = jax.random.normal(ks[1], (d, Vp)) * 0.1
    labels = jax.random.randint(ks[2], (B, S), 0, V)
    valid = labels >= (V // 4)   # some invalid rows
    loss_sum, n_valid = L.chunked_cross_entropy(
        h, w, labels, valid=valid, vocab_size=V, chunk=chunk)
    # direct reference
    logits = (h @ w).astype(jnp.float32)
    logits = jnp.where(jnp.arange(Vp) < V, logits, L.NEG_INF)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], -1)[..., 0]
    ref = jnp.sum((lse - gold) * valid)
    np.testing.assert_allclose(float(loss_sum), float(ref), rtol=1e-5)
    assert float(n_valid) == float(valid.sum())


def test_ce_padded_vocab_never_predicted():
    """Padded vocab ids must carry ~zero probability mass."""
    B, S, d, V, Vp = 1, 4, 8, 10, 16
    h = jax.random.normal(jax.random.PRNGKey(0), (B, S, d))
    w = jnp.zeros((d, Vp)).at[:, V:].set(100.0)   # push mass onto padding
    labels = jnp.zeros((B, S), jnp.int32)
    loss_sum, _ = L.chunked_cross_entropy(
        h, w, labels, valid=jnp.ones((B, S), bool), vocab_size=V, chunk=2)
    # if padding leaked, loss would be ~100+; masked it's ~log(10)
    assert float(loss_sum) / (B * S) < 5.0


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

@given(st.sampled_from([8, 16, 64]), st.integers(0, 1000))
@settings(max_examples=15, deadline=None)
def test_rope_preserves_norm_and_relativity(hd, offset):
    """RoPE is a rotation (norm-preserving) and q·k depends only on the
    relative distance."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(hd))
    q = jax.random.normal(k1, (1, 1, 1, hd))
    k = jax.random.normal(k2, (1, 1, 1, hd))

    def rot(x, pos):
        cos, sin = L.rope_cos_sin(jnp.array([pos]), hd, 10_000.0)
        return L.apply_rope(x, cos, sin)

    # norm preservation
    np.testing.assert_allclose(float(jnp.linalg.norm(rot(q, offset))),
                               float(jnp.linalg.norm(q)), rtol=1e-5)
    # relative property: <R(q,m), R(k,n)> == <R(q,m+s), R(k,n+s)>
    d1 = float(jnp.vdot(rot(q, 5), rot(k, offset)))
    d2 = float(jnp.vdot(rot(q, 5 + 17), rot(k, offset + 17)))
    np.testing.assert_allclose(d1, d2, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------

def _moe_cfg(**kw):
    from repro.configs import get_smoke_config
    import dataclasses
    return dataclasses.replace(get_smoke_config("olmoe-1b-7b"), **kw)


def test_moe_dropless_at_high_capacity():
    from repro.models import moe
    cfg = _moe_cfg(capacity_factor=8.0, moe_group_size=64)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe.moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux["moe_dropped"]) == 0.0
    assert jnp.all(jnp.isfinite(out))


def test_moe_drops_at_tiny_capacity():
    from repro.models import moe
    cfg = _moe_cfg(capacity_factor=0.1, moe_group_size=64)
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))
    out, aux = moe.moe_apply(p, x, cfg)
    assert float(aux["moe_dropped"]) > 0.0
    assert jnp.all(jnp.isfinite(out))


def test_moe_aux_losses_positive():
    from repro.models import moe
    cfg = _moe_cfg()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model))
    _, aux = moe.moe_apply(p, x, cfg)
    assert float(aux["moe_lb_loss"]) > 0.0
    assert float(aux["moe_z_loss"]) >= 0.0


# ---------------------------------------------------------------------------
# Mamba: chunked SSD == sequential recurrence
# ---------------------------------------------------------------------------

@given(st.sampled_from([16, 32, 64]), st.sampled_from([8, 16]))
@settings(max_examples=8, deadline=None)
def test_ssd_chunked_matches_recurrence(S, chunk):
    from repro.models.mamba import ssd_chunked
    from repro.kernels.ssd_scan.ref import ssd_scan_ref
    B, h, p, n = 1, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(S), 5)
    x = jax.random.normal(ks[0], (B, S, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, h))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, n)) * 0.5
    y, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
    ref = ssd_scan_ref(x.transpose(0, 2, 1, 3), dt.transpose(0, 2, 1),
                       A, Bm, Cm).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                               atol=1e-4, rtol=1e-4)
