"""Subprocess runner for multi-device tests.

JAX locks the device count at first backend init, and conftest keeps the
main pytest process at 1 CPU device (per the dry-run isolation rule). Tests
that need an N-device mesh run a named case from tests/mp_cases.py in a
fresh subprocess with XLA_FLAGS set.
"""

from __future__ import annotations

import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_case(case: str, ndev: int = 8, timeout: int = 300, args=()) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT, env.get("PYTHONPATH", "")])
    proc = subprocess.run(
        [sys.executable, "-m", "tests.mp_cases", case, *map(str, args)],
        capture_output=True, text=True, timeout=timeout, env=env, cwd=ROOT)
    if proc.returncode != 0:
        raise AssertionError(
            f"case {case!r} failed (rc={proc.returncode})\n"
            f"--- stdout ---\n{proc.stdout}\n--- stderr ---\n{proc.stderr}")
    assert "CASE-OK" in proc.stdout, proc.stdout
    return proc.stdout
