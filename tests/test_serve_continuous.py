"""Continuous-batching engine tests: greedy token parity against the
static baseline (the acceptance bar for the serving substrate), the
decode edge cases carried into both engines (EOS on the first token,
eos_id=-1 never-done, all-done early exit, temperature determinism under
a fixed seed), traffic-loop draining, and CommStream binding."""

import dataclasses

import jax
import numpy as np

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.core import threadcomm_init
from repro.core.compat import make_mesh
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import (CellQueueScheduler, ContinuousEngine, ServeRequest,
                         StaticEngine, make_trace)

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)


def _bundle(arch="gemma-2b", seed=0):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


def _prompt(cfg, B=4, S=8):
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    return {"tokens": batch["tokens"]}


# ---------------------------------------------------------------------------
# parity (acceptance criterion: token-identical greedy same-arrival batch)
# ---------------------------------------------------------------------------

def test_greedy_parity_same_arrival_batch():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    static = StaticEngine(model, params, cache_len=24).generate(prompt, 12)
    cont = ContinuousEngine(model, params, cache_len=24,
                            num_slots=4).generate(prompt, 12)
    assert np.array_equal(static, cont)


def test_greedy_parity_fewer_slots_than_requests():
    """Slot recycling: 2 slots serve 4 requests, tokens still identical."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=8)
    static = StaticEngine(model, params, cache_len=24).generate(prompt, 10)
    cont = ContinuousEngine(model, params, cache_len=24,
                            num_slots=2).generate(prompt, 10)
    assert np.array_equal(static, cont)


def test_parity_ssm_family():
    """The slot pool carries SSM/conv state too (mamba2)."""
    cfg, model, params = _bundle("mamba2-370m")
    prompt = _prompt(cfg, B=2, S=8)
    static = StaticEngine(model, params, cache_len=16).generate(prompt, 6)
    cont = ContinuousEngine(model, params, cache_len=16,
                            num_slots=2).generate(prompt, 6)
    assert np.array_equal(static, cont)


def test_continuous_ring_slots_long_decode():
    """Ring-buffer slots: cache_len = window < prompt+new, pages recycle
    in place and the slot footprint stays fixed (paged/ring KV)."""
    cfg = dataclasses.replace(get_smoke_config("hymba-1.5b"),
                              global_layers=())
    model = build_model(cfg, TRAIN, ServeConfig(ring_buffer=True), tp=1)
    params = model.init(jax.random.PRNGKey(1))
    eng = ContinuousEngine(model, params, cache_len=cfg.swa_window,
                           num_slots=2)
    prompt = _prompt(cfg, B=2, S=8)
    out = eng.generate(prompt, 3 * cfg.swa_window)   # decode past window
    assert out.shape == (2, 3 * cfg.swa_window)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()


# ---------------------------------------------------------------------------
# decode edge cases (satellite), for BOTH engines
# ---------------------------------------------------------------------------

def _engines(model, params, cache_len, eos_id, slots=2):
    return (StaticEngine(model, params, cache_len=cache_len, eos_id=eos_id),
            ContinuousEngine(model, params, cache_len=cache_len,
                             num_slots=slots, eos_id=eos_id))


def test_eos_on_first_generated_token():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=1, S=8)
    # discover the greedy first token, then declare it EOS
    free = StaticEngine(model, params, cache_len=16).generate(prompt, 4)
    eos = int(free[0, 0])
    for eng in _engines(model, params, 16, eos_id=eos, slots=1):
        out = eng.generate(prompt, 6)
        assert out.shape == (1, 6)
        assert (out[0] == eos).all(), out   # EOS + eos padding throughout


def test_eos_minus_one_never_done():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    for eng in _engines(model, params, 32, eos_id=-1):
        out = eng.generate(prompt, 16)
        assert out.shape == (2, 16)
        # every position is a sampled vocab token; nothing eos-masked
        assert (out >= 0).all() and (out < cfg.vocab_size).all()


def test_all_done_early_exit_and_per_row_masking():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    free = StaticEngine(model, params, cache_len=40).generate(prompt, 24)
    # choose an EOS that row 0 emits early but is NOT row 1's first token
    candidates = [t for t in free[0].tolist() if t != free[1][0]]
    assert candidates, "degenerate smoke model output"
    eos = int(candidates[0])
    t0 = free[0].tolist().index(eos)
    s_out, c_out = (e.generate(prompt, 24)
                    for e in _engines(model, params, 40, eos_id=eos))
    assert np.array_equal(s_out, c_out)
    # row 0: finished at its first EOS, padded with EOS after
    assert (s_out[0, t0:] == eos).all()
    assert np.array_equal(s_out[0, :t0], free[0][:t0])
    # row 1 keeps decoding past row 0's EOS (until its own EOS, if any)
    row1 = free[1].tolist()
    stop1 = row1.index(eos) if eos in row1 else 24
    assert np.array_equal(s_out[1, :stop1], free[1][:stop1])
    # all-done early exit: a batch whose rows ALL hit EOS ends with every
    # remaining column already eos-padded
    if stop1 < 24:
        assert (s_out[:, max(t0, stop1):] == eos).all()


def test_temperature_sampling_deterministic_fixed_seed():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    for mk in (lambda: StaticEngine(model, params, cache_len=24),
               lambda: ContinuousEngine(model, params, cache_len=24,
                                        num_slots=2)):
        a = mk().generate(prompt, 8, temperature=0.7, seed=11)
        b = mk().generate(prompt, 8, temperature=0.7, seed=11)
        assert np.array_equal(a, b)
        c = mk().generate(prompt, 8, temperature=0.7, seed=12)
        assert a.shape == c.shape == (2, 8)


# ---------------------------------------------------------------------------
# traffic loop: staggered arrivals drain through the micro-step API
# ---------------------------------------------------------------------------

def test_micro_step_loop_drains_mixed_trace():
    cfg, model, params = _bundle()
    trace = make_trace(6, prompt_len=8, max_new=(2, 5), arrival="all",
                       seed=1)
    eng = ContinuousEngine(model, params, cache_len=16, num_slots=2,
                           scheduler=CellQueueScheduler(num_cells=8))
    reqs = []
    for rid, e in enumerate(trace):
        batch = make_synthetic_batch(cfg, 1, e.prompt_len, seed=rid,
                                     compute_dtype="float32")
        req = ServeRequest(rid=rid, batch={"tokens": batch["tokens"]},
                           max_new_tokens=e.max_new)
        reqs.append(req)
        eng.submit(req, now=float(rid))
    steps = 0
    while not eng.idle:
        eng.step(now=10.0 + steps)
        steps += 1
        assert steps < 200
    for r in reqs:
        assert r.output is not None and r.generated == r.max_new_tokens
        assert r.finish_time is not None and r.admit_time is not None
    stats = eng.scheduler.latency_stats()
    assert stats["n"] == 6.0
    assert stats["tokens"] == float(sum(e.max_new for e in trace))


# ---------------------------------------------------------------------------
# CommStream binding: prefill/decode on distinct streams, same tokens
# ---------------------------------------------------------------------------

def test_engine_streams_bound_to_comm():
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    plain = ContinuousEngine(model, params, cache_len=24,
                             num_slots=2).generate(prompt, 8)
    mesh = make_mesh((1,), ("ranks",))
    root = threadcomm_init(mesh, process_axes=(), thread_axes=("ranks",))
    root.start()
    try:
        eng = ContinuousEngine(model, params, cache_len=24, num_slots=2,
                               comm=root)
        ordered = eng.generate(prompt, 8)
        # distinct streams, both threaded through the run
        assert eng._prefill_stream.name == "prefill"
        assert eng._decode_stream.name == "decode"
        assert eng._prefill_stream._token is not None
        assert eng._decode_stream._token is not None
    finally:
        root.finish()
        root.free()
    assert np.array_equal(plain, ordered)   # ordering never changes tokens
