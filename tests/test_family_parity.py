"""Universal family serving (DESIGN.md §13): every registry family runs
the continuous + paged path token-identically to its static monolithic
baseline, and the state-threaded chunk contract resumes recurrent scans
bit-exactly at any chunk boundary.

Three layers of evidence:

* chunked deposit vs monolithic prefill produce the identical
  carried-state pytree and the identical first token for SSM and hybrid
  — a hypothesis property over random prompt lengths / chunk sizes
  (``ssm_chunk`` multiples) when hypothesis is installed, plus a
  deterministic seeded sweep that always runs;
* engine-level token identity for all four non-dense families
  (MoE, SSM, hybrid, enc-dec) through ``ContinuousEngine`` with
  ``kv_layout="paged"`` and chunked prefill;
* one carried-state family end-to-end through the replicated serving
  fabric (the router must not perturb a single sampled token).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import ContinuousEngine, ServeRequest, StaticEngine
from repro.serve.fabric.router import ServingFabric

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)

_BUNDLES = {}


def _bundle(arch):
    if arch not in _BUNDLES:
        cfg = get_smoke_config(arch)
        model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
        _BUNDLES[arch] = (cfg, model, model.init(jax.random.PRNGKey(0)))
    return _BUNDLES[arch]


def _prompt(cfg, B, S, seed=0):
    batch = make_synthetic_batch(cfg, B, S, seed=seed,
                                 compute_dtype="float32")
    return {k: np.asarray(v) for k, v in batch.items() if k != "labels"}


# ---------------------------------------------------------------------------
# chunked == monolithic: carried state + first token (SSM / hybrid)
# ---------------------------------------------------------------------------

def _chunked_deposit(model, params, tokens, chunk, cache_len):
    """Drive the slot chunk step over a whole prompt by hand (what the
    engine's prefill ladder does) and return (first token, cache)."""
    cache = model.init_cache(1, cache_len)
    S = tokens.shape[1]
    logits = None
    for pos0 in range(0, S, chunk):
        n_valid = min(chunk, S - pos0)
        tok = np.zeros(chunk, np.int32)
        tok[:n_valid] = tokens[0, pos0:pos0 + n_valid]
        logits, cache = model.prefill_chunk(
            params, cache, jnp.asarray(tok),
            jnp.int32(pos0), jnp.int32(n_valid))
    return int(jnp.argmax(logits)), cache


def _assert_chunked_matches_monolithic(arch, S, chunk, seed):
    cfg, model, params = _bundle(arch)
    m = model.capabilities.chunk_multiple
    cache_len = 4 * m
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, cfg.vocab_size, (1, S)).astype(np.int32)
    leaves = model.capabilities.state_leaves

    logits_m, cache_m = model.prefill(params, {"tokens": jnp.asarray(tokens)},
                                      cache_len)
    tok_m = int(jnp.argmax(logits_m[0]))
    tok_c, cache_c = _chunked_deposit(model, params, tokens, chunk, cache_len)
    assert tok_c == tok_m, (arch, S, chunk, seed)

    # the state-threading contract: resuming the scan at a DIFFERENT
    # chunk grid deposits the identical carried state, bit for bit
    other = 2 * m if chunk == m else m
    tok_o, cache_o = _chunked_deposit(model, params, tokens, other, cache_len)
    assert tok_o == tok_c
    for leaf in leaves:
        np.testing.assert_array_equal(
            np.asarray(cache_c[leaf]), np.asarray(cache_o[leaf]),
            err_msg=f"carried-state leaf {leaf!r} depends on the chunk "
                    f"grid ({arch}, S={S}, {chunk} vs {other}, seed={seed})")

    # vs the monolithic oracle: pure SSM is bit-exact (same scan
    # implementation both paths); the hybrid's attention layers
    # accumulate in a different order in full-sequence prefill than in
    # cached-chunk deposit, so the state the downstream SSM blocks see
    # carries float32 reassociation noise — bounded, not a logic bug
    exact = cfg.block == "ssm"
    for leaf in leaves:
        a, b = np.asarray(cache_m[leaf]), np.asarray(cache_c[leaf])
        if exact:
            np.testing.assert_array_equal(
                a, b, err_msg=f"carried-state leaf {leaf!r} diverged "
                              f"({arch}, S={S}, chunk={chunk}, seed={seed})")
        else:
            np.testing.assert_allclose(
                a, b, rtol=1e-3, atol=1e-5,
                err_msg=f"carried-state leaf {leaf!r} diverged beyond "
                        f"float32 reassociation noise "
                        f"({arch}, S={S}, chunk={chunk}, seed={seed})")


@pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
def test_chunked_prefill_state_and_first_token_sweep(arch):
    """Deterministic sweep of the chunk-resume invariant: prompt lengths
    off the chunk grid, chunk sizes at 1x/2x the family multiple."""
    m = _bundle(arch)[1].capabilities.chunk_multiple
    for seed, (S, k) in enumerate([(1, 1), (m, 1), (m + 3, 1),
                                   (2 * m, 2), (3 * m - 1, 1),
                                   (2 * m + 5, 2)]):
        _assert_chunked_matches_monolithic(arch, S, k * m, seed)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                  # pragma: no cover
    pass
else:
    @pytest.mark.parametrize("arch", ["mamba2-370m", "hymba-1.5b"])
    @given(data=st.data())
    @settings(max_examples=8, deadline=None)
    def test_chunked_prefill_state_and_first_token_property(arch, data):
        cfg, model, params = _bundle(arch)
        m = model.capabilities.chunk_multiple
        S = data.draw(st.integers(1, 3 * m), label="prompt_len")
        chunk = m * data.draw(st.integers(1, 3), label="chunk_multiples")
        seed = data.draw(st.integers(0, 2 ** 16), label="seed")
        _assert_chunked_matches_monolithic(arch, S, chunk, seed)


# ---------------------------------------------------------------------------
# engine-level: four non-dense families, paged + chunked vs static
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "mamba2-370m",
                                  "hymba-1.5b", "whisper-tiny"])
def test_family_paged_chunked_token_identity(arch):
    cfg, model, params = _bundle(arch)
    prompt = _prompt(cfg, B=3, S=24)
    static = StaticEngine(model, params, cache_len=32).generate(prompt, 6)
    eng = ContinuousEngine(model, params, cache_len=32, num_slots=4,
                           prefill_chunk=16, kv_layout="paged",
                           block_size=8)
    out = eng.generate(prompt, 6)
    assert np.array_equal(np.asarray(static), np.asarray(out)), arch


# ---------------------------------------------------------------------------
# carried-state family through the replicated fabric
# ---------------------------------------------------------------------------

def test_ssm_family_through_replicated_fabric():
    cfg, model, params = _bundle("mamba2-370m")
    assert model.capabilities.carried_state

    def reqs_for():
        out = []
        for rid in range(4):
            b = _prompt(cfg, B=1, S=24, seed=1000 + rid)
            out.append(ServeRequest(rid=rid, batch=b, max_new_tokens=4,
                                    temperature=0.0, seed=0))
        return out

    def drain(target, reqs):
        for r in reqs:
            target.submit(r, 0.0)
        guard = 0
        while not target.idle:
            target.step(0.0)
            guard += 1
            assert guard < 2000, "failed to drain"
        return [r.output[:r.generated].copy() for r in reqs]

    ref = drain(ContinuousEngine(model, params, cache_len=32, num_slots=4,
                                 prefill_chunk=16, kv_layout="paged",
                                 block_size=8), reqs_for())
    fab = ServingFabric(model, params, ranks=2, placement="replicated",
                        cache_len=32, slots_per_rank=2, prefill_chunk=16,
                        block_size=8)
    try:
        out = drain(fab, reqs_for())
        assert all(np.array_equal(a, b) for a, b in zip(ref, out))
        # the router's dispatch-hop scheduler prices the carried-state
        # handoff (capability-driven, DESIGN.md §13)
        assert fab.scheduler.state_bytes > 0
    finally:
        fab.close()


def test_disagg_refuses_carried_state_family():
    """KV-block migration would strand recurrent state at the prefill
    rank: the fabric refuses up front, naming the capability."""
    cfg, model, params = _bundle("mamba2-370m")
    with pytest.raises(ValueError, match="kv_migration"):
        ServingFabric(model, params, ranks=2, placement="disagg",
                      cache_len=32, slots_per_rank=2, prefill_chunk=16,
                      block_size=8)
