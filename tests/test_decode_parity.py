"""Teacher-forcing decode parity for the non-dense families (the dense case
lives in test_models_smoke): decode_step at position i must reproduce the
full-forward logits — exercises KV caches, SSM states, conv states, and
cross-attention caches end to end."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)
B, S = 2, 24


@pytest.mark.parametrize("arch", ["hymba-1.5b", "mamba2-370m",
                                  "olmoe-1b-7b", "whisper-tiny",
                                  "gemma-2b"])
def test_decode_matches_full_forward(arch):
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.num_experts:
        # capacity-based MoE is NOT teacher-forcing consistent by design:
        # a token grouped with 45 others at prefill can be capacity-dropped,
        # while at decode it routes alone and is always kept (the classic
        # train/serve MoE gap). Parity holds in the dropless regime.
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    params = model.init(jax.random.PRNGKey(2))
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    cache_len = S + 4

    pre_batch = dict(batch, tokens=batch["tokens"][:, :S - 1])
    _, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, pre_batch)
    logits_dec, _ = jax.jit(model.decode_step)(
        params, cache, batch["tokens"][:, S - 1:S], jnp.int32(S - 1))

    logits_full, _ = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, batch)
    np.testing.assert_allclose(
        np.asarray(logits_dec), np.asarray(logits_full),
        atol=5e-3, rtol=5e-3,
        err_msg=f"{arch}: decode diverges from teacher forcing")
