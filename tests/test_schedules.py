"""Property tests (hypothesis) for the pure-python collective schedules —
the system invariants behind every executable collective."""

import math

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import schedules as sch

sizes = st.integers(min_value=1, max_value=64)
pow2_sizes = st.sampled_from([1, 2, 4, 8, 16, 32, 64, 128, 256, 512])


@given(sizes)
@settings(max_examples=60, deadline=None)
def test_dissemination_full_knowledge(n):
    rounds = sch.dissemination_rounds(n)
    know = sch.simulate_knowledge(n, rounds)
    assert all(k == set(range(n)) for k in know), (n, know)
    # lg N rounds exactly
    assert len(rounds) == (math.ceil(math.log2(n)) if n > 1 else 0)


@given(sizes, st.integers(min_value=0, max_value=63))
@settings(max_examples=60, deadline=None)
def test_binomial_reduce_sums_to_root(n, root):
    root = root % n
    rounds = sch.binomial_reduce_rounds(n, root)
    acc = sch.simulate_reduce(n, rounds, values=[float(i + 1) for i in range(n)])
    assert acc[root] == float(n * (n + 1) / 2), (n, root, acc)
    # every non-root sends exactly once (tree property)
    senders = [s for rnd in rounds for (s, _) in rnd]
    assert sorted(senders) == sorted(set(senders))
    assert len(senders) == n - 1
    assert root not in senders


@given(sizes, st.integers(min_value=0, max_value=63))
@settings(max_examples=60, deadline=None)
def test_binomial_bcast_reaches_all(n, root):
    root = root % n
    rounds = sch.binomial_bcast_rounds(n, root)
    know = sch.simulate_knowledge(n, rounds)
    assert all(root in k for k in know), (n, root, know)
    # each rank receives at most once
    receivers = [d for rnd in rounds for (_, d) in rnd]
    assert len(receivers) == len(set(receivers)) == n - 1


@given(pow2_sizes)
@settings(max_examples=20, deadline=None)
def test_recursive_doubling_full_reduction(n):
    if n == 1:
        return
    rounds = sch.recursive_doubling_rounds(n)
    acc = sch.simulate_reduce(n, rounds, values=[1.0] * n)
    assert all(a == float(n) for a in acc), (n, acc)
    know = sch.simulate_knowledge(n, rounds)
    assert all(k == set(range(n)) for k in know)


@given(st.integers(min_value=2, max_value=1024),
       st.integers(min_value=64, max_value=1 << 24))
@settings(max_examples=60, deadline=None)
def test_ring_beats_doubling_for_large_messages(n, nbytes):
    """Bandwidth-optimality crossover: for big payloads ring's 2(n-1)/n byte
    term beats recursive doubling's lg(n) full-vector exchanges."""
    alpha, beta = 1e-6, 1e-10
    ring = sch.allreduce_cost(n, nbytes, alpha=alpha, beta=beta,
                              schedule="ring")
    rd = sch.allreduce_cost(n, nbytes, alpha=alpha, beta=beta,
                            schedule="recursive_doubling")
    if n >= 4 and nbytes >= 1 << 22:
        assert ring < rd, (n, nbytes, ring, rd)
    if n >= 4 and nbytes <= 256:
        assert rd < ring, (n, nbytes, ring, rd)


@given(st.integers(min_value=2, max_value=64),
       st.integers(min_value=2, max_value=256),
       st.integers(min_value=1 << 16, max_value=1 << 28))
@settings(max_examples=60, deadline=None)
def test_hierarchical_beats_flat_over_slow_links(n_proc, m_thread, nbytes):
    """The paper's quantitative claim, generalized: two-level allreduce that
    keeps the bulk on the fast domain beats a flat schedule that pays slow-
    link beta on every hop."""
    fast = dict(alpha_fast=1e-6, beta_fast=1.0 / 50e9)
    slow = dict(alpha_slow=5e-6, beta_slow=1.0 / 6.25e9)
    hier = sch.hierarchical_allreduce_cost(n_proc, m_thread, nbytes,
                                           **fast, **slow)
    flat = sch.flat_allreduce_cost(n_proc * m_thread, nbytes, **slow)
    assert hier < flat, (n_proc, m_thread, nbytes, hier, flat)


@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=1, max_value=64))
@settings(max_examples=40, deadline=None)
def test_two_level_plan_slow_fraction(n_proc, m_thread):
    plan = sch.two_level_allreduce_plan(n_proc, m_thread)
    assert plan["slow_domain_fraction"] == 1.0 / m_thread
    phases = [p[0] for p in plan["phases"]]
    assert phases == ["reduce_scatter", "allreduce", "allgather"]


@given(st.integers(min_value=1, max_value=1 << 20))
@settings(max_examples=80, deadline=None)
def test_protocol_selection_monotone(nbytes):
    """Protocol boundaries are monotone in message size and match the
    paper's thresholds (4096 interthread, 16384 interprocess)."""
    from repro.core import protocol as pr
    p = pr.select_protocol(nbytes, interthread=True)
    if nbytes <= 4096:
        assert p in ("eager_fast", "eager")
    else:
        assert p == "one_copy"
    q = pr.select_protocol(nbytes, interthread=False)
    assert q == ("eager" if nbytes <= 16384 else "rndv")
    # latency model is monotone nondecreasing in size within a protocol
    lat1 = pr.interthread_latency(nbytes)
    lat2 = pr.interthread_latency(nbytes + 1024)
    if pr.select_protocol(nbytes) == pr.select_protocol(nbytes + 1024):
        assert lat2 >= lat1
