"""Radix-tree prefix cache (DESIGN.md §12): trie lookup/insert, partial
(CoW) hits, the park/lease lifecycle, LRU eviction with live-descendant
pinning — plus engine-level evidence that a warm cache changes *work*,
never *tokens* (cached-vs-cold output identity).

Property tests ride hypothesis when available (same split as
``tests/test_block_pool.py``); the deterministic tests always run.
"""

import numpy as np
import pytest

from repro.serve.block_pool import BlockPool
from repro.serve.kv_cache import SlotError
from repro.serve.prefix_cache import PrefixCache

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # property subset needs pip install repro[test]
    given = None

BS = 4


def _cache(num_blocks=16):
    pool = BlockPool(num_blocks=num_blocks, block_size=BS)
    return pool, PrefixCache(pool)


def _park_chain(pool, cache, toks, owner="req-0"):
    """Alloc + insert + free: the canonical finished-request path. The
    chain's blocks end up parked (sole ref = the cache's)."""
    blocks = pool.alloc(len(toks) // BS, owner)
    cache.insert(toks, blocks)
    pool.free(blocks)
    return blocks


# ---------------------------------------------------------------------------
# trie lookup / insert
# ---------------------------------------------------------------------------

def test_empty_cache_misses():
    _, cache = _cache()
    hit = cache.lookup(list(range(12)))
    assert hit.blocks == [] and hit.tokens == 0
    assert hit.cow_src is None and hit.cow_tokens == 0


def test_insert_lookup_roundtrip_full_blocks():
    pool, cache = _cache()
    toks = list(range(12))
    blocks = _park_chain(pool, cache, toks)
    hit = cache.lookup(toks)
    assert hit.blocks == blocks and hit.tokens == 12
    assert hit.cow_src is None and hit.n_parked == 3
    assert cache.num_cached == 3 and cache.num_parked == 3


def test_partial_hit_names_cow_source():
    """A prompt diverging mid-block hits the full-block prefix and names
    the divergent cached block as the CoW source."""
    pool, cache = _cache()
    toks = list(range(12))
    blocks = _park_chain(pool, cache, toks)
    fork = toks[:9] + [91, 92, 93]            # diverges 1 token into block 3
    hit = cache.lookup(fork)
    assert hit.blocks == blocks[:2] and hit.tokens == 8
    assert hit.cow_src == blocks[2] and hit.cow_tokens == 1
    assert hit.total_tokens == 9


def test_limit_clamps_to_partial():
    """The engine clamps limit one token short of the prompt so the last
    chunk re-prefills; the trie answers with a partial hit there."""
    pool, cache = _cache()
    toks = list(range(12))
    blocks = _park_chain(pool, cache, toks)
    hit = cache.lookup(toks, limit=11)
    assert hit.blocks == blocks[:2] and hit.tokens == 8
    assert hit.cow_src == blocks[2] and hit.cow_tokens == 3


def test_duplicate_insert_keeps_first_copy():
    pool, cache = _cache()
    toks = list(range(8))
    first = pool.alloc(2, "a")
    assert cache.insert(toks, first) == 2
    second = pool.alloc(2, "b")
    assert cache.insert(toks, second) == 0     # loser stays unindexed
    pool.free(first)
    pool.free(second)
    assert cache.num_parked == 2               # only the first copy parked
    hit = cache.lookup(toks)
    assert hit.blocks == [int(b) for b in first]
    assert pool.num_live == 2                  # loser's blocks fully freed


# ---------------------------------------------------------------------------
# park / lease lifecycle
# ---------------------------------------------------------------------------

def test_lease_unparks_and_refs():
    pool, cache = _cache()
    toks = list(range(12))
    _park_chain(pool, cache, toks)
    hit = cache.lookup(toks)
    cache.lease(hit, "req-9")
    assert cache.num_parked == 0
    assert all(pool.refcount(b) == 2 for b in hit.blocks)
    pool.free(hit.blocks)                      # request done -> re-parked
    assert cache.num_parked == 3
    assert all(pool.refcount(b) == 1 for b in hit.blocks)
    cache.check()


def test_cow_lease_release_roundtrip():
    """The CoW source gets a temporary reference for the clone window;
    releasing it re-parks the block without ever freeing it."""
    pool, cache = _cache()
    toks = list(range(8))
    _park_chain(pool, cache, toks)
    fork = toks[:6] + [91, 92]
    hit = cache.lookup(fork)
    assert hit.cow_tokens == 2 and hit.cow_src is not None
    cache.lease(hit, "req-c")
    assert pool.refcount(hit.cow_src) == 2
    cache.release_cow(hit.cow_src)
    assert pool.refcount(hit.cow_src) == 1     # cache ref survives
    assert cache.num_cached == 2               # still indexed
    pool.free(hit.blocks)
    cache.check()


def test_pool_counts_parked_as_free():
    """Admission math: parked blocks are reclaimable, so the pool counts
    them free until a lease pins them."""
    pool, cache = _cache()
    _park_chain(pool, cache, list(range(12)))
    assert pool.num_free == 16                 # 13 on free list + 3 parked
    hit = cache.lookup(list(range(12)))
    cache.lease(hit, "pin")
    assert pool.num_free == 13                 # leased blocks stop counting
    pool.free(hit.blocks)
    assert pool.num_free == 16


# ---------------------------------------------------------------------------
# LRU eviction under pressure
# ---------------------------------------------------------------------------

def test_reclaim_evicts_lru_oldest_first():
    pool, cache = _cache(num_blocks=4)
    _park_chain(pool, cache, [0, 1, 2, 3], "old")      # parks first (LRU old)
    _park_chain(pool, cache, [7, 6, 5, 4], "new")      # parks second
    blocks = pool.alloc(3, "pressure")                 # 2 free + 1 reclaimed
    assert len(blocks) == 3
    assert cache.lookup([0, 1, 2, 3]).tokens == 0      # oldest evicted
    assert cache.lookup([7, 6, 5, 4]).tokens == 4      # newest survived
    assert cache.n_evictions == 1
    pool.free(blocks)


def test_live_descendant_pins_parked_parent():
    """A parked node above a live path is not evictable — dropping it
    would orphan the descendant's prefix."""
    pool, cache = _cache(num_blocks=4)
    toks = list(range(8))
    _park_chain(pool, cache, toks)                     # chain of 2, parked
    hit = cache.lookup(toks)
    cache.lease(hit, "r2")                             # both live again
    pool.free([hit.blocks[0]])                         # parent parks, child live
    assert cache.evictable() == 0
    with pytest.raises(SlotError, match="exhausted"):
        pool.alloc(3, "starved")                       # 2 free, nothing evictable
    pool.free([hit.blocks[1]])                         # child parks too
    blocks = pool.alloc(3, "fits-now")                 # subtree evicted whole
    assert len(blocks) == 3 and cache.num_cached == 0
    pool.free(blocks)
    assert pool.num_live == 0


def test_eviction_frees_whole_parked_subtree():
    pool, cache = _cache(num_blocks=8)
    _park_chain(pool, cache, list(range(12)))          # chain of 3
    assert cache.reclaim(1) == 3                       # subtree goes together
    assert cache.num_cached == 0 and pool.num_free == 8
    assert cache.n_evictions == 3


# ---------------------------------------------------------------------------
# hypothesis properties (skipped without hypothesis, like test_block_pool)
# ---------------------------------------------------------------------------

if given is not None:

    @given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 7)),
                    max_size=40))
    @settings(max_examples=50, deadline=None)
    def test_conservation_under_random_traffic(ops):
        """Arbitrary insert/finish/reclaim interleavings: every block is
        on the free list xor leased (parked counts as leased-by-cache),
        and the trie invariants hold after every op."""
        pool, cache = _cache(num_blocks=8)
        rng = np.random.default_rng(7)
        live = []
        for kind, x in ops:
            if kind == 0 and pool.num_free >= 2:
                toks = [int(t) for t in rng.integers(0, 3, size=8)]
                try:
                    blocks = pool.alloc(2, f"req{x}")
                except SlotError:      # evictable subset pinned mid-walk
                    continue
                cache.insert(toks, blocks)
                live.append(blocks)
            elif kind == 1 and live:
                pool.free(live.pop(x % len(live)))
            elif kind == 2:
                cache.reclaim(x)
            cache.check()
            assert (pool.num_free - cache.evictable()
                    + pool.num_live == 8)
        for blocks in live:
            pool.free(blocks)
        cache.clear()
        assert pool.num_free == 8 and pool.num_live == 0

    @given(st.integers(1, 5))
    @settings(max_examples=25, deadline=None)
    def test_nway_lease_refcount_roundtrip(n):
        """N concurrent warm requests over one cached chain: refcount is
        exactly N+1 while leased and 1 (parked) after all finish."""
        pool, cache = _cache()
        toks = list(range(8))
        _park_chain(pool, cache, toks)
        hits = [cache.lookup(toks) for _ in range(n)]
        for i, h in enumerate(hits):
            cache.lease(h, f"req{i}")
        assert all(pool.refcount(b) == n + 1 for b in hits[0].blocks)
        for h in hits:
            pool.free(h.blocks)
        assert cache.num_parked == 2
        assert all(pool.refcount(b) == 1 for b in hits[0].blocks)
        cache.check()

    @given(st.integers(1, 3))
    @settings(max_examples=20, deadline=None)
    def test_cow_source_survives_any_divergence_point(k):
        """Wherever the fork lands inside a block, the CoW source is
        leased, released, and left cached — never freed or mutated in
        the index."""
        pool, cache = _cache()
        toks = list(range(8))
        _park_chain(pool, cache, toks)
        fork = toks[:4 + k] + [91] * (4 - k)
        hit = cache.lookup(fork, limit=8)
        assert hit.cow_tokens == k and hit.cow_src is not None
        cache.lease(hit, "req-c")
        assert pool.refcount(hit.cow_src) == 2
        cache.release_cow(hit.cow_src)
        assert pool.refcount(hit.cow_src) == 1
        assert cache.lookup(toks).tokens == 8      # index intact
        pool.free(hit.blocks)
        cache.check()


# ---------------------------------------------------------------------------
# engine level: warm cache changes work, never tokens
# ---------------------------------------------------------------------------

def test_engine_warm_cache_token_identical_and_saves_prefill():
    """Cold (cache off), cold (cache on, empty trie), and warm (trie
    preserved across reset) runs emit bitwise-identical tokens; the warm
    run documents the saved work: >0.5 token hit rate, skipped prefill
    dispatches, and CoW clones for the partial last block."""
    import jax

    from repro.config import ServeConfig, TrainConfig
    from repro.configs import get_smoke_config
    from repro.models.registry import build_model, make_synthetic_batch
    from repro.serve import ContinuousEngine, StaticEngine

    cfg = get_smoke_config("gemma-2b")
    train = TrainConfig(param_dtype="float32", compute_dtype="float32",
                        loss_chunk=16, attn_chunk_threshold=64,
                        attn_chunk=16, remat=False)
    model = build_model(cfg, train, ServeConfig(), tp=1)
    if model.decode_step_paged is None or model.clone_paged_block is None:
        pytest.skip("paged decode/clone unavailable for this arch")
    params = model.init(jax.random.PRNGKey(0))

    B, S, SPL = 4, 16, 12                       # 12-token shared prefix
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    toks = np.array(batch["tokens"])
    toks[:, :SPL] = toks[0, :SPL]
    prompt = {"tokens": toks}

    ref = StaticEngine(model, params, cache_len=24).generate(prompt, 6)
    eng = ContinuousEngine(model, params, cache_len=24, num_slots=4,
                           prefill_chunk=4, kv_layout="paged",
                           block_size=4, num_blocks=40, prefix_cache=True)
    cold = eng.generate(prompt, 6)
    eng.reset(preserve_prefix=True)             # keep the trie, free rows
    warm = eng.generate(prompt, 6)

    assert np.array_equal(ref, cold)
    assert np.array_equal(cold, warm)

    stats = eng.prefix_stats()
    assert stats["prefix_hit_rate"] > 0.5       # 15/16 tokens resident
    assert stats["prefill_tokens_saved"] > 0
    assert stats["prefill_dispatches_saved"] > 0
    assert stats["prefix_cow_clones"] >= 1      # partial last block clones
    assert stats["prefix_modeled_hit_cost_us"] > 0

    eng.reset()                                 # cold reset drops the trie
    assert eng.prefix_cache.num_cached == 0
    assert eng.kv.pool.num_free == 40 and eng.kv.pool.num_live == 0
