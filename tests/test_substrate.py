"""Substrate tests: optimizer, data pipeline, checkpoint, serving engine,
single-device trainer; multi-device grad-sync parity runs via mp_cases."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import TrainConfig, ServeConfig, MeshConfig
from repro.configs import get_smoke_config
from repro.data import SyntheticPipeline
from repro.models.registry import build_model, make_synthetic_batch
from repro.optim import adamw_init, adamw_update, cosine_schedule
from repro.train import checkpoint as ckpt
from repro.train.trainer import TrainState, init_train_state, make_train_step
from repro.serve import Engine
from tests.helpers import run_case

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False, learning_rate=1e-2, warmup_steps=2,
                    total_steps=50)
MESH1 = MeshConfig(shape=(1,), axis_names=("data",))


def _model(arch="yi-9b"):
    cfg = get_smoke_config(arch)
    return cfg, build_model(cfg, TRAIN, ServeConfig(), tp=1)


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([3.0, -2.0, 1.5])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(jnp.square(p["w"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, m = adamw_update(g, state, params, lr=0.05,
                                        weight_decay=0.0)
    assert loss(params) < 1e-3
    assert int(state.step) == 200
    assert jnp.isfinite(m["grad_norm"])


def test_adamw_grad_clip_and_master():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = adamw_init(params)
    assert state.master is not None            # bf16 params need fp32 master
    g = {"w": jnp.full((4,), 1e6, jnp.bfloat16)}
    new_p, new_s, m = adamw_update(g, state, params, lr=0.1, grad_clip=1.0)
    assert float(m["grad_norm"]) > 1e5
    # clipped step is bounded: |dw| <= lr * (1 + wd) approx
    dw = np.abs(np.asarray(new_s.master["w"]) - 1.0)
    assert np.all(dw < 0.3)


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, 10, 100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) == pytest.approx(0.1, rel=1e-3)
    assert float(lr(55)) > float(lr(90))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_pipeline_deterministic_and_shardable():
    cfg = get_smoke_config("yi-9b")
    pipe = SyntheticPipeline(cfg, batch=8, seq_len=16, seed=7)
    b1, b2 = pipe.get_batch(3), pipe.get_batch(3)
    assert np.array_equal(b1["tokens"], b2["tokens"])       # deterministic
    b3 = pipe.get_batch(4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])   # varies by step
    # shard slices tile the global batch exactly
    parts = [pipe.shard_slice(3, s, 4)["tokens"] for s in range(4)]
    assert np.array_equal(np.concatenate(parts), b1["tokens"])
    # labels are next-token shifted
    full = pipe._tokens(pipe._rng(3), 8, 17)
    assert np.array_equal(b1["labels"], full[:, 1:])
    # resumable state round-trip
    st = pipe.state_dict(3)
    pipe2 = SyntheticPipeline.from_state(cfg, 8, 16, st)
    assert np.array_equal(pipe2.get_batch(3)["tokens"], b1["tokens"])


def test_pipeline_tokens_in_vocab():
    for arch in ("whisper-tiny", "internvl2-76b", "mamba2-370m"):
        cfg = get_smoke_config(arch)
        pipe = SyntheticPipeline(cfg, batch=2, seq_len=16)
        b = pipe.get_batch(0)
        assert b["tokens"].min() >= 0
        assert b["tokens"].max() < cfg.vocab_size
        for k in ("frames", "patch_embeds"):
            if k in b:
                assert np.isfinite(b[k]).all()


# ---------------------------------------------------------------------------
# trainer (single device)
# ---------------------------------------------------------------------------

def test_train_loss_decreases():
    cfg, model = _model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, MESH1, TRAIN))
    pipe = SyntheticPipeline(cfg, batch=4, seq_len=32, seed=0)
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in pipe.get_batch(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[:3] + losses[-3:]
    assert np.isfinite(losses).all()


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_keep_k(tmp_path):
    cfg, model = _model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    for s in (1, 2, 3, 4):
        ckpt.save(d, s, state, extra={"data_step": s * 10}, keep=2)
    assert ckpt.latest_step(d) == 4
    steps = sorted(os.listdir(d))
    assert steps == ["step_00000003", "step_00000004"]      # keep-k pruning
    restored, step, extra = ckpt.restore(d, state)
    assert step == 4 and extra == {"data_step": 40}
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        state, restored)


def test_checkpoint_async_and_atomic(tmp_path):
    cfg, model = _model()
    state = init_train_state(model, jax.random.PRNGKey(0))
    d = str(tmp_path / "ckpt")
    t = ckpt.save(d, 7, state, async_save=True)
    t.join()
    assert ckpt.latest_step(d) == 7
    assert not any(x.endswith(".tmp") for x in os.listdir(d))


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    ckpt.save(d, 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        ckpt.restore(d, {"w": jnp.zeros((5,))})


# ---------------------------------------------------------------------------
# engine
# ---------------------------------------------------------------------------

def test_engine_generates():
    cfg, model = _model("gemma-2b")
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, cache_len=48)
    batch = make_synthetic_batch(cfg, 2, 8, compute_dtype="float32")
    out = eng.generate({"tokens": batch["tokens"]}, max_new_tokens=6)
    assert out.shape == (2, 6)
    assert (out >= 0).all() and (out < cfg.vocab_size).all()
    # greedy decode is deterministic
    out2 = eng.generate({"tokens": batch["tokens"]}, max_new_tokens=6)
    assert np.array_equal(out, out2)


def test_engine_temperature_sampling():
    cfg, model = _model("mamba2-370m")
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, cache_len=32)
    batch = make_synthetic_batch(cfg, 2, 8, compute_dtype="float32")
    out = eng.generate({"tokens": batch["tokens"]}, max_new_tokens=5,
                       temperature=1.0, seed=3)
    assert out.shape == (2, 5)


# ---------------------------------------------------------------------------
# multi-device (subprocess)
# ---------------------------------------------------------------------------

def test_grad_sync_modes_agree():
    run_case("grad_sync_parity", ndev=8, timeout=600)


def test_elastic_checkpoint_remesh():
    run_case("elastic_remesh", ndev=8, timeout=600)
