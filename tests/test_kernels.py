"""Pallas kernel validation: interpret=True execution vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.msgq.ops import copy_accounting, msgq_copy
from repro.kernels.msgq.ref import msgq_copy_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# msgq
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nelems", [17, 256, 1024, 5000, 1 << 15])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_msgq_copy_matches_ref(nelems, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        msg = jnp.arange(nelems, dtype=dtype)
    else:
        msg = jax.random.normal(jax.random.PRNGKey(0), (nelems,)).astype(dtype)
    out, proto = msgq_copy(msg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msgq_copy_ref(msg)))
    nbytes = nelems * msg.dtype.itemsize
    assert proto == ("one_copy" if nbytes > 4096 else "eager_fast")


@pytest.mark.parametrize("force", ["eager", "one_copy"])
def test_msgq_forced_protocols(force):
    msg = jax.random.normal(jax.random.PRNGKey(1), (3000,))
    out, proto = msgq_copy(msg, force_protocol=force)
    assert proto == force
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_msgq_accounting():
    # eager moves 2x the bytes; 1-copy moves 1x (the Fig.3 bandwidth story)
    e = copy_accounting(1 << 20, "eager")
    o = copy_accounting(1 << 20, "one_copy")
    assert e["bytes_moved"] == 2 * o["bytes_moved"]
    assert e["dma_issues"] == 2 * o["dma_issues"]


def test_msgq_multidim_roundtrip():
    msg = jax.random.normal(jax.random.PRNGKey(2), (7, 33, 5))
    out, _ = msgq_copy(msg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,hd,bq,bk", [
    (1, 2, 2, 64, 64, 16, 16, 16),
    (2, 4, 2, 128, 128, 32, 64, 32),     # GQA
    (1, 8, 1, 64, 64, 64, 32, 32),       # MQA
    (2, 2, 2, 96, 96, 16, 32, 32),       # non-power-of-two seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_causal(B, H, Hkv, Sq, Sk, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True
                              ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_q_offset_decode_continuation():
    """q_offset places queries mid-sequence (prefill continuation)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    out = flash_attention(q, k, v, causal=True, q_offset=96,
                          block_q=16, block_k=32)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              q_offset=96).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Cross-validate the kernel against the model's lax.scan chunked path
    (the two production implementations must agree)."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 4, 32))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))
    pos = jnp.arange(128)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                          chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,p,n,chunk", [
    (1, 2, 64, 16, 8, 16),
    (2, 4, 128, 32, 16, 32),
    (1, 1, 96, 8, 4, 8),
    (2, 2, 64, 16, 8, 64),    # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_sequential_ref(B, H, S, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, H, S, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, S, n)) * 0.5
    out = ssd_scan(x, dt.astype(jnp.float32), A, Bm, Cm, chunk=chunk)
    ref = ssd_scan_ref(x, dt.astype(jnp.float32), A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_scan_matches_model_chunked():
    """Kernel vs the model's jnp chunked SSD (both against the same math)."""
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, H, S, p, n = 2, 3, 64, 16, 8
    x = jax.random.normal(ks[0], (B, H, S, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, n)) * 0.5
    kern = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    # model layout: x (b,s,h,p), dt (b,s,h)
    y_model, _ = ssd_chunked(x.transpose(0, 2, 1, 3),
                             dt.transpose(0, 2, 1), A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(kern),
                               np.asarray(y_model.transpose(0, 2, 1, 3)),
                               atol=1e-4, rtol=1e-4)
