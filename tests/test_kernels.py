"""Pallas kernel validation: interpret=True execution vs pure-jnp oracles,
swept over shapes and dtypes (per-kernel allclose requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.msgq.ops import copy_accounting, msgq_copy
from repro.kernels.msgq.ref import msgq_copy_ref
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref


# ---------------------------------------------------------------------------
# msgq
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("nelems", [17, 256, 1024, 5000, 1 << 15])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int32])
def test_msgq_copy_matches_ref(nelems, dtype):
    if jnp.issubdtype(dtype, jnp.integer):
        msg = jnp.arange(nelems, dtype=dtype)
    else:
        msg = jax.random.normal(jax.random.PRNGKey(0), (nelems,)).astype(dtype)
    out, proto = msgq_copy(msg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msgq_copy_ref(msg)))
    nbytes = nelems * msg.dtype.itemsize
    assert proto == ("one_copy" if nbytes > 4096 else "eager_fast")


@pytest.mark.parametrize("force", ["eager", "one_copy"])
def test_msgq_forced_protocols(force):
    msg = jax.random.normal(jax.random.PRNGKey(1), (3000,))
    out, proto = msgq_copy(msg, force_protocol=force)
    assert proto == force
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


def test_msgq_accounting():
    # eager moves 2x the bytes; 1-copy moves 1x (the Fig.3 bandwidth story)
    e = copy_accounting(1 << 20, "eager")
    o = copy_accounting(1 << 20, "one_copy")
    assert e["bytes_moved"] == 2 * o["bytes_moved"]
    assert e["dma_issues"] == 2 * o["dma_issues"]


def test_msgq_multidim_roundtrip():
    msg = jax.random.normal(jax.random.PRNGKey(2), (7, 33, 5))
    out, _ = msgq_copy(msg)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(msg))


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,Hkv,Sq,Sk,hd,bq,bk", [
    (1, 2, 2, 64, 64, 16, 16, 16),
    (2, 4, 2, 128, 128, 32, 64, 32),     # GQA
    (1, 8, 1, 64, 64, 64, 32, 32),       # MQA
    (2, 2, 2, 96, 96, 16, 32, 32),       # non-power-of-two seq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_matches_ref_causal(B, H, Hkv, Sq, Sk, hd, bq, bk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, H, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, hd)).astype(dtype)
    out = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True
                              ).transpose(0, 2, 1, 3)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [8, 32])
def test_flash_sliding_window(window):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 16))
    k = jax.random.normal(ks[1], (1, 64, 2, 16))
    v = jax.random.normal(ks[2], (1, 64, 2, 16))
    out = flash_attention(q, k, v, causal=True, window=window,
                          block_q=16, block_k=16)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_q_offset_decode_continuation():
    """q_offset places queries mid-sequence (prefill continuation)."""
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    k = jax.random.normal(ks[1], (1, 128, 2, 16))
    v = jax.random.normal(ks[2], (1, 128, 2, 16))
    q = jax.random.normal(ks[0], (1, 32, 2, 16))
    out = flash_attention(q, k, v, causal=True, q_offset=96,
                          block_q=16, block_k=32)
    ref = flash_attention_ref(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3), causal=True,
                              q_offset=96).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_model_chunked_attention():
    """Cross-validate the kernel against the model's lax.scan chunked path
    (the two production implementations must agree)."""
    from repro.models.layers import chunked_attention
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 4, 32))
    v = jax.random.normal(ks[2], (2, 128, 4, 32))
    pos = jnp.arange(128)
    a = flash_attention(q, k, v, causal=True, block_q=32, block_k=32)
    b = chunked_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                          chunk_q=32, chunk_k=32)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# paged attention
# ---------------------------------------------------------------------------

def _paged_inputs(B, H, Hkv, hd, P, bs, NB, seed=0, dtype=jnp.float32):
    """Random pool + per-request tables of distinct blocks + lengths that
    land strictly inside each table's capacity."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd)).astype(dtype)
    kp = jax.random.normal(ks[1], (P, bs, Hkv, hd)).astype(dtype)
    vp = jax.random.normal(ks[2], (P, bs, Hkv, hd)).astype(dtype)
    rng = np.random.default_rng(seed)
    perm = rng.permutation(P)
    bt = np.full((B, NB), -1, np.int32)
    ln = np.zeros((B,), np.int32)
    used = 0
    for b in range(B):
        nb = int(rng.integers(1, NB + 1))
        bt[b, :nb] = perm[used:used + nb]
        used += nb
        ln[b] = int(rng.integers((nb - 1) * bs + 1, nb * bs + 1))
    return q, kp, vp, jnp.asarray(bt), jnp.asarray(ln)


@pytest.mark.parametrize("B,H,Hkv,hd,P,bs,NB", [
    (1, 2, 2, 16, 6, 8, 3),
    (3, 4, 2, 32, 16, 16, 4),            # GQA
    (2, 8, 1, 64, 12, 8, 4),             # MQA
    (4, 4, 4, 16, 24, 4, 6),             # many small blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(B, H, Hkv, hd, P, bs, NB, dtype):
    q, kp, vp, bt, ln = _paged_inputs(B, H, Hkv, hd, P, bs, NB, dtype=dtype)
    out = paged_attention(q, kp, vp, bt, ln)
    ref = paged_attention_ref(q, kp, vp, bt, ln)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [4, 16])
def test_paged_attention_sliding_window(window):
    q, kp, vp, bt, ln = _paged_inputs(2, 4, 2, 16, 10, 8, 4, seed=1)
    out = paged_attention(q, kp, vp, bt, ln, window=window)
    ref = paged_attention_ref(q, kp, vp, bt, ln, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_softcap():
    q, kp, vp, bt, ln = _paged_inputs(2, 4, 2, 16, 10, 8, 4, seed=2)
    out = paged_attention(q, kp, vp, bt, ln, softcap=20.0)
    ref = paged_attention_ref(q, kp, vp, bt, ln, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_identity_table_matches_dense():
    """With an identity block table (block i at pool slot i) the paged
    kernel is plain causal decode attention — cross-validate against the
    flash attention oracle at the last position."""
    B, H, Hkv, hd, bs, NB = 2, 4, 2, 32, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    Sk = NB * bs
    ln = jnp.array([Sk, Sk - 5], jnp.int32)
    kp = jax.random.normal(ks[1], (NB * B, bs, Hkv, hd))
    vp = jax.random.normal(ks[2], (NB * B, bs, Hkv, hd))
    q = jax.random.normal(ks[0], (B, H, hd))
    bt = jnp.arange(B * NB, dtype=jnp.int32).reshape(B, NB)
    out = paged_attention(q, kp, vp, bt, ln)
    # dense view: request b's tokens are pool blocks [b*NB, (b+1)*NB)
    kd = kp.reshape(B, Sk, Hkv, hd).transpose(0, 2, 1, 3)
    vd = vp.reshape(B, Sk, Hkv, hd).transpose(0, 2, 1, 3)
    for b in range(B):
        L_b = int(ln[b])
        ref = flash_attention_ref(
            q[b:b + 1, :, None, :], kd[b:b + 1, :, :L_b], vd[b:b + 1, :, :L_b],
            causal=True, q_offset=L_b - 1)[:, :, 0]
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_paged_attention_block_scatter_invariance():
    """The output depends only on the table's *order*, not on where the
    blocks physically live in the pool: permuting pool rows (and the
    table with them) leaves the result unchanged."""
    q, kp, vp, bt, ln = _paged_inputs(2, 4, 2, 16, 10, 8, 4, seed=4)
    out = paged_attention(q, kp, vp, bt, ln)
    perm = np.random.default_rng(0).permutation(kp.shape[0])
    inv = np.argsort(perm)
    kp2 = jnp.asarray(np.asarray(kp)[perm])
    vp2 = jnp.asarray(np.asarray(vp)[perm])
    bt2 = jnp.where(bt >= 0, jnp.asarray(inv)[jnp.maximum(bt, 0)], -1)
    out2 = paged_attention(q, kp2, vp2, bt2, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# paged attention, multi-query (speculative verify q-block)
# ---------------------------------------------------------------------------

def _paged_mq_inputs(B, H, Hkv, hd, P, bs, NB, K, seed=0, dtype=jnp.float32):
    """Pool/table fixtures plus a (B, K, H, hd) q-block; lengths clamped
    so every query position ``lengths[b] - K + j`` is a real token."""
    _, kp, vp, bt, ln = _paged_inputs(B, H, Hkv, hd, P, bs, NB, seed=seed,
                                      dtype=dtype)
    q = jax.random.normal(jax.random.PRNGKey(seed + 100),
                          (B, K, H, hd)).astype(dtype)
    return q, kp, vp, bt, jnp.maximum(ln, K)


@pytest.mark.parametrize("K", [1, 2, 3, 4])
@pytest.mark.parametrize("B,H,Hkv,hd,P,bs,NB", [
    (2, 4, 4, 16, 10, 8, 4),
    (3, 4, 2, 32, 16, 16, 4),            # GQA
    (2, 8, 1, 64, 12, 8, 4),             # MQA
])
def test_paged_attention_mq_matches_ref(B, H, Hkv, hd, P, bs, NB, K):
    q, kp, vp, bt, ln = _paged_mq_inputs(B, H, Hkv, hd, P, bs, NB, K)
    out = paged_attention(q, kp, vp, bt, ln)
    assert out.shape == (B, K, H, hd)
    ref = paged_attention_ref(q, kp, vp, bt, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               atol=2e-5, rtol=2e-5)


def test_paged_attention_mq_k1_bit_identical_to_single():
    """The q-block kernel at K=1 must reduce to the single-token kernel
    BIT-EXACTLY — same loop structure, same accumulation order — so a
    speculative engine at k=1 prices and computes like the plain one."""
    from repro.kernels.paged_attention.paged_attention import (
        paged_attention_fwd,
    )
    q, kp, vp, bt, ln = _paged_inputs(3, 4, 2, 32, 16, 16, 4, seed=5)
    single = paged_attention_fwd(q, kp, vp, bt, ln, interpret=True)
    mq = paged_attention_fwd(q[:, None], kp, vp, bt, ln, interpret=True)
    np.testing.assert_array_equal(np.asarray(mq[:, 0]), np.asarray(single))


def test_paged_attention_mq_identity_table_matches_flash():
    """Identity table + q-block == dense causal attention over the last K
    positions (flash oracle with q_offset = L - K)."""
    B, H, Hkv, hd, bs, NB, K = 2, 4, 2, 32, 8, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(6), 3)
    Sk = NB * bs
    ln = jnp.array([Sk, Sk - 5], jnp.int32)
    kp = jax.random.normal(ks[1], (NB * B, bs, Hkv, hd))
    vp = jax.random.normal(ks[2], (NB * B, bs, Hkv, hd))
    q = jax.random.normal(ks[0], (B, K, H, hd))
    bt = jnp.arange(B * NB, dtype=jnp.int32).reshape(B, NB)
    out = paged_attention(q, kp, vp, bt, ln)
    kd = kp.reshape(B, Sk, Hkv, hd).transpose(0, 2, 1, 3)
    vd = vp.reshape(B, Sk, Hkv, hd).transpose(0, 2, 1, 3)
    for b in range(B):
        L_b = int(ln[b])
        ref = flash_attention_ref(
            q[b:b + 1].transpose(0, 2, 1, 3), kd[b:b + 1, :, :L_b],
            vd[b:b + 1, :, :L_b], causal=True,
            q_offset=L_b - K).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out[b:b + 1]), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)


def test_paged_attention_mq_block_scatter_invariance():
    """q-block output depends only on table order, not pool placement."""
    q, kp, vp, bt, ln = _paged_mq_inputs(2, 4, 2, 16, 10, 8, 4, 3, seed=7)
    out = paged_attention(q, kp, vp, bt, ln)
    perm = np.random.default_rng(1).permutation(kp.shape[0])
    inv = np.argsort(perm)
    kp2 = jnp.asarray(np.asarray(kp)[perm])
    vp2 = jnp.asarray(np.asarray(vp)[perm])
    bt2 = jnp.where(bt >= 0, jnp.asarray(inv)[jnp.maximum(bt, 0)], -1)
    out2 = paged_attention(q, kp2, vp2, bt2, ln)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2),
                               atol=2e-6, rtol=2e-6)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,p,n,chunk", [
    (1, 2, 64, 16, 8, 16),
    (2, 4, 128, 32, 16, 32),
    (1, 1, 96, 8, 4, 8),
    (2, 2, 64, 16, 8, 64),    # single chunk
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_sequential_ref(B, H, S, p, n, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (B, H, S, p)).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(jax.random.PRNGKey(9), (B, S, n)) * 0.5
    out = ssd_scan(x, dt.astype(jnp.float32), A, Bm, Cm, chunk=chunk)
    ref = ssd_scan_ref(x, dt.astype(jnp.float32), A, Bm, Cm)
    tol = 5e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol, rtol=tol)


def test_ssd_scan_matches_model_chunked():
    """Kernel vs the model's jnp chunked SSD (both against the same math)."""
    from repro.models.mamba import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(5), 5)
    B, H, S, p, n = 2, 3, 64, 16, 8
    x = jax.random.normal(ks[0], (B, H, S, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, n)) * 0.5
    kern = ssd_scan(x, dt, A, Bm, Cm, chunk=16)
    # model layout: x (b,s,h,p), dt (b,s,h)
    y_model, _ = ssd_chunked(x.transpose(0, 2, 1, 3),
                             dt.transpose(0, 2, 1), A, Bm, Cm, chunk=16)
    np.testing.assert_allclose(np.asarray(kern),
                               np.asarray(y_model.transpose(0, 2, 1, 3)),
                               atol=1e-4, rtol=1e-4)


def test_ssd_scan_state_threading_resumes_bit_exact():
    """Splitting a sequence across two kernel calls and threading the
    final state into the second call reproduces the single-call outputs
    BIT-EXACTLY (same chunk grid on both sides — the state-threaded
    chunked-prefill contract, DESIGN.md §13)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 5)
    B, H, S, p, n, chunk = 2, 3, 64, 16, 8, 16
    half = S // 2
    x = jax.random.normal(ks[0], (B, H, S, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, n)) * 0.5

    y_full, s_full = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk,
                              return_state=True)
    y1, s1 = ssd_scan(x[:, :, :half], dt[:, :, :half], A, Bm[:, :half],
                      Cm[:, :half], chunk=chunk, return_state=True)
    y2, s2 = ssd_scan(x[:, :, half:], dt[:, :, half:], A, Bm[:, half:],
                      Cm[:, half:], s1, chunk=chunk, return_state=True)
    np.testing.assert_array_equal(np.asarray(y_full[:, :, :half]),
                                  np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y_full[:, :, half:]),
                                  np.asarray(y2))
    np.testing.assert_array_equal(np.asarray(s_full), np.asarray(s2))


def test_ssd_scan_state_threading_matches_ref():
    """Kernel carried state agrees with the sequential-recurrence oracle's
    (same initial_state/return_state contract on both)."""
    ks = jax.random.split(jax.random.PRNGKey(11), 6)
    B, H, S, p, n = 1, 2, 32, 8, 4
    x = jax.random.normal(ks[0], (B, H, S, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, S))) * 0.1
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, n)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, n)) * 0.5
    s0 = jax.random.normal(ks[5], (B, H, p, n)) * 0.2

    y_k, f_k = ssd_scan(x, dt, A, Bm, Cm, s0, chunk=8, return_state=True)
    y_r, f_r = ssd_scan_ref(x, dt, A, Bm, Cm, s0, return_state=True)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f_k), np.asarray(f_r),
                               atol=1e-4, rtol=1e-4)
