"""Block-pool allocator properties (hypothesis-driven): alloc/free/refcount
round-trips under arbitrary interleavings, conservation under
fragmentation (no block is ever lost or double-leased), and block-table
growth matching token counts. Deterministic allocator/engine tests live in
``tests/test_paged_engine.py`` (they run without hypothesis)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis "
    "(pip install repro[test])")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.serve.block_pool import BlockPool, PagedKVCache  # noqa: E402


@given(st.lists(st.tuples(st.booleans(), st.integers(1, 6)),
                min_size=1, max_size=60),
       st.integers(2, 32))
@settings(max_examples=50, deadline=None)
def test_fragmentation_never_loses_blocks(ops, num_blocks):
    """Arbitrary interleaved alloc/free traffic: every block is always
    exactly free or leased-once, and a full drain restores the pool."""
    pool = BlockPool(num_blocks=num_blocks, block_size=4)
    live = []
    for want_alloc, n in ops:
        if want_alloc and n <= pool.num_free:
            live.append(pool.alloc(n, f"req{len(live)}"))
        elif not want_alloc and live:
            pool.free(live.pop(
                int(np.random.default_rng(n).integers(len(live)))))
        leased = {b for blocks in live for b in blocks}
        assert len(leased) == sum(map(len, live))      # never double-leased
        assert pool.num_free + len(leased) == num_blocks   # conservation
    for blocks in live:
        pool.free(blocks)
    assert pool.num_free == num_blocks


@given(st.lists(st.integers(0, 3), min_size=1, max_size=30),
       st.integers(2, 8))
@settings(max_examples=50, deadline=None)
def test_refcount_roundtrip(extra_refs, num_blocks):
    """A block leased once and ref'd k more times survives exactly k+1
    frees and then double-free raises."""
    pool = BlockPool(num_blocks=num_blocks, block_size=4)
    for k in extra_refs:
        [b] = pool.alloc(1, "first")
        for _ in range(k):
            pool.ref(b)
        assert pool.refcount(b) == k + 1
        for i in range(k + 1):
            assert pool.refcount(b) == k + 1 - i
            pool.free([b])
        assert pool.refcount(b) == 0
        with pytest.raises(Exception):
            pool.free([b])
        assert pool.num_free == num_blocks


@given(st.integers(0, 10_000), st.integers(1, 256))
@settings(max_examples=100, deadline=None)
def test_blocks_needed_matches_token_count(ntokens, block_size):
    pool = BlockPool(num_blocks=1, block_size=block_size)
    nb = pool.blocks_needed(ntokens)
    assert nb * block_size >= ntokens          # covers every token
    assert (nb - 1) * block_size < max(ntokens, 1)   # no spare block


class _StubModel:
    @staticmethod
    def init_paged_cache(num_blocks, block_size, dtype=None):
        return {"k": np.zeros((1, num_blocks, block_size, 1, 1)),
                "v": np.zeros((1, num_blocks, block_size, 1, 1))}


@given(st.lists(st.integers(1, 30), min_size=1, max_size=12))
@settings(max_examples=50, deadline=None)
def test_table_growth_matches_token_count(token_counts):
    """Each admitted request's table holds exactly ceil(tokens/bs) valid
    entries; freeing returns exactly that many blocks."""
    kv = PagedKVCache(_StubModel(), num_blocks=128, block_size=4,
                      num_slots=12, max_blocks_per_req=8)
    rows = []
    for i, n in enumerate(token_counts):
        free_before = kv.num_free_blocks
        row = kv.alloc(f"req{i}", n)
        nb = -(-n // 4)
        table = kv.table_rows([row])[0]
        assert (table >= 0).sum() == nb
        assert free_before - kv.num_free_blocks == nb
        rows.append((row, nb))
    for row, nb in rows:
        free_before = kv.num_free_blocks
        kv.free(row)
        assert kv.num_free_blocks - free_before == nb
    assert kv.num_free_blocks == 128 and kv.num_live == 0
