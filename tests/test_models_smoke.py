"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step (and a prefill+decode step) on CPU; asserts output shapes
and finiteness. The FULL configs are exercised only via the dry-run."""

import jax
import jax.numpy as jnp
import pytest

from repro.config import TrainConfig, ServeConfig
from repro.configs import ARCH_NAMES, get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)
SERVE = ServeConfig(param_dtype="float32", compute_dtype="float32")

B, S = 2, 32


@pytest.fixture(scope="module")
def models():
    return {}


def _build(arch):
    cfg = get_smoke_config(arch)
    return cfg, build_model(cfg, TRAIN, SERVE, tp=1)


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_train_step_smoke(arch):
    cfg, model = _build(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    # gradients flow and are finite
    grads = jax.grad(lambda p: model.train_loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(jnp.all(jnp.isfinite(g)) for g in leaves), f"{arch}: NaN grads"
    assert any(jnp.any(g != 0) for g in leaves), f"{arch}: all-zero grads"


@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_prefill_decode_smoke(arch):
    cfg, model = _build(arch)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    cache_len = S + 4
    logits, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, batch)
    assert logits.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits[:, :cfg.vocab_size])), arch
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    step = jax.jit(model.decode_step)
    logits2, cache2 = step(params, cache, tok, jnp.int32(S))
    assert logits2.shape == (B, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits2[:, :cfg.vocab_size])), arch
    # cache structure is stable across steps (scan-compatible)
    jax.tree_util.tree_map(lambda a, b: None, cache, cache2)


def test_decode_matches_prefill_logits():
    """Teacher-forcing consistency: decode_step at position i must reproduce
    the full-forward logits for a dense arch (tight numeric check)."""
    cfg, model = _build("yi-9b")
    params = model.init(jax.random.PRNGKey(1))
    batch = make_synthetic_batch(cfg, B, S, compute_dtype="float32")
    tokens = batch["tokens"]
    cache_len = S + 8
    # prefill on the first S-1 tokens, then decode token S-1
    pre_batch = dict(batch, tokens=tokens[:, :S - 1])
    logits_pre, cache = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, pre_batch)
    logits_dec, _ = jax.jit(model.decode_step)(
        params, cache, tokens[:, S - 1:S], jnp.int32(S - 1))
    # full forward for reference
    logits_full, cache_full = jax.jit(
        lambda p, b: model.prefill(p, b, cache_len))(params, batch)
    assert jnp.allclose(logits_dec, logits_full, atol=2e-3, rtol=2e-3), (
        jnp.max(jnp.abs(logits_dec - logits_full)))


def test_hymba_window_masking():
    """Sliding-window layers must not attend beyond the window."""
    from repro.models import layers as L
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 4))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 4))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 8, 2, 4))
    pos = jnp.arange(8)
    out_w = L.full_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                             window=2)
    # windowed attention at position i only sees {i-1, i}; build reference
    out_ref = L.full_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                               window=jnp.asarray(2))
    assert jnp.allclose(out_w, out_ref)
    out_full = L.full_attention(q, k, v, q_pos=pos, k_pos=pos, causal=True,
                                window=None)
    assert not jnp.allclose(out_w, out_full)
