"""SpMV / PETSc case-study app tests (single device) + distributed case."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps.spmv import cg_solve_ref, stencil_matmult_ref
from tests.helpers import run_case


def _numpy_stencil(x):
    """Naive 27-point stencil oracle (zero boundary)."""
    n = x.shape[0]
    xp = np.zeros((n + 2,) * 3, x.dtype)
    xp[1:-1, 1:-1, 1:-1] = np.asarray(x)
    y = np.zeros_like(np.asarray(x))
    for dz in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dx in (-1, 0, 1):
                w = 26.0 if (dz, dy, dx) == (0, 0, 0) else -1.0
                y += w * xp[1 + dz:n + 1 + dz, 1 + dy:n + 1 + dy,
                            1 + dx:n + 1 + dx]
    return y


def test_stencil_matches_numpy():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 8, 8))
    got = np.asarray(stencil_matmult_ref(x))
    want = _numpy_stencil(x)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_cg_reduces_residual():
    b = jax.random.normal(jax.random.PRNGKey(1), (12, 12, 12))
    x = cg_solve_ref(b, iters=15)
    r = b - stencil_matmult_ref(x)
    assert float(jnp.linalg.norm(r)) < 0.2 * float(jnp.linalg.norm(b))


def test_distributed_matmult_case():
    run_case("spmv_distributed", ndev=8)
