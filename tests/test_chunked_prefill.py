"""Chunked, batched prefill for the continuous engine (DESIGN.md §8):
token parity against monolithic prefill and the static baseline, O(1)
prefill compiles across distinct prompt lengths, per-request sampling
determinism under any admission order / chunking config, slot reuse
after an EOS first token, the prefilling scheduler state, and engine
``reset()`` (no stale device state after warm-up)."""

import jax
import numpy as np
import pytest

from repro.config import ServeConfig, TrainConfig
from repro.configs import get_smoke_config
from repro.models.registry import build_model, make_synthetic_batch
from repro.serve import ContinuousEngine, ServeRequest, StaticEngine

TRAIN = TrainConfig(param_dtype="float32", compute_dtype="float32",
                    loss_chunk=16, attn_chunk_threshold=64, attn_chunk=16,
                    remat=False)


def _bundle(arch="gemma-2b", seed=0):
    cfg = get_smoke_config(arch)
    model = build_model(cfg, TRAIN, ServeConfig(), tp=1)
    return cfg, model, model.init(jax.random.PRNGKey(seed))


def _prompt(cfg, B=4, S=8, seed=0):
    batch = make_synthetic_batch(cfg, B, S, seed=seed,
                                 compute_dtype="float32")
    return {"tokens": batch["tokens"]}


def _cont(model, params, *, cache_len, num_slots, chunk, per_step=1,
          eos_id=-1):
    return ContinuousEngine(model, params, cache_len=cache_len,
                            num_slots=num_slots, eos_id=eos_id,
                            prefill_chunk=chunk,
                            max_prefill_per_step=per_step)


# ---------------------------------------------------------------------------
# parity: chunked deposit must be token-identical to monolithic prefill
# ---------------------------------------------------------------------------

def test_chunked_vs_monolithic_token_parity_greedy():
    """Multi-chunk prompts (20 tokens, chunks of 8/5/64) produce exactly
    the tokens of the monolithic prefill and the static baseline."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=3, S=20, seed=3)
    static = StaticEngine(model, params, cache_len=36).generate(prompt, 10)
    mono = _cont(model, params, cache_len=36, num_slots=3,
                 chunk=0).generate(prompt, 10)
    assert np.array_equal(static, mono)
    for chunk, per_step in ((8, 2), (5, 1), (64, 3)):
        out = _cont(model, params, cache_len=36, num_slots=3, chunk=chunk,
                    per_step=per_step).generate(prompt, 10)
        assert np.array_equal(static, out), (chunk, per_step)


def test_chunked_parity_fewer_slots_than_requests():
    """Slot recycling with chunked deposits: freed slots are re-streamed
    into (reset_slot) without stale pages aliasing as history."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=4, S=12, seed=5)
    static = StaticEngine(model, params, cache_len=24).generate(prompt, 9)
    cont = _cont(model, params, cache_len=24, num_slots=2, chunk=4,
                 per_step=2).generate(prompt, 9)
    assert np.array_equal(static, cont)


def test_non_dense_families_chunk_with_parity():
    """State-threaded chunk contract (DESIGN.md §13): SSM and MoE
    families run the chunked slot path token-identically to the static
    monolithic baseline — the old silent fallback is gone."""
    cfg, model, params = _bundle("mamba2-370m")
    assert model.prefill_chunk is not None
    assert model.capabilities.carried_state
    eng = _cont(model, params, cache_len=16, num_slots=2, chunk=8)
    assert eng.prefill_chunk == 8
    prompt = _prompt(cfg, B=2, S=8)
    static = StaticEngine(model, params, cache_len=16).generate(prompt, 6)
    assert np.array_equal(static, eng.generate(prompt, 6))
    # MoE routes per-token (dropless) on every serving path, so chunk
    # boundaries cannot shift expert-capacity competition
    moe_cfg, moe_model, moe_params = _bundle("olmoe-1b-7b")
    assert moe_model.prefill_chunk is not None
    moe_eng = _cont(moe_model, moe_params, cache_len=16, num_slots=2,
                    chunk=8)
    assert moe_eng.prefill_chunk == 8
    moe_prompt = _prompt(moe_cfg, B=2, S=8)
    moe_static = StaticEngine(moe_model, moe_params,
                              cache_len=16).generate(moe_prompt, 6)
    assert np.array_equal(moe_static, moe_eng.generate(moe_prompt, 6))


def test_chunk_floored_to_family_multiple():
    """SSM/hybrid chunk sizes are floored to ssm_chunk multiples (scan
    resume is bit-exact only on the fixed inner grid); a chunk smaller
    than one multiple raises naming the constraint."""
    _, model, params = _bundle("mamba2-370m")
    m = model.capabilities.chunk_multiple
    eng = _cont(model, params, cache_len=4 * m, num_slots=2,
                chunk=m + m // 2)
    assert eng.prefill_chunk == m
    with pytest.raises(ValueError, match="chunk_multiple"):
        _cont(model, params, cache_len=4 * m, num_slots=2, chunk=m - 1)


def test_unchunkable_family_raises_naming_capability():
    """patch_stub frontends cannot chunk: requesting chunked prefill
    raises naming the missing capability instead of silently running
    monolithic (explicit monolithic via chunk=0 still works)."""
    _, model, params = _bundle("internvl2-76b")
    with pytest.raises(ValueError, match="chunked_prefill"):
        _cont(model, params, cache_len=16, num_slots=2, chunk=8)
    eng = _cont(model, params, cache_len=16, num_slots=2, chunk=0)
    assert eng.prefill_chunk == 0


# ---------------------------------------------------------------------------
# O(1) compiles: the chunk jit never sees a new shape
# ---------------------------------------------------------------------------

def test_prefill_compile_count_independent_of_prompt_lengths():
    cfg, model, params = _bundle()
    chunked = _cont(model, params, cache_len=40, num_slots=2, chunk=8,
                    per_step=2)
    mono = _cont(model, params, cache_len=40, num_slots=2, chunk=0)
    for eng in (chunked, mono):
        for S in (5, 12, 20):
            eng.generate(_prompt(cfg, B=1, S=S, seed=S), 3)
    assert chunked.prefill_compiles == 1          # one chunk program, ever
    assert mono.prefill_compiles == 3             # one per distinct length


# ---------------------------------------------------------------------------
# sampling determinism: fold_in(rid) key streams are admission-invariant
# ---------------------------------------------------------------------------

def _run_trace(model, params, prompts, *, chunk, per_step, num_slots,
               order, temperature=0.7, seed=11, max_new=6):
    eng = _cont(model, params, cache_len=40, num_slots=num_slots,
                chunk=chunk, per_step=per_step)
    reqs = {}
    for rid in order:
        req = ServeRequest(rid=rid, batch=prompts[rid],
                           max_new_tokens=max_new,
                           temperature=temperature, seed=seed)
        reqs[rid] = req
        eng.submit(req, 0.0)
    steps = 0
    while not eng.idle:
        eng.step(0.0)
        steps += 1
        assert steps < 500
    return {rid: r.output.copy() for rid, r in reqs.items()}


def test_temperature_decode_deterministic_across_admission_and_chunking():
    """temperature>0 outputs are a pure function of (rid, seed): any
    admission order, slot count, ``max_prefill_per_step`` and chunk size
    (including monolithic) yields identical per-request tokens."""
    cfg, model, params = _bundle()
    prompts = {rid: _prompt(cfg, B=1, S=6 + 3 * rid, seed=100 + rid)
               for rid in range(4)}
    base = _run_trace(model, params, prompts, chunk=8, per_step=1,
                      num_slots=2, order=[0, 1, 2, 3])
    for kw in (dict(chunk=8, per_step=1, num_slots=2, order=[3, 1, 0, 2]),
               dict(chunk=4, per_step=3, num_slots=4, order=[2, 0, 3, 1]),
               dict(chunk=0, per_step=2, num_slots=3, order=[1, 3, 2, 0])):
        out = _run_trace(model, params, prompts, **kw)
        for rid in prompts:
            assert np.array_equal(base[rid], out[rid]), (rid, kw)


# ---------------------------------------------------------------------------
# EOS on the first token + slot reuse
# ---------------------------------------------------------------------------

def test_slot_reuse_after_eos_first_token_chunked():
    """A request whose very first sampled token is EOS finishes at the
    end of its prefill; its slot must be immediately reusable and the
    next occupant's tokens unaffected."""
    cfg, model, params = _bundle()
    p0 = _prompt(cfg, B=1, S=10, seed=7)
    free = StaticEngine(model, params, cache_len=24).generate(p0, 4)
    eos = int(free[0, 0])
    p1 = _prompt(cfg, B=1, S=10, seed=8)
    solo = _cont(model, params, cache_len=24, num_slots=1, chunk=4,
                 eos_id=eos).generate(p1, 6)

    eng = _cont(model, params, cache_len=24, num_slots=1, chunk=4,
                per_step=1, eos_id=eos)
    r0 = ServeRequest(rid=0, batch=p0, max_new_tokens=6)
    r1 = ServeRequest(rid=1, batch=p1, max_new_tokens=6)
    eng.submit(r0, 0.0)
    eng.submit(r1, 0.0)
    steps = 0
    while not eng.idle:
        eng.step(0.0)
        steps += 1
        assert steps < 200
    assert r0.generated == 1 and r0.output[0] == eos
    assert (r0.output == eos).all()               # eos-padded tail
    assert np.array_equal(r1.output, solo[0])     # clean slot reuse


# ---------------------------------------------------------------------------
# prefilling scheduler state + accounting
# ---------------------------------------------------------------------------

def test_prefilling_state_and_chunk_accounting():
    cfg, model, params = _bundle()
    eng = _cont(model, params, cache_len=40, num_slots=2, chunk=8,
                per_step=1)
    req = ServeRequest(rid=0, batch=_prompt(cfg, B=1, S=20, seed=2),
                       max_new_tokens=3)
    eng.submit(req, 0.0)
    assert req.state == "queued"
    eng.step(0.0)                       # admitted + first chunk deposited
    assert req.state == "prefilling"
    assert eng.num_prefilling == 1 and eng.num_decoding == 0
    assert req.first_token_time is None
    eng.step(1.0)
    eng.step(2.0)                       # 20 tokens / chunk 8 -> 3 chunks
    assert req.state == "decoding"
    assert req.prefill_chunks == 3
    # first token sampled at the final chunk, plus the same step's decode
    # micro-step (finalized slots decode immediately, like monolithic)
    assert req.first_token_time == 2.0 and req.generated == 2
    while not eng.idle:
        eng.step(3.0)
    assert req.state == "done" and req.generated == 3
    assert eng.scheduler.latency_stats()["ttft_p95_s"] == pytest.approx(2.0)


def test_drive_static_mixed_temperature_samples_per_row():
    """Bugfix: a static batch group applied group[0]'s temperature to
    every row; greedy rows in a mixed-temperature group must stay exactly
    greedy."""
    from repro.launch.serve import drive_static
    cfg, model, params = _bundle()
    eng = StaticEngine(model, params, cache_len=24)
    prompt = _prompt(cfg, B=4, S=8, seed=4)
    greedy = eng.generate(prompt, 6)                      # temperature 0
    reqs = [ServeRequest(rid=i,
                         batch={"tokens": prompt["tokens"][i:i + 1]},
                         max_new_tokens=6,
                         temperature=0.0 if i < 2 else 0.9)
            for i in range(4)]
    drive_static(eng, reqs, batch_size=4)
    for i in range(2):                  # greedy rows unaffected by the mix
        assert np.array_equal(reqs[i].output, greedy[i])
    assert all(r.output is not None for r in reqs)


def test_drive_static_heterogeneous_seeds_raise():
    from repro.launch.serve import drive_static
    cfg, model, params = _bundle()
    eng = StaticEngine(model, params, cache_len=24)
    prompt = _prompt(cfg, B=2, S=8)
    reqs = [ServeRequest(rid=i, batch={"tokens": prompt["tokens"][i:i + 1]},
                         max_new_tokens=4, temperature=0.5, seed=i)
            for i in range(2)]
    with pytest.raises(ValueError, match="heterogeneous seeds"):
        drive_static(eng, reqs, batch_size=2)


def test_drive_static_buckets_mixed_prompt_lengths():
    """Static batches need rectangular prompts: a mixed-length trace is
    bucketed by prompt length instead of crashing on ragged concat."""
    from repro.launch.serve import drive_static
    cfg, model, params = _bundle()
    eng = StaticEngine(model, params, cache_len=32)
    reqs = []
    for i in range(4):
        S = 8 if i % 2 == 0 else 16
        p = _prompt(cfg, B=1, S=S, seed=20 + i)
        reqs.append(ServeRequest(rid=i, batch=p, max_new_tokens=4))
    stats = drive_static(eng, reqs, batch_size=2)
    assert stats["n"] == 4.0
    assert all(r.output is not None and r.finish_time is not None
               for r in reqs)


def test_engine_reset_clears_stale_state():
    """After warm-up traffic, ``reset()`` returns the engine to a clean
    slate: freed-slot device state is parked (no silent advancing), the
    pool is empty, the scheduler accounting zeroed — and a post-reset run
    is token-identical to a fresh engine's."""
    cfg, model, params = _bundle()
    prompt = _prompt(cfg, B=2, S=8)
    fresh = _cont(model, params, cache_len=24, num_slots=2, chunk=4)
    expect = fresh.generate(prompt, 8)

    eng = _cont(model, params, cache_len=24, num_slots=2, chunk=4)
    eng.generate(_prompt(cfg, B=2, S=6, seed=9), 5)      # warm-up traffic
    assert eng.scheduler.n_submitted == 2
    eng.reset()
    assert eng.idle and eng.kv.num_free == eng.kv.num_slots
    assert eng.scheduler.n_submitted == 0
    assert not eng.scheduler.finished
    assert np.array_equal(eng.generate(prompt, 8), expect)
