"""Host-side serving substrate tests: cell-queue admission (paper §3.2 as
admission control), slot-pool lifecycle, traces, and the protocol-name
validation satellite (ValueError instead of silent 1-copy fallthrough)."""

import numpy as np
import pytest

from repro.core import p2p, protocol
from repro.serve import (CellQueueScheduler, ServeRequest, SlotError,
                         SlotKVCache, make_trace, shard_trace)


def _req(rid, prompt_len, max_new=8, arrival=0.0):
    return ServeRequest(rid=rid,
                        batch={"tokens": np.zeros((1, prompt_len), np.int32)},
                        max_new_tokens=max_new, arrival=arrival)


# ---------------------------------------------------------------------------
# cell-queue scheduler
# ---------------------------------------------------------------------------

def test_eager_admission_within_cell_budget():
    s = CellQueueScheduler(num_cells=4)
    # 16-token prompt = 64 bytes -> single-cell eager_fast
    assert s.submit(_req(0, 16), now=0.0) == "cells"
    assert s.queue_depths()["cells"] == 1 and s.cells_free == 3
    out = s.admit(now=1.0, free_slots=2)
    assert [q.rid for q in out] == [0]
    assert s.cells_free == 4
    assert out[0].protocol == "eager_fast" and out[0].cells == 1
    assert out[0].admit_time == 1.0 and out[0].submit_time == 0.0


def test_multi_cell_eager_occupancy_and_overflow_promotion():
    # cell_size=1024B -> 256 tokens/cell; 600-token prompt = 2400B:
    # eager class (<= 4096B) but 3 cells
    s = CellQueueScheduler(num_cells=4, cell_size=1024)
    assert s.submit(_req(0, 600), 0.0) == "cells"
    assert s.cells_free == 1
    # next eager request needs 2 cells -> overflows (bounded pool)
    assert s.submit(_req(1, 300), 0.0) == "overflow"
    assert s.n_deferred == 1
    # admitting rid 0 releases its cells and promotes rid 1 FIFO
    out = s.admit(1.0, free_slots=1)
    assert [q.rid for q in out] == [0]
    assert s.queue_depths() == {"cells": 1, "overflow": 0, "rendezvous": 0,
                                "cells_free": 2}
    out = s.admit(2.0, free_slots=4)
    assert [q.rid for q in out] == [1]


def test_eager_request_larger_than_pool_takes_rendezvous_path():
    """A prompt that could never fit the cell pool even when empty must
    not starve in overflow — it follows the rendezvous discipline."""
    s = CellQueueScheduler(num_cells=2, cell_size=1024)
    # 800 tokens = 3200B: eager class, but needs 4 cells > pool of 2
    assert s.submit(_req(0, 800), 0.0) == "rendezvous"
    out = s.admit(1.0, free_slots=1)
    assert [q.rid for q in out] == [0] and out[0].cells == 0


def test_rendezvous_class_defers_until_slot_free():
    s = CellQueueScheduler(num_cells=8)
    # 2000-token prompt = 8000B > eager threshold -> rendezvous (1-copy)
    assert s.submit(_req(0, 2000), 0.0) == "rendezvous"
    assert s.submit(_req(1, 16), 0.0) == "cells"
    # no slot free: nothing moves (the handshake waits for the receiver)
    assert s.admit(1.0, free_slots=0) == []
    # buffered (cell) requests drain ahead of rendezvous ones
    out = s.admit(2.0, free_slots=2)
    assert [q.rid for q in out] == [1, 0]
    assert out[1].protocol == "one_copy" and out[1].cells == 0


def test_fifo_within_class_and_accounting():
    s = CellQueueScheduler(num_cells=16)
    for i in range(4):
        s.submit(_req(i, 16, arrival=float(i)), now=float(i))
    out = s.admit(5.0, free_slots=4)
    assert [q.rid for q in out] == [0, 1, 2, 3]
    for q in out:
        q.generated = 4
        s.record_finish(q, now=6.0)
    stats = s.latency_stats()
    assert stats["n"] == 4.0 and stats["tokens"] == 16.0
    assert stats["latency_p50_s"] == pytest.approx(6.0 - 1.5)
    assert s.modeled_admit_cost_s > 0.0   # protocol cost model engaged


# ---------------------------------------------------------------------------
# slot pool
# ---------------------------------------------------------------------------

class _StubModel:
    """Just enough of the Model bundle for SlotKVCache."""

    @staticmethod
    def init_cache(batch, cache_len, dtype=None):
        import jax.numpy as jnp
        return {"k": jnp.zeros((2, batch, cache_len, 1, 4), jnp.float32),
                "pos": jnp.full((2, cache_len), -1, jnp.int32)}


def test_slot_pool_alloc_free_lifecycle():
    import jax.numpy as jnp
    kv = SlotKVCache(_StubModel(), cache_len=8, num_slots=2)
    a = kv.alloc("req-a")
    b = kv.alloc("req-b")
    assert {a, b} == {0, 1} and kv.num_free == 0
    with pytest.raises(SlotError):
        kv.alloc("req-c")               # exhaustion is an error, not a wait
    one = _StubModel.init_cache(1, 8)
    kv.insert(a, one, length=5)
    kv.advance(a)
    assert kv.length(a) == 6 and kv.owner(a) == "req-a"
    kv.free(a)
    with pytest.raises(SlotError):
        kv.free(a)                      # double free
    with pytest.raises(SlotError):
        kv.insert(a, one, length=1)     # insert into freed slot
    assert kv.num_free == 1 and kv.live_slots == [b]
    # buffers keep the stacked leading slot dim
    assert kv.buffers["k"].shape == (2, 2, 1, 8, 1, 4)


# ---------------------------------------------------------------------------
# traces + replica fan-out
# ---------------------------------------------------------------------------

def test_make_trace_kinds_and_shard():
    tr = make_trace(8, prompt_len=16, max_new=(2, 6), arrival="poisson",
                    rate=100.0, seed=0)
    assert len(tr) == 8 and tr[0].arrival == 0.0
    assert all(t2.arrival >= t1.arrival for t1, t2 in zip(tr, tr[1:]))
    assert all(2 <= t.max_new <= 6 for t in tr)
    tb = make_trace(8, prompt_len=16, max_new=4, arrival="burst", burst=4,
                    rate=10.0)
    assert tb[0].arrival == tb[3].arrival and tb[4].arrival > tb[3].arrival
    with pytest.raises(ValueError):
        make_trace(4, prompt_len=8, max_new=2, arrival="bogus")
    s0, s1 = shard_trace(tr, 0, 2), shard_trace(tr, 1, 2)
    assert len(s0) + len(s1) == len(tr)
    assert not {id(e) for e in s0} & {id(e) for e in s1}
    with pytest.raises(ValueError):
        shard_trace(tr, 2, 2)


# ---------------------------------------------------------------------------
# protocol-name validation (satellite: no silent 1-copy fallthrough)
# ---------------------------------------------------------------------------

def test_unknown_protocol_rejected():
    with pytest.raises(ValueError, match="unknown protocol"):
        protocol.validate_protocol("two_copy")
    with pytest.raises(ValueError, match="unknown protocol"):
        protocol.request_overhead(64, proto="two_copy")
    import jax.numpy as jnp
    with pytest.raises(ValueError, match="unknown protocol"):
        p2p.send_recv(jnp.zeros((4,)), "ranks", [(0, 0)],
                      force_protocol="two_copy")
    # known names still accepted by the model helpers
    assert protocol.request_overhead(64, proto="eager_fast") == 0.0
    assert protocol.request_overhead(64, proto="one_copy") > 0.0
